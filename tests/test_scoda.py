"""SCoDA correctness: equivalence to the sequential algorithm at block_size=1,
parity at production block sizes, determinism, and label invariants."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import planted_partition, mode_degree, pad_edges
from repro.core.scoda import ScodaConfig, detect_communities, dense_labels
from repro.core.modularity import modularity


def seq_scoda(edges_np, n, threshold, rounds=1, tie="join"):
    """The sequential oracle: Hollocou's SCoDA with the paper's round scheme."""
    com = np.arange(n)
    deg = np.zeros(n, np.int64)
    for r in range(rounds):
        t = min(threshold ** (r + 1), 2**30)
        for u, v in edges_np:
            deg[u] += 1
            deg[v] += 1
            if deg[u] <= t and deg[v] <= t:
                if deg[u] < deg[v]:
                    com[u] = com[v]
                elif deg[v] < deg[u]:
                    com[v] = com[u]
                elif tie == "join":
                    com[u] = com[v]
    return com.astype(np.int32)


@pytest.fixture(scope="module")
def small_graph():
    edges, labels = planted_partition(300, 6, 0.25, 0.005, seed=7)
    return edges, labels, 300


@pytest.mark.parametrize("tie", ["join", "skip"])
def test_block_size_one_equals_sequential(small_graph, tie):
    """block_size=1 *is* the sequential algorithm — exact label equality."""
    edges_np, _, n = small_graph
    edges_np = edges_np[:600]
    dt = max(2, mode_degree(edges_np, n))
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    for rounds in (1, 2):
        cfg = ScodaConfig(degree_threshold=dt, rounds=rounds, block_size=1, tie_break=tie)
        lab, _ = detect_communities(edges, n, cfg)
        ref = seq_scoda(edges_np, n, dt, rounds=rounds, tie=tie)
        np.testing.assert_array_equal(np.asarray(lab), ref)


def test_parallel_matches_sequential_quality(small_graph):
    """At production block sizes the partition quality tracks the oracle."""
    edges_np, _, n = small_graph
    dt = max(2, mode_degree(edges_np, n))
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    ref = seq_scoda(edges_np, n, dt, rounds=4)
    q_ref = float(modularity(edges, jnp.asarray(ref), n))
    cfg = ScodaConfig(degree_threshold=dt, rounds=4, block_size=1024, tie_break="join")
    lab, _ = detect_communities(edges, n, cfg)
    q_par = float(modularity(edges, lab, n))
    assert q_par > 0.5 * q_ref, (q_par, q_ref)


def test_deterministic(small_graph):
    edges_np, _, n = small_graph
    dt = max(2, mode_degree(edges_np, n))
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    cfg = ScodaConfig(degree_threshold=dt, rounds=3, block_size=512)
    lab1, deg1 = detect_communities(edges, n, cfg)
    lab2, deg2 = detect_communities(edges, n, cfg)
    np.testing.assert_array_equal(np.asarray(lab1), np.asarray(lab2))
    np.testing.assert_array_equal(np.asarray(deg1), np.asarray(deg2))


def test_labels_are_valid_node_ids(small_graph):
    edges_np, _, n = small_graph
    dt = max(2, mode_degree(edges_np, n))
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    cfg = ScodaConfig(degree_threshold=dt, rounds=4, block_size=256)
    lab, deg = detect_communities(edges, n, cfg)
    lab = np.asarray(lab)
    assert lab.min() >= 0 and lab.max() < n
    assert (np.asarray(deg) >= 0).all()


def test_multi_round_merges(small_graph):
    """Paper Table 3: more rounds → communities merge (fewer supernodes)."""
    edges_np, _, n = small_graph
    dt = max(2, mode_degree(edges_np, n))
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    counts = []
    for rounds in (1, 4):
        cfg = ScodaConfig(degree_threshold=dt, rounds=rounds, block_size=512)
        lab, _ = detect_communities(edges, n, cfg)
        counts.append(len(np.unique(np.asarray(lab))))
    assert counts[1] <= counts[0]


def test_isolated_nodes_stay_singletons():
    n = 64
    edges_np = np.array([[0, 1], [1, 2], [2, 3]], np.int32)
    edges = jnp.asarray(pad_edges(edges_np, 8, n))
    cfg = ScodaConfig(degree_threshold=3, rounds=2, block_size=4)
    lab, _ = detect_communities(edges, n, cfg)
    lab = np.asarray(lab)
    for i in range(4, n):
        assert lab[i] == i


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_dense_labels_bijective(seed):
    rng = np.random.default_rng(seed)
    n = 50
    raw = rng.integers(0, n, size=n).astype(np.int32)
    dense, count = dense_labels(jnp.asarray(raw), n)
    dense = np.asarray(dense)
    assert int(count) == len(np.unique(raw))
    # same raw label ⇔ same dense label
    for lab in np.unique(raw):
        vals = dense[raw == lab]
        assert (vals == vals[0]).all()
    assert dense.min() >= 0 and dense.max() < int(count)
