"""Multi-device sharded detect+layout pipeline: bit-identity to the
single-device path, divisibility fallbacks, and the StreamRunner chunk
padding fix.

These tests adapt to the available device count: on the tier-1 single
device the sharded entry points take their graceful-degradation fallbacks
(API coverage), and the CI ``shard-smoke`` matrix re-runs the same file
under ``XLA_FLAGS=--xla_force_host_platform_device_count={2,8}`` where the
collectives actually engage. One subprocess test forces 4 devices so real
multi-device coverage exists even in the tier-1 run.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import forceatlas2 as fa2
from repro.core.pipeline import biggraphvis, default_config
from repro.core.stream import StreamConfig
from repro.graph import mode_degree, planted_partition
from repro.kernels.grid.ref import bin_and_sort, near_field_ref, near_field_rows
from repro.kernels.repulsion import ops as rep_ops
from repro.launch.mesh import make_stream_mesh
from repro.launch.stream_runner import StreamRunner, StreamRunnerConfig

N = 768
COMMUNITIES = 16
multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count)",
)


def _graph():
    edges, _ = planted_partition(N, COMMUNITIES, 0.3, 0.002, seed=11)
    return edges


def _cfg(edges, iterations=5, block=128):
    cfg = default_config(N, len(edges), mode_degree(edges, N),
                         rounds=2, iterations=iterations)
    return replace(cfg, scoda=replace(cfg.scoda, block_size=block))


def _assert_same(a, b):
    assert np.array_equal(np.asarray(a.labels), np.asarray(b.labels))
    assert np.array_equal(np.asarray(a.supergraph.edges),
                          np.asarray(b.supergraph.edges))
    assert np.array_equal(np.asarray(a.supergraph.weights),
                          np.asarray(b.supergraph.weights))
    assert np.array_equal(a.sizes, b.sizes)
    assert a.n_supernodes == b.n_supernodes
    assert a.n_superedges == b.n_superedges
    assert a.modularity == b.modularity
    assert np.array_equal(a.positions, b.positions)


def test_sharded_pipeline_matches_unsharded():
    """Full streamed pipeline, sharded vs plain, whatever the device count.

    block 128 divides by any power-of-two mesh up to 8 and chunk 256 holds
    whole blocks, so the sharded path engages whenever devices allow.
    """
    edges = _graph()
    cfg = _cfg(edges)
    res_plain = biggraphvis(edges, N, cfg, stream=StreamConfig(chunk_size=256))
    mesh = make_stream_mesh()
    runner = StreamRunner(cfg, StreamRunnerConfig(
        stream=StreamConfig(chunk_size=256, shard_detect=True,
                            shard_layout=True),
        shard_chunks=True,
    ), mesh=mesh)
    res_shard = runner.run(edges, N)
    _assert_same(res_plain, res_shard)
    assert res_shard.stream.devices == mesh.size
    assert res_shard.stream.peak_local_bytes <= res_shard.stream.peak_device_bytes


def test_sharded_pipeline_lexsort_backend():
    edges = _graph()
    cfg = _cfg(edges)
    scfg = StreamConfig(chunk_size=256, agg_backend="lexsort")
    res_plain = biggraphvis(edges, N, cfg, stream=scfg)
    res_shard = biggraphvis(
        edges, N, cfg,
        stream=replace(scfg, mesh=make_stream_mesh(), shard_detect=True),
    )
    _assert_same(res_plain, res_shard)


def test_divisibility_fallback_is_silent_and_identical():
    """Extents that can't split across devices → unsharded path, same
    result, and StreamStats reports the fallback (devices == 1). 81 is odd,
    so both the detect (block) and supergraph (chunk) gates trip on any
    multi-device mesh (device counts are powers of two here)."""
    edges = _graph()
    cfg = _cfg(edges, block=81)
    scfg = StreamConfig(chunk_size=81)
    res_plain = biggraphvis(edges, N, cfg, stream=scfg)
    res_shard = biggraphvis(
        edges, N, cfg,
        stream=replace(scfg, mesh=make_stream_mesh(), shard_detect=True),
    )
    _assert_same(res_plain, res_shard)
    if 81 % jax.device_count() != 0:
        assert res_shard.stream.devices == 1


@pytest.mark.parametrize("repulsion", ["exact", "grid"])
def test_layout_sharded_matches_layout(repulsion):
    edges = jnp.asarray(_graph()[:512])
    w = jnp.ones(edges.shape[0], jnp.float32)
    mass = jnp.zeros(N, jnp.float32).at[edges[:, 0]].add(1.0) + 1.0
    cfg = fa2.FA2Config(iterations=4, repulsion=repulsion, grid_size=8,
                        grid_window=8)
    pos, trace, it = fa2.layout(edges, w, mass, N, cfg)
    mesh = make_stream_mesh()
    pos_s, trace_s, it_s = fa2.layout_sharded(edges, w, mass, N, cfg, mesh)
    assert np.array_equal(np.asarray(pos), np.asarray(pos_s))
    assert np.array_equal(np.asarray(trace), np.asarray(trace_s))
    assert int(it) == int(it_s) == cfg.iterations


def test_layout_sharded_fallbacks():
    """Non-divisible n and no-sharded-form backends fall back to layout,
    warning once (regression: the fallback used to be silent, so a
    configured mesh could quietly never engage)."""
    import warnings

    n = 99  # prime-ish: only divides a 1/3/9/11/33/99-device mesh
    edges = jnp.asarray([[0, 1], [1, 2], [2, 3]], jnp.int32)
    w = jnp.ones(3, jnp.float32)
    mass = jnp.ones(n, jnp.float32)
    cfg = fa2.FA2Config(iterations=2, repulsion="exact")
    pos, _, _ = fa2.layout(edges, w, mass, n, cfg)
    fa2._FALLBACK_WARNED.clear()
    with pytest.warns(UserWarning, match="falling back to single-device"):
        pos_s, _, _ = fa2.layout_sharded(
            edges, w, mass, n, cfg, make_stream_mesh())
    assert np.array_equal(np.asarray(pos), np.asarray(pos_s))
    # Warn-once: the same reason does not warn again.
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        fa2.layout_sharded(edges, w, mass, n, cfg, make_stream_mesh())
    # mesh=None is the caller opting out — silent, no warning.
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pos_n, _, _ = fa2.layout_sharded(edges, w, mass, n, cfg, None)
    assert np.array_equal(np.asarray(pos), np.asarray(pos_n))


def test_layout_sharded_nonfloat32_grid_falls_back():
    """Regression: the sharded grid path computed in hardcoded float32
    whatever cfg.dtype asked for. It now refuses (warn + fall back to the
    single-device path, which keeps its cast-in/cast-out semantics) rather
    than silently produce a layout in the wrong precision."""
    edges = jnp.asarray(_graph()[:256])
    w = jnp.ones(edges.shape[0], jnp.float32)
    mass = jnp.zeros(N, jnp.float32).at[edges[:, 0]].add(1.0) + 1.0
    cfg = fa2.FA2Config(iterations=3, repulsion="grid", grid_size=8,
                        grid_window=8, dtype="bfloat16")
    mesh = make_stream_mesh()
    if mesh.size > 1:
        reason = fa2._sharded_fallback_reason(N, cfg, mesh)
        assert reason is not None and "float32" in reason
    fa2._FALLBACK_WARNED.clear()
    pos, trace, it = fa2.layout(edges, w, mass, N, cfg)
    with pytest.warns(UserWarning):
        pos_s, trace_s, it_s = fa2.layout_sharded(edges, w, mass, N, cfg, mesh)
    assert pos_s.dtype == jnp.bfloat16
    assert np.array_equal(
        np.asarray(pos, np.float32), np.asarray(pos_s, np.float32))
    # float32 grid keeps its sharded form (no reason to refuse).
    f32 = replace(cfg, dtype="float32")
    if mesh.size > 1:
        assert fa2._sharded_fallback_reason(N, f32, mesh) is None


def test_repulsion_chunked_rows_bitwise():
    """Row slices of the chunked j-scan are bitwise equal to the full run
    (the sharded layout's correctness rests on this; chunk 64 forces
    multiple j-chunks and a padded tail)."""
    rng = np.random.default_rng(0)
    n = 200
    pos = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    mass = jnp.asarray(rng.uniform(0.5, 2.0, size=n), jnp.float32)
    radii = jnp.asarray(rng.uniform(0.1, 1.0, size=n), jnp.float32)
    full = rep_ops.repulsion_chunked(pos, mass, 9.0, radii=radii, chunk=64)
    for i0, nl in ((0, 50), (50, 50), (150, 50), (64, 8)):
        part = rep_ops.repulsion_chunked_rows(
            pos, mass, i0, nl, 9.0, radii=radii, chunk=64)
        assert np.array_equal(np.asarray(full[i0:i0 + nl]), np.asarray(part))


def test_near_field_rows_bitwise():
    """Halo near field on row blocks == slicing the full banded near field."""
    rng = np.random.default_rng(1)
    n = 160
    pos = jnp.asarray(rng.uniform(-10, 10, size=(n, 2)), jnp.float32)
    mass = jnp.asarray(rng.uniform(0.5, 2.0, size=n), jnp.float32)
    cell, order = bin_and_sort(pos, 4)
    pos_s, mass_s, cell_s = pos[order], mass[order], cell[order]
    full = near_field_ref(pos_s, mass_s, cell_s, 7.0, 16)
    for i0, nl in ((0, 40), (40, 40), (120, 40), (8, 16)):
        part = near_field_rows(pos_s, mass_s, cell_s, 7.0, 16, i0, nl)
        assert np.array_equal(np.asarray(full[i0:i0 + nl]), np.asarray(part))


@multi_device
def test_runner_put_pads_non_divisible_chunks():
    """Regression: a chunk whose rows don't divide by the device count used
    to crash the sharded ``device_put``; it must now pad with the trash
    sentinel (after ``run`` set it) and still row-shard."""
    edges = _graph()
    cfg = _cfg(edges)
    mesh = make_stream_mesh()
    runner = StreamRunner(
        cfg, StreamRunnerConfig(shard_chunks=True), mesh=mesh)

    # Before any run there is no sentinel: fall back to replication.
    odd = np.asarray(edges[: mesh.size + 1], np.int32)
    arr = runner.put(odd)
    assert arr.shape == odd.shape
    assert np.array_equal(np.asarray(arr), odd)

    runner._trash = N  # what run() sets before streaming
    arr = runner.put(odd)
    assert arr.shape[0] % mesh.size == 0
    got = np.asarray(arr)
    assert np.array_equal(got[: len(odd)], odd)
    assert (got[len(odd):] == N).all()  # padding is all trash rows
    shard_rows = {s.data.shape[0] for s in arr.addressable_shards}
    assert shard_rows == {arr.shape[0] // mesh.size}  # evenly row-sharded

    # End to end: a chunk size indivisible by any multi-device count streams
    # through the padding path and yields a valid result. (Chunks-only
    # sharding is a placement mode: the auto-partitioned detect scatter may
    # break ties differently than one device, so unlike shard_detect it
    # does not promise bit-identity — see StreamRunner's docstring.)
    runner = StreamRunner(cfg, StreamRunnerConfig(
        stream=StreamConfig(chunk_size=255), shard_chunks=True), mesh=mesh)
    res = runner.run(edges, N)
    labels = np.asarray(res.labels)
    assert labels.shape == (N,) and (labels >= 0).all()
    assert res.n_supernodes > 0
    assert np.isfinite(res.modularity)


def test_multi_device_subprocess_bit_identity():
    """Force 4 host devices in a subprocess and check the sharded pipeline
    reproduces this process's single-device result bit for bit — real
    multi-device coverage even when the parent test run has one device."""
    edges = _graph()
    cfg = _cfg(edges)
    res = biggraphvis(edges, N, cfg, stream=StreamConfig(chunk_size=256))
    script = textwrap.dedent("""
        import json, sys
        import numpy as np
        from dataclasses import replace
        import jax
        from repro.core.pipeline import default_config
        from repro.core.stream import StreamConfig
        from repro.graph import mode_degree, planted_partition
        from repro.launch.mesh import make_stream_mesh
        from repro.launch.stream_runner import StreamRunner, StreamRunnerConfig

        assert jax.device_count() == 4, jax.device_count()
        N, COMMUNITIES = {n}, {communities}
        edges, _ = planted_partition(N, COMMUNITIES, 0.3, 0.002, seed=11)
        cfg = default_config(N, len(edges), mode_degree(edges, N),
                             rounds=2, iterations=5)
        cfg = replace(cfg, scoda=replace(cfg.scoda, block_size=128))
        runner = StreamRunner(cfg, StreamRunnerConfig(
            stream=StreamConfig(chunk_size=256, shard_detect=True,
                                shard_layout=True),
            shard_chunks=True,
        ), mesh=make_stream_mesh())
        res = runner.run(edges, N)
        assert res.stream.devices == 4, res.stream.devices
        json.dump({{
            "labels": np.asarray(res.labels).tolist(),
            "sg_edges": np.asarray(res.supergraph.edges).tolist(),
            "positions_bytes": np.asarray(res.positions).tobytes().hex(),
            "modularity": res.modularity,
        }}, sys.stdout)
    """).format(n=N, communities=COMMUNITIES)
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    got = __import__("json").loads(out.stdout)
    assert got["labels"] == np.asarray(res.labels).tolist()
    assert got["sg_edges"] == np.asarray(res.supergraph.edges).tolist()
    assert got["modularity"] == res.modularity
    assert got["positions_bytes"] == np.asarray(res.positions).tobytes().hex()
