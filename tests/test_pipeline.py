"""End-to-end BigGraphVis pipeline behaviour (replaces the scaffold
placeholder system test)."""
import os

import numpy as np
import pytest

from repro.core import biggraphvis, default_config, full_layout_colored, write_svg
from repro.graph import planted_partition, mode_degree


@pytest.fixture(scope="module")
def result():
    edges, _ = planted_partition(1500, 15, 0.25, 0.002, seed=11)
    n = 1500
    cfg = default_config(n, len(edges), mode_degree(edges, n), rounds=4,
                         iterations=40, s_cap=2048)
    return biggraphvis(edges, n, cfg), edges, n, cfg


def test_pipeline_outputs(result):
    res, edges, n, cfg = result
    assert 1 < res.n_supernodes < n
    assert res.n_superedges > 0
    assert np.isfinite(res.positions).all()
    assert res.labels.shape == (n,)
    assert (res.sizes >= 0).all()
    assert res.groups.shape == (cfg.s_cap,)


def test_pipeline_modularity_positive(result):
    """Paper Table 1: detected communities have meaningful modularity."""
    res, *_ = result
    assert res.modularity > 0.3


def test_live_supernodes_have_sizes(result):
    res, *_ = result
    live = res.sizes[: res.n_supernodes]
    assert (live > 0).mean() > 0.5  # most detected communities sized


def test_full_layout_colored(tmp_path):
    edges, _ = planted_partition(400, 8, 0.3, 0.01, seed=13)
    n = 400
    cfg = default_config(n, len(edges), mode_degree(edges, n), rounds=2,
                         iterations=10, s_cap=512)
    pos, groups = full_layout_colored(edges, n, cfg, iterations=10)
    assert pos.shape == (n, 2)
    assert np.isfinite(pos).all()
    assert groups.shape == (n,)
    path = os.path.join(tmp_path, "layout.svg")
    write_svg(path, pos, np.ones(n), groups)
    assert os.path.getsize(path) > 1000


def test_speedup_supergraph_vs_full():
    """The paper's headline claim, at CPU scale: laying out the supergraph
    is much cheaper than laying out the full graph (same iteration count
    economics — supergraph is ~100× smaller)."""
    edges, _ = planted_partition(1200, 12, 0.3, 0.002, seed=17)
    n = 1200
    cfg = default_config(n, len(edges), mode_degree(edges, n), rounds=4,
                         iterations=20, s_cap=1024)
    res = biggraphvis(edges, n, cfg)
    assert res.n_supernodes < n / 5  # real aggregation happened
