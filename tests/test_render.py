"""Streaming rasterizer contracts (repro/render): chunked rendering is
bit-identical to one-shot (the engine contract carried into the drawing
stage), sources are interchangeable, PNG I/O round-trips, the hybrid node
pass equals the dense kernel, and write_svg orientation + large-input
delegation behave per the spec."""
import os

import numpy as np
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro.core import biggraphvis, default_config, write_svg
from repro.data.edge_store import write_npy
from repro.graph import mode_degree, planted_partition
from repro.kernels.raster import ops as raster_ops
from repro.render import (
    RenderConfig,
    image_summary,
    read_png,
    render,
    render_arrays,
    write_png,
)
from repro.render.raster import _node_pass


def _scene(seed=1, n=400, e=8000):
    rng = np.random.default_rng(seed)
    pos = rng.normal(0, 100, (n, 2)).astype(np.float32)
    radii = rng.uniform(1, 8, n).astype(np.float32)
    radii[::9] = 0.0  # dead padding slots
    groups = rng.integers(0, 11, n).astype(np.int32)
    edges = rng.integers(0, n, (e, 2)).astype(np.int32)
    return pos, radii, groups, edges


CFG = RenderConfig(width=128, height=128, supersample=2, chunk_size=1024)


# ------------------------------------------------- chunked == one-shot
@pytest.mark.parametrize("chunk", [700, 1024, 4096])
def test_chunked_render_bit_identical_to_oneshot(chunk):
    pos, radii, groups, edges = _scene()
    one, st1 = render_arrays(
        pos, radii, groups, edges, cfg=replace(CFG, chunk_size=1 << 20)
    )
    assert st1.chunks == 1
    img, st = render_arrays(
        pos, radii, groups, edges, cfg=replace(CFG, chunk_size=chunk)
    )
    assert st.chunks > 1
    np.testing.assert_array_equal(img, one)


def test_chunked_render_weighted_bit_identical():
    pos, radii, groups, edges = _scene()
    w = np.random.default_rng(2).integers(1, 6, len(edges)).astype(np.float32)
    one, _ = render_arrays(
        pos, radii, groups, edges, edge_weights=w,
        cfg=replace(CFG, chunk_size=1 << 20),
    )
    img, _ = render_arrays(
        pos, radii, groups, edges, edge_weights=w,
        cfg=replace(CFG, chunk_size=777),
    )
    np.testing.assert_array_equal(img, one)
    # unit weights == no weights (the sorted unit-increment fast path)
    a, _ = render_arrays(pos, radii, groups, edges, cfg=CFG)
    b, _ = render_arrays(
        pos, radii, groups, edges,
        edge_weights=np.ones(len(edges), np.float32), cfg=CFG,
    )
    np.testing.assert_array_equal(a, b)


def test_disk_store_source_matches_memory(tmp_path):
    pos, radii, groups, edges = _scene()
    path = write_npy(tmp_path / "edges.npy", edges)
    a, _ = render_arrays(pos, radii, groups, edges, cfg=CFG)
    b, stats = render_arrays(pos, radii, groups, path, cfg=CFG)
    np.testing.assert_array_equal(a, b)
    assert stats.stream.chunks == stats.chunks


def test_render_residency_independent_of_edge_count():
    pos, radii, groups, edges = _scene()
    cfg = replace(CFG, draw_nodes=False)
    _, st1 = render_arrays(pos, radii, groups, edges, cfg=cfg)
    _, st4 = render_arrays(
        pos, radii, groups, np.tile(edges, (4, 1)), cfg=cfg
    )
    assert st1.peak_device_bytes == st4.peak_device_bytes
    assert st4.edges_streamed >= 4 * len(edges)


# ------------------------------------------------------- node/edge passes
def test_hybrid_node_pass_equals_dense_kernel():
    rng = np.random.default_rng(5)
    n, h, w = 300, 96, 80
    px = rng.uniform(-10, w + 10, n).astype(np.float32)
    py = rng.uniform(-10, h + 10, n).astype(np.float32)
    r = rng.uniform(0, 25, n).astype(np.float32)  # spans small + large
    r[::6] = 0.0
    g = rng.integers(0, 11, n).astype(np.int32)
    hyb = _node_pass(px, py, r, g, 11, h, w, "ref")
    dense = raster_ops.disk_accum(
        jnp.asarray(px), jnp.asarray(py), jnp.asarray(r), jnp.asarray(g),
        11, h, w, "ref",
    )
    np.testing.assert_array_equal(np.asarray(hyb), np.asarray(dense))


def test_all_padding_edge_chunks_draw_nothing():
    """A stream of pure trash edges (id n) must leave the image equal to
    the nodes-only render — the renderer's all-padding-chunk case."""
    pos, radii, groups, _ = _scene()
    n = len(pos)
    trash = np.full((3000, 2), n, np.int32)
    base, _ = render_arrays(pos, radii, groups, None, cfg=CFG)
    img, stats = render_arrays(pos, radii, groups, trash, cfg=CFG)
    np.testing.assert_array_equal(img, base)
    assert stats.chunks >= 1


def test_offscreen_edge_samples_dropped_not_clamped():
    """Edges leaving the viewport (fitted to alive nodes only) must drop
    their out-of-image samples, not clamp them onto border pixels."""
    pos = np.array([[0.0, 0.0], [10.0, 0.0], [1000.0, 1000.0]], np.float32)
    radii = np.array([0.01, 0.01, 0.0], np.float32)  # third node is dead
    groups = np.array([3, 4, 5], np.int32)
    edges = np.array([[0, 2], [1, 2]], np.int32)  # both point off-viewport
    img, _ = render_arrays(pos, radii, groups, edges, cfg=CFG)
    base, _ = render_arrays(pos, radii, groups, None, cfg=CFG)
    # every sample of both edges lies outside the viewport: the edge pass
    # must contribute nothing, and in particular no border streaks
    np.testing.assert_array_equal(img, base)
    border = np.concatenate(
        [img[0], img[-1], img[:, 0], img[:, -1]]
    ).reshape(-1, 3)
    assert (border == 255).all(), "off-image edge samples smeared the border"


def test_zero_extent_layout_renders():
    """Collapsed layout (every node at one point) must not NaN — nodes
    land on the image center."""
    n = 50
    pos = np.zeros((n, 2), np.float32)
    radii = np.ones(n, np.float32)
    groups = np.arange(n, dtype=np.int32) % 11
    edges = np.stack([np.arange(n), (np.arange(n) + 1) % n], 1).astype(np.int32)
    img, _ = render_arrays(pos, radii, groups, edges, cfg=CFG)
    assert not np.array_equal(img, np.full_like(img, 255))
    h, w = img.shape[:2]
    assert (img[h // 2 - 2 : h // 2 + 2, w // 2 - 2 : w // 2 + 2] != 255).any()


def test_render_content_and_summary():
    pos, radii, groups, edges = _scene(n=600, e=12000)
    img, _ = render_arrays(
        pos, radii, groups, edges, cfg=replace(CFG, width=256, height=256)
    )
    frac, counts = image_summary(img)
    assert frac > 0.01
    assert (counts > 20).sum() >= 3  # several distinct palette colors


def test_empty_scene_is_background():
    pos = np.zeros((4, 2), np.float32)
    img, stats = render_arrays(
        pos, np.zeros(4, np.float32), np.zeros(4, np.int32), None, cfg=CFG
    )
    assert (img == 255).all()
    assert stats.nodes_drawn == 0


# ------------------------------------------------------------------ PNG I/O
def test_png_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    img = rng.integers(0, 256, (37, 53, 3)).astype(np.uint8)
    path = str(tmp_path / "t.png")
    write_png(path, img)
    np.testing.assert_array_equal(read_png(path), img)


def test_png_rejects_bad_inputs(tmp_path):
    with pytest.raises(ValueError, match="uint8"):
        write_png(str(tmp_path / "x.png"), np.zeros((4, 4, 3), np.float32))
    bad = tmp_path / "bad.png"
    bad.write_bytes(b"not a png at all")
    with pytest.raises(ValueError, match="not a PNG"):
        read_png(str(bad))


# ----------------------------------------------------------- pipeline wiring
def test_render_result_and_biggraphvis_wiring(tmp_path):
    n = 600
    edges, _ = planted_partition(n, 12, 0.3, 0.002, seed=7)
    cfg = default_config(n, len(edges), mode_degree(edges, n),
                         rounds=2, iterations=10, s_cap=256)
    out = str(tmp_path / "sg.png")
    res = biggraphvis(edges, n, cfg, render_path=out,
                      render_cfg=RenderConfig(width=96, height=96))
    assert os.path.exists(out)
    assert res.timings["render_s"] > 0
    img = read_png(out)
    assert img.shape == (96, 96, 3)
    # direct render() of the same result is deterministic
    img2, stats = render(res, cfg=RenderConfig(width=96, height=96))
    np.testing.assert_array_equal(img2, img)
    assert stats.nodes_drawn == res.n_supernodes


# ------------------------------------------------------------------ write_svg
def test_write_svg_y_axis_not_mirrored(tmp_path):
    """World y-up must map to SVG y-down: the higher-y node gets the
    smaller cy coordinate."""
    pos = np.array([[0.0, 0.0], [0.0, 100.0]], np.float32)  # low, high
    path = str(tmp_path / "o.svg")
    out = write_svg(path, pos, np.ones(2), np.array([1, 2]))
    assert out == path
    svg = open(path).read()
    circles = [ln for ln in svg.splitlines() if ln.startswith("<circle")]
    cy = [float(c.split('cy="')[1].split('"')[0]) for c in circles]
    assert cy[1] < cy[0], f"high-y node should draw above low-y node: {cy}"


def test_write_svg_delegates_large_inputs_to_renderer(tmp_path):
    pos, radii, groups, edges = _scene(n=50, e=500)
    radii = np.maximum(radii, 1.0)
    path = str(tmp_path / "big.svg")
    out = write_svg(path, pos, radii, groups, edges=edges, max_nodes=10)
    assert out.endswith(".png") and os.path.exists(out)
    img = read_png(out)
    frac, _ = image_summary(img)
    assert frac > 0.001
