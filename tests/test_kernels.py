"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes per the deliverable spec."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import cms as cms_lib
from repro.kernels.cms.cms_update import cms_update_pallas
from repro.kernels.cms.ref import cms_update_ref
from repro.kernels.cms import ops as cms_ops
from repro.kernels.repulsion.nbody import repulsion_pallas
from repro.kernels.repulsion.ref import repulsion_ref
from repro.kernels.repulsion import ops as rep_ops
from repro.kernels.segment.seg_matmul import segment_sum_pallas
from repro.kernels.segment.ref import segment_sum_ref
from repro.kernels.segment import ops as seg_ops


# ---------------------------------------------------------------- repulsion
@pytest.mark.parametrize("n,tile", [(128, 128), (256, 128), (512, 256), (1024, 512)])
@pytest.mark.parametrize("use_radii", [True, False])
def test_repulsion_kernel_vs_ref(n, tile, use_radii):
    rng = np.random.default_rng(n + use_radii)
    pos = jnp.asarray(rng.uniform(-100, 100, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 4.0, n).astype(np.float32))
    radii = jnp.asarray(rng.uniform(0.0, 2.0, n).astype(np.float32))
    got = repulsion_pallas(
        pos, mass, radii, kr=80.0, ti=tile, tj=tile, use_radii=use_radii, interpret=True
    )
    want = repulsion_ref(pos, mass, 80.0, radii=radii if use_radii else None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-3)


def test_repulsion_ops_backends_agree():
    rng = np.random.default_rng(3)
    n = 300  # deliberately not tile-aligned: exercises padding
    pos = jnp.asarray(rng.uniform(-10, 10, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    f_ref = rep_ops.repulsion(pos, mass, 80.0, backend="ref")
    f_chk = rep_ops.repulsion(pos, mass, 80.0, backend="chunked")
    f_pal = rep_ops.repulsion(pos, mass, 80.0, backend="interpret", tile=128)
    np.testing.assert_allclose(np.asarray(f_chk), np.asarray(f_ref), rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_ref), rtol=2e-4, atol=1e-3)


def test_repulsion_padding_neutral():
    """mass-0 padding must not change forces on real nodes."""
    rng = np.random.default_rng(5)
    n = 200
    pos = jnp.asarray(rng.uniform(-10, 10, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    f1 = rep_ops.repulsion(pos, mass, 80.0, backend="interpret", tile=128)
    pos_p = jnp.concatenate([pos, jnp.zeros((56, 2), jnp.float32)])
    mass_p = jnp.concatenate([mass, jnp.zeros(56, jnp.float32)])
    f2 = rep_ops.repulsion(pos_p, mass_p, 80.0, backend="interpret", tile=128)[:n]
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5)


# ---------------------------------------------------------------------- CMS
@pytest.mark.parametrize("rows,cols,n,blk", [(1, 128, 700, 256), (4, 512, 2000, 1024), (4, 5000, 4096, 1024)])
def test_cms_kernel_vs_ref(rows, cols, n, blk):
    rng = np.random.default_rng(rows * cols)
    h = jnp.asarray(rng.integers(0, cols, (rows, n)).astype(np.int32))
    w = jnp.asarray(rng.uniform(0, 3, n).astype(np.float32))
    sketch = jnp.asarray(rng.uniform(0, 1, (rows, cols)).astype(np.float32))
    got = cms_update_pallas(sketch, h, w, cols, blk=blk, interpret=True)
    want = cms_update_ref(sketch, h, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_cms_kernel_padding_mask():
    cols, n = 64, 100
    h = jnp.asarray(np.full((4, n), 7, np.int32))
    h = h.at[:, 50:].set(-1)  # padding
    w = jnp.ones(n, jnp.float32)
    sketch = jnp.zeros((4, cols), jnp.float32)
    got = cms_update_pallas(sketch, h, w, cols, blk=64, interpret=True)
    assert float(got[0, 7]) == 50.0


def test_cms_ops_matches_core_cms():
    """kernels/cms/ops must agree with core/cms.update (same hash family)."""
    rng = np.random.default_rng(11)
    cfg = cms_lib.CMSConfig(rows=4, cols=256, seed=3)
    keys = jnp.asarray(rng.integers(0, 100, 500).astype(np.int32))
    w = jnp.asarray(rng.uniform(0, 2, 500).astype(np.float32))
    s0 = cms_lib.init_sketch(cfg)
    want = cms_lib.update(s0, keys, w, cfg)
    got = cms_ops.update(s0, keys, w, cfg, backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------- segment sum
@pytest.mark.parametrize("e,d,n,tn,blk", [
    (500, 8, 100, 128, 256),
    (2048, 64, 300, 256, 512),
    (1000, 128, 1000, 256, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum_kernel_vs_ref(e, d, n, tn, blk, dtype):
    rng = np.random.default_rng(e + d)
    data = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32)).astype(dtype)
    seg = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    got = segment_sum_pallas(data, seg, n, tn=tn, blk=blk, interpret=True)
    want = segment_sum_ref(data.astype(jnp.float32), seg, n)
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=rtol, atol=1e-2
    )


def test_segment_sum_drops_out_of_range():
    data = jnp.ones((10, 4), jnp.float32)
    seg = jnp.asarray([0, 1, 2, 99, -1, 0, 1, 2, 99, -1], jnp.int32)
    got = segment_sum_pallas(data, seg, 3, tn=128, blk=128, interpret=True)
    want = segment_sum_ref(data, seg, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    assert float(got.sum()) == 6 * 4  # 6 in-range rows


def test_segment_ops_wrapper():
    rng = np.random.default_rng(21)
    data = jnp.asarray(rng.standard_normal((256, 16)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, 50, 256).astype(np.int32))
    a = seg_ops.segment_sum(data, seg, 50, backend="ref")
    b = seg_ops.segment_sum(data, seg, 50, backend="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
