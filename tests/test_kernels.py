"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes per the deliverable spec."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import cms as cms_lib
from repro.kernels.cms.cms_update import cms_update_pallas
from repro.kernels.cms.ref import cms_update_ref
from repro.kernels.cms import ops as cms_ops
from repro.kernels.repulsion.nbody import repulsion_pallas
from repro.kernels.repulsion.ref import repulsion_ref
from repro.kernels.repulsion import ops as rep_ops
from repro.kernels.segment.seg_matmul import segment_sum_pallas
from repro.kernels.segment.ref import segment_sum_ref
from repro.kernels.segment import ops as seg_ops
from repro.kernels.merge.ref import merge_combine_ref
from repro.kernels.merge.sorted_merge import merge_combine_pallas
from repro.kernels.merge import ops as merge_ops
from repro.kernels.raster.ref import (
    count_scatter_into_ref,
    count_scatter_ref,
    disk_accum_ref,
)
from repro.kernels.raster.splat import count_scatter_pallas, disk_accum_pallas
from repro.kernels.raster import ops as raster_ops
from repro.kernels.grid import ref as grid_ref
from repro.kernels.grid.tiled import far_field_pallas, near_field_pallas
from repro.kernels.grid import ops as grid_ops

INT32_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------- repulsion
@pytest.mark.parametrize("n,tile", [(128, 128), (256, 128), (512, 256), (1024, 512)])
@pytest.mark.parametrize("use_radii", [True, False])
def test_repulsion_kernel_vs_ref(n, tile, use_radii):
    rng = np.random.default_rng(n + use_radii)
    pos = jnp.asarray(rng.uniform(-100, 100, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 4.0, n).astype(np.float32))
    radii = jnp.asarray(rng.uniform(0.0, 2.0, n).astype(np.float32))
    got = repulsion_pallas(
        pos, mass, radii, kr=80.0, ti=tile, tj=tile, use_radii=use_radii, interpret=True
    )
    want = repulsion_ref(pos, mass, 80.0, radii=radii if use_radii else None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-3)


def test_repulsion_ops_backends_agree():
    rng = np.random.default_rng(3)
    n = 300  # deliberately not tile-aligned: exercises padding
    pos = jnp.asarray(rng.uniform(-10, 10, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    f_ref = rep_ops.repulsion(pos, mass, 80.0, backend="ref")
    f_chk = rep_ops.repulsion(pos, mass, 80.0, backend="chunked")
    f_pal = rep_ops.repulsion(pos, mass, 80.0, backend="interpret", tile=128)
    np.testing.assert_allclose(np.asarray(f_chk), np.asarray(f_ref), rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_ref), rtol=2e-4, atol=1e-3)


def test_repulsion_padding_neutral():
    """mass-0 padding must not change forces on real nodes."""
    rng = np.random.default_rng(5)
    n = 200
    pos = jnp.asarray(rng.uniform(-10, 10, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    f1 = rep_ops.repulsion(pos, mass, 80.0, backend="interpret", tile=128)
    pos_p = jnp.concatenate([pos, jnp.zeros((56, 2), jnp.float32)])
    mass_p = jnp.concatenate([mass, jnp.zeros(56, jnp.float32)])
    f2 = rep_ops.repulsion(pos_p, mass_p, 80.0, backend="interpret", tile=128)[:n]
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5)


# ---------------------------------------------------------------------- CMS
@pytest.mark.parametrize("rows,cols,n,blk", [(1, 128, 700, 256), (4, 512, 2000, 1024), (4, 5000, 4096, 1024)])
def test_cms_kernel_vs_ref(rows, cols, n, blk):
    rng = np.random.default_rng(rows * cols)
    h = jnp.asarray(rng.integers(0, cols, (rows, n)).astype(np.int32))
    w = jnp.asarray(rng.uniform(0, 3, n).astype(np.float32))
    sketch = jnp.asarray(rng.uniform(0, 1, (rows, cols)).astype(np.float32))
    got = cms_update_pallas(sketch, h, w, cols, blk=blk, interpret=True)
    want = cms_update_ref(sketch, h, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_cms_kernel_padding_mask():
    cols, n = 64, 100
    h = jnp.asarray(np.full((4, n), 7, np.int32))
    h = h.at[:, 50:].set(-1)  # padding
    w = jnp.ones(n, jnp.float32)
    sketch = jnp.zeros((4, cols), jnp.float32)
    got = cms_update_pallas(sketch, h, w, cols, blk=64, interpret=True)
    assert float(got[0, 7]) == 50.0


def test_cms_ops_matches_core_cms():
    """kernels/cms/ops must agree with core/cms.update (same hash family)."""
    rng = np.random.default_rng(11)
    cfg = cms_lib.CMSConfig(rows=4, cols=256, seed=3)
    keys = jnp.asarray(rng.integers(0, 100, 500).astype(np.int32))
    w = jnp.asarray(rng.uniform(0, 2, 500).astype(np.float32))
    s0 = cms_lib.init_sketch(cfg)
    want = cms_lib.update(s0, keys, w, cfg)
    got = cms_ops.update(s0, keys, w, cfg, backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------- segment sum
@pytest.mark.parametrize("e,d,n,tn,blk", [
    (500, 8, 100, 128, 256),
    (2048, 64, 300, 256, 512),
    (1000, 128, 1000, 256, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum_kernel_vs_ref(e, d, n, tn, blk, dtype):
    rng = np.random.default_rng(e + d)
    data = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32)).astype(dtype)
    seg = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    got = segment_sum_pallas(data, seg, n, tn=tn, blk=blk, interpret=True)
    want = segment_sum_ref(data.astype(jnp.float32), seg, n)
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=rtol, atol=1e-2
    )


def test_segment_sum_drops_out_of_range():
    data = jnp.ones((10, 4), jnp.float32)
    seg = jnp.asarray([0, 1, 2, 99, -1, 0, 1, 2, 99, -1], jnp.int32)
    got = segment_sum_pallas(data, seg, 3, tn=128, blk=128, interpret=True)
    want = segment_sum_ref(data, seg, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    assert float(got.sum()) == 6 * 4  # 6 in-range rows


def test_segment_ops_wrapper():
    rng = np.random.default_rng(21)
    data = jnp.asarray(rng.standard_normal((256, 16)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, 50, 256).astype(np.int32))
    a = seg_ops.segment_sum(data, seg, 50, backend="ref")
    b = seg_ops.segment_sum(data, seg, 50, backend="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_segment_sum_sorted_flag():
    """The ``indices_are_sorted`` fast path (FA2 attraction / grid stats)
    matches the unsorted path on sorted ids, incl. out-of-range tails."""
    rng = np.random.default_rng(13)
    data = jnp.asarray(rng.standard_normal((512, 3)).astype(np.float32))
    seg = jnp.asarray(np.sort(rng.integers(0, 80, 512)).astype(np.int32))
    seg = seg.at[-20:].set(80)  # trash tail sorts last, must be dropped
    a = seg_ops.segment_sum(data, seg, 80, backend="ref")
    b = seg_ops.segment_sum(data, seg, 80, backend="ref",
                            indices_are_sorted=True)
    c = seg_ops.segment_sum(data, seg, 80, backend="interpret",
                            indices_are_sorted=True)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ grid fields
@pytest.mark.parametrize("n,g,ti,tc", [
    (300, 8, 128, 128),   # C=64 < one cell tile
    (1000, 16, 256, 128),  # padding on both axes
    (512, 32, 256, 256),   # n < C
])
def test_grid_far_field_kernel_vs_ref(n, g, ti, tc):
    rng = np.random.default_rng(n + g)
    pos = jnp.asarray(rng.uniform(-300, 300, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 4.0, n).astype(np.float32))
    cell, order = grid_ref.bin_and_sort(pos, g)
    ccent, cmass = grid_ops.cell_stats(pos[order], mass[order], cell[order],
                                       g * g, backend="ref")
    want = grid_ref.far_field_ref(pos, mass, cell, ccent, cmass, 80.0)
    got = far_field_pallas(pos, mass, cell, ccent, cmass, 80.0,
                           ti=ti, tc=tc, interpret=True)
    scale = float(np.abs(np.asarray(want)).max())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4 * max(scale, 1.0))


@pytest.mark.parametrize("n,g,window,ti", [
    (300, 8, 16, 128),
    (700, 4, 64, 128),   # heavy cells, window spilling into neighbor tiles
    (256, 16, 0, 128),   # empty band
    (100, 1, 256, 128),  # window > n: ti is raised to cover it
])
def test_grid_near_field_kernel_vs_ref(n, g, window, ti):
    rng = np.random.default_rng(n + window)
    pos = jnp.asarray(rng.uniform(-300, 300, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 4.0, n).astype(np.float32))
    cell, order = grid_ref.bin_and_sort(pos, g)
    pos_s, mass_s, cell_s = pos[order], mass[order], cell[order]
    want = grid_ref.near_field_ref(pos_s, mass_s, cell_s, 80.0, window)
    got = near_field_pallas(pos_s, mass_s, cell_s, 80.0, window,
                            ti=ti, interpret=True)
    scale = float(np.abs(np.asarray(want)).max())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4 * max(scale, 1.0))


def test_grid_ops_padding_neutral():
    """mass-0 padding must not change grid forces on real nodes."""
    rng = np.random.default_rng(17)
    n = 200
    pos = jnp.asarray(rng.uniform(-50, 50, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    cell, order = grid_ref.bin_and_sort(pos, 8)
    ccent, cmass = grid_ops.cell_stats(pos[order], mass[order], cell[order],
                                       64, backend="ref")
    f1 = grid_ref.far_field_ref(pos, mass, cell, ccent, cmass, 80.0)
    pos_p = jnp.concatenate([pos, jnp.zeros((56, 2), jnp.float32)])
    mass_p = jnp.concatenate([mass, jnp.zeros(56, jnp.float32)])
    cell_p = jnp.concatenate([cell, jnp.full(56, -1, jnp.int32)])
    f2 = grid_ref.far_field_ref(pos_p, mass_p, cell_p, ccent, cmass, 80.0)[:n]
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5)


# ---------------------------------------------------- sorted-merge-combine

def _sorted_run(pairs: dict, size: int, s_cap: int):
    """{(a, b): w} → (a [size], b [size], w [size]) sorted, trash-padded."""
    items = sorted(pairs.items())
    assert len(items) <= size
    a = np.full(size, s_cap, np.int32)
    b = np.full(size, s_cap, np.int32)
    w = np.zeros(size, np.float32)
    for i, ((x, y), ww) in enumerate(items):
        a[i], b[i], w[i] = x, y, ww
    return jnp.asarray(a), jnp.asarray(b), jnp.asarray(w)


def _rand_pairs(rng, k: int, s_cap: int, max_w: int = 5) -> dict:
    pairs = {}
    while len(pairs) < k:
        x, y = sorted(rng.choice(s_cap, size=2, replace=False))
        pairs[(int(x), int(y))] = float(rng.integers(1, max_w + 1))
    return pairs


def _merge_oracle(state: dict, chunk: dict, cap: int):
    union = dict(state)
    for p, w in chunk.items():
        union[p] = union.get(p, 0) + w
    kept = dict(sorted(union.items())[:cap])
    return kept, len(union)


def _assert_merge_outputs_equal(got, want, label=""):
    for x, y in zip(got, want):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=label)


@pytest.mark.parametrize("cap,c,ks,kc,tn,blk", [
    (64, 16, 20, 10, 64, 64),
    (256, 64, 200, 64, 64, 128),
    (100, 24, 77, 20, 32, 64),  # cap/C not tile-aligned: exercises padding
])
def test_merge_kernel_vs_ref(cap, c, ks, kc, tn, blk):
    rng = np.random.default_rng(cap + c)
    s_cap = 32
    state = _rand_pairs(rng, ks, s_cap)
    chunk = _rand_pairs(rng, kc, s_cap)
    sa, sb, sw = _sorted_run(state, cap, s_cap)
    ca, cb, cw = _sorted_run(chunk, c, s_cap)
    want = merge_combine_ref(sa, sb, sw, ca, cb, cw, s_cap)
    got = merge_combine_pallas(
        sa, sb, sw, ca, cb, cw, s_cap, tn=tn, blk=blk, interpret=True
    )
    _assert_merge_outputs_equal(got, want)
    # and both match the python oracle
    kept, n = _merge_oracle(state, chunk, cap)
    oa, ob, ow, n_out = want
    assert int(n_out) == n
    got_pairs = {
        (int(a), int(b)): float(w)
        for a, b, w in zip(np.asarray(oa), np.asarray(ob), np.asarray(ow))
        if a < s_cap
    }
    assert got_pairs == kept


@pytest.mark.parametrize("case", [
    "empty_chunk", "all_duplicate", "all_padding_state_too",
    "state_at_capacity", "chunk_below_state", "chunk_above_state",
])
def test_merge_kernel_adversarial(case):
    """Pallas-interpret vs ref on the contract's edge cases."""
    rng = np.random.default_rng(7)
    s_cap, cap, c = 16, 32, 16
    state = _rand_pairs(rng, 12, s_cap)
    if case == "empty_chunk":
        chunk = {}
    elif case == "all_duplicate":
        chunk = {p: 1.0 for p in list(state)[:c]}  # every pair already held
    elif case == "all_padding_state_too":
        state, chunk = {}, {}
    elif case == "state_at_capacity":
        state = _rand_pairs(rng, cap, s_cap)  # no free slot: pure overflow
        chunk = _rand_pairs(rng, c, s_cap)
    elif case == "chunk_below_state":
        state = {(8, j): 1.0 for j in range(9, 16)}
        chunk = {(0, j): 2.0 for j in range(1, 8)}  # all keys sort first
    else:  # chunk_above_state
        state = {(0, j): 1.0 for j in range(1, 8)}
        chunk = {(8, j): 2.0 for j in range(9, 16)}
    sa, sb, sw = _sorted_run(state, cap, s_cap)
    ca, cb, cw = _sorted_run(chunk, c, s_cap)
    want = merge_combine_ref(sa, sb, sw, ca, cb, cw, s_cap)
    got = merge_combine_pallas(
        sa, sb, sw, ca, cb, cw, s_cap, tn=32, blk=32, interpret=True
    )
    _assert_merge_outputs_equal(got, want, case)
    kept, n = _merge_oracle(state, chunk, cap)
    assert int(want[3]) == n
    oa, ow = np.asarray(want[0]), np.asarray(want[2])
    assert ((oa < s_cap) == (np.arange(cap) < len(kept))).all()
    want_w = np.array([w for _, w in sorted(kept.items())], np.float32)
    np.testing.assert_array_equal(ow[: len(kept)], want_w)


def test_merge_ops_wrapper():
    rng = np.random.default_rng(3)
    s_cap, cap, c = 64, 128, 32
    sa, sb, sw = _sorted_run(_rand_pairs(rng, 90, s_cap), cap, s_cap)
    ca, cb, cw = _sorted_run(_rand_pairs(rng, 25, s_cap), c, s_cap)
    a = merge_ops.merge_combine(sa, sb, sw, ca, cb, cw, s_cap, backend="ref")
    b = merge_ops.merge_combine(sa, sb, sw, ca, cb, cw, s_cap, backend="interpret")
    _assert_merge_outputs_equal(a, b)


def test_merge_s_cap_at_packing_limit():
    """s_cap = 2^16 (the BGVConfig default): packed uint32 keys brush the
    sentinel — pairs near (s_cap-2, s_cap-1) must still merge exactly."""
    s_cap, cap, c = 1 << 16, 16, 8
    top = s_cap - 1
    state = {(0, 1): 1.0, (top - 1, top): 2.0}
    chunk = {(0, 1): 1.0, (top - 2, top): 3.0, (top - 1, top): 1.0}
    sa, sb, sw = _sorted_run(state, cap, s_cap)
    ca, cb, cw = _sorted_run(chunk, c, s_cap)
    want = merge_combine_ref(sa, sb, sw, ca, cb, cw, s_cap)
    got = merge_combine_pallas(
        sa, sb, sw, ca, cb, cw, s_cap, tn=32, blk=32, interpret=True
    )
    _assert_merge_outputs_equal(got, want, "s_cap at packing limit")
    kept, n = _merge_oracle(state, chunk, cap)
    oa, ob, ow, n_out = want
    assert int(n_out) == n == 3
    got_pairs = {
        (int(a), int(b)): float(w)
        for a, b, w in zip(np.asarray(oa), np.asarray(ob), np.asarray(ow))
        if a < s_cap
    }
    assert got_pairs == kept


def test_merge_rejects_oversized_s_cap():
    """The packed uint32 pair keys only cover s_cap ≤ 2^16."""
    z = jnp.zeros(8, jnp.int32)
    with pytest.raises(ValueError, match="s_cap"):
        merge_combine_ref(z, z, z.astype(jnp.float32), z, z,
                          z.astype(jnp.float32), (1 << 16) + 1)


# -------------------------------------------------------------------- raster
@pytest.mark.parametrize("n,size,tn,blk", [
    (500, 300, 64, 128),
    (2048, 4096, 512, 512),
    (777, 1000, 128, 256),  # neither tile- nor block-aligned
])
def test_count_scatter_kernel_vs_ref(n, size, tn, blk):
    rng = np.random.default_rng(n + size)
    pos = rng.integers(0, size, n).astype(np.int32)
    pos[::7] = INT32_MAX  # dropped-sample marker (padding chunks)
    pos[::11] = size + 3  # out of range
    inc = rng.integers(1, 6, n).astype(np.int32)
    want = count_scatter_ref(jnp.asarray(pos), jnp.asarray(inc), size)
    got = count_scatter_pallas(
        jnp.asarray(pos), jnp.asarray(inc), size, tn=tn, blk=blk, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("case", ["one_pixel", "all_padding", "negatives"])
def test_count_scatter_adversarial(case):
    """Edge-splat contract edge cases: every sample in one pixel (dense
    single-cell collision), an empty / all-padding chunk (every position
    is the dropped marker), and negative positions (must drop, not wrap)."""
    n, size = 640, 256
    rng = np.random.default_rng(3)
    inc = rng.integers(1, 4, n).astype(np.int32)
    if case == "one_pixel":
        pos = np.full(n, 77, np.int32)
    elif case == "all_padding":
        pos = np.full(n, INT32_MAX, np.int32)
    else:
        pos = rng.integers(-5, size, n).astype(np.int32)
    want = count_scatter_ref(jnp.asarray(pos), jnp.asarray(inc), size)
    got = count_scatter_pallas(
        jnp.asarray(pos), jnp.asarray(inc), size, tn=64, blk=128, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if case == "one_pixel":
        assert int(want[77]) == int(inc.sum())
    if case == "all_padding":
        assert int(np.asarray(want).sum()) == 0


def test_count_scatter_into_matches_fresh():
    """The accumulating form (weighted and unit-increment sorted path)
    equals fresh-buffer scatter + add."""
    rng = np.random.default_rng(9)
    size, n = 500, 1200
    pos = rng.integers(-2, size + 2, n).astype(np.int32)
    inc = rng.integers(1, 5, n).astype(np.int32)
    base = jnp.asarray(rng.integers(0, 3, size).astype(np.int32))
    got_w = count_scatter_into_ref(base, jnp.asarray(pos), jnp.asarray(inc))
    want_w = base + count_scatter_ref(jnp.asarray(pos), jnp.asarray(inc), size)
    np.testing.assert_array_equal(np.asarray(got_w), np.asarray(want_w))
    got_1 = count_scatter_into_ref(base, jnp.asarray(pos), None)
    want_1 = base + count_scatter_ref(
        jnp.asarray(pos), jnp.ones(n, jnp.int32), size
    )
    np.testing.assert_array_equal(np.asarray(got_1), np.asarray(want_1))


@pytest.mark.parametrize("n,h,w,tp,blk", [
    (64, 32, 32, 128, 64),
    (300, 60, 100, 256, 128),  # h*w not tile-aligned, n not block-aligned
])
def test_disk_accum_kernel_vs_ref(n, h, w, tp, blk):
    rng = np.random.default_rng(n + h)
    cx = jnp.asarray(rng.uniform(-10, w + 10, n).astype(np.float32))
    cy = jnp.asarray(rng.uniform(-10, h + 10, n).astype(np.float32))
    r = jnp.asarray(rng.uniform(-2, 12, n).astype(np.float32))
    g = jnp.asarray(rng.integers(-2, 13, n).astype(np.int32))
    want = disk_accum_ref(cx, cy, r, g, 11, h, w)
    got = disk_accum_pallas(cx, cy, r, g, 11, h, w, tp=tp, blk=blk, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("case", ["one_pixel", "zero_extent", "all_dead"])
def test_disk_accum_adversarial(case):
    """All nodes stacked in one pixel, a degenerate zero-extent layout
    (every center identical — what a collapsed FA2 run produces), and an
    all-dead scene (r ≤ 0 everywhere, the s_cap padding regime)."""
    n, h, w = 96, 24, 40
    rng = np.random.default_rng(11)
    g = jnp.asarray(rng.integers(0, 11, n).astype(np.int32))
    if case == "one_pixel":
        # center 0.447px from pixel (13, 7), ≥ 0.632px from every other:
        # r ∈ (0.5, 0.6) ⇒ every disk covers exactly that one pixel.
        cx = jnp.full(n, 13.4, jnp.float32)
        cy = jnp.full(n, 7.2, jnp.float32)
        r = jnp.asarray(rng.uniform(0.5, 0.6, n).astype(np.float32))
    elif case == "zero_extent":
        cx = jnp.full(n, 20.0, jnp.float32)
        cy = jnp.full(n, 12.0, jnp.float32)
        r = jnp.asarray(rng.uniform(0.0, 6.0, n).astype(np.float32))
    else:
        cx = jnp.asarray(rng.uniform(0, w, n).astype(np.float32))
        cy = jnp.asarray(rng.uniform(0, h, n).astype(np.float32))
        r = jnp.asarray(-rng.uniform(0, 2, n).astype(np.float32))
    want = disk_accum_ref(cx, cy, r, g, 11, h, w)
    got = disk_accum_pallas(cx, cy, r, g, 11, h, w, tp=128, blk=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if case == "one_pixel":
        assert int(np.asarray(want)[:, 7, 13].sum()) == n
        assert int(np.asarray(want).sum()) == n
    if case == "all_dead":
        assert int(np.asarray(want).sum()) == 0


def test_raster_ops_wrappers():
    rng = np.random.default_rng(21)
    pos = jnp.asarray(rng.integers(0, 200, 600).astype(np.int32))
    inc = jnp.asarray(rng.integers(1, 3, 600).astype(np.int32))
    a = raster_ops.count_scatter(pos, inc, 200, backend="ref")
    b = raster_ops.count_scatter(pos, inc, 200, backend="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # accumulating form: the aliased-in-place pallas path == ref
    base = jnp.asarray(rng.integers(0, 4, 200).astype(np.int32))
    for weights in (inc, None):
        ia = raster_ops.count_scatter_into(base, pos, weights, backend="ref")
        ib = raster_ops.count_scatter_into(
            base, pos, weights, backend="interpret"
        )
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    n = 80
    cx = jnp.asarray(rng.uniform(0, 50, n).astype(np.float32))
    cy = jnp.asarray(rng.uniform(0, 30, n).astype(np.float32))
    r = jnp.asarray(rng.uniform(0, 5, n).astype(np.float32))
    g = jnp.asarray(rng.integers(0, 11, n).astype(np.int32))
    da = raster_ops.disk_accum(cx, cy, r, g, 11, 30, 50, backend="ref")
    db = raster_ops.disk_accum(cx, cy, r, g, 11, 30, 50, backend="interpret")
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))
