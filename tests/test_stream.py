"""Streaming chunked-edge engine: chunked init/update/finalize over K chunks
must match the one-shot SCoDA/CMS/supergraph results bit-for-bit, including
with chunk size ≪ |E| (multi-pass streaming)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import biggraphvis, default_config
from repro.core.modularity import (
    modularity,
    modularity_finalize,
    modularity_init,
    modularity_update,
)
from repro.core.scoda import ScodaConfig, detect_communities, dense_labels
from repro.core.stream import (
    EdgeChunkStream,
    StreamConfig,
    oneshot_device_bytes,
    stream_detect,
    stream_pipeline,
    stream_supergraph,
)
from repro.core.supergraph import (
    agg_finalize,
    agg_init,
    agg_update,
    aggregate_edges,
    build_supergraph,
)
from repro.graph import mode_degree, pad_edges, planted_partition
from repro.graph.utils import degrees


@pytest.fixture(scope="module")
def graph():
    edges, _ = planted_partition(300, 6, 0.25, 0.005, seed=7)
    return edges, 300


def _scoda_cfg(edges, n, block_size=64, rounds=4):
    dt = max(2, mode_degree(edges, n))
    return ScodaConfig(degree_threshold=dt, rounds=rounds, block_size=block_size)


# ------------------------------------------------------- EdgeChunkStream unit


def test_chunk_stream_shapes_and_padding(graph):
    edges, n = graph
    st = EdgeChunkStream(edges, n, 100, block_size=64)
    assert st.chunk_size == 128  # rounded up to a block_size multiple
    chunks = list(st)
    assert len(chunks) == st.n_chunks == -(-len(edges) // 128)
    flat = np.concatenate(chunks)
    assert flat.shape == (st.n_chunks * 128, 2)
    np.testing.assert_array_equal(flat[: len(edges)], edges)
    assert (flat[len(edges):] == n).all()  # tail padded with the trash node


def test_chunk_stream_counts_passes(graph):
    edges, n = graph
    st = EdgeChunkStream(edges, n, 128)
    assert st.passes == 0
    list(st)
    list(st)
    assert st.passes == 2


def test_chunk_stream_single_chunk_covers_all(graph):
    edges, n = graph
    st = EdgeChunkStream(edges, n, 10 * len(edges))
    (chunk,) = list(st)
    np.testing.assert_array_equal(chunk[: len(edges)], edges)


# --------------------------------------------------- stage-level equivalence


def test_chunked_scoda_matches_oneshot(graph):
    """Chunked update over K chunks == one-shot, bit-for-bit (labels + deg)."""
    edges, n = graph
    cfg = _scoda_cfg(edges, n)
    ej = jnp.asarray(pad_edges(edges, len(edges), n))
    lab1, deg1 = detect_communities(ej, n, cfg)
    st = EdgeChunkStream(edges, n, 128, block_size=cfg.block_size)
    assert st.n_chunks >= 4  # a real multi-chunk stream, chunk < |E|/4
    lab2, deg2, gdeg = stream_detect(st, n, cfg)
    np.testing.assert_array_equal(np.asarray(lab1), np.asarray(lab2))
    np.testing.assert_array_equal(np.asarray(deg1), np.asarray(deg2))
    np.testing.assert_array_equal(
        np.asarray(degrees(ej, n)), np.asarray(gdeg)
    )


def test_chunked_agg_matches_oneshot(graph):
    """Superedge aggregation: merging K chunks == one-shot lexsort-dedupe."""
    edges, n = graph
    rng = np.random.default_rng(3)
    labels = jnp.asarray(rng.integers(0, 40, n).astype(np.int32))
    labels_dense, _ = dense_labels(labels, n)
    # capacity must hold every unique pair (≤ 40·39/2): overflow truncation
    # is lossy and chunk-order-dependent, so equality only holds below it.
    s_cap, cap = 64, 1024
    ej = jnp.asarray(pad_edges(edges, len(edges), n))
    se1, sw1, n1 = aggregate_edges(ej, labels_dense, s_cap, cap)

    labels_ext = jnp.concatenate([labels_dense, jnp.array([s_cap], jnp.int32)])
    state = agg_init(s_cap, cap)
    for chunk in EdgeChunkStream(edges, n, 97):  # deliberately odd chunk size
        state = agg_update(state, jnp.asarray(chunk), labels_ext, s_cap, cap)
    se2, sw2, n2 = agg_finalize(state)
    assert int(n1) == int(n2)
    np.testing.assert_array_equal(np.asarray(se1), np.asarray(se2))
    np.testing.assert_array_equal(np.asarray(sw1), np.asarray(sw2))


def test_stream_agg_backends_identical_and_timed(graph):
    """Engine-level: merge vs lexsort aggregation produce the same
    supergraph through stream_pipeline, and ``time_agg`` fills the
    per-chunk aggregation timing in StreamStats."""
    edges, n = graph
    cfg = _scoda_cfg(edges, n, rounds=2)
    from repro.core.cms import CMSConfig

    out = {}
    for backend in ("lexsort", "merge"):
        labels, gdeg, sg, q, stats = stream_pipeline(
            edges, n, cfg, CMSConfig(rows=4, cols=256), 512, 2048,
            StreamConfig(chunk_size=128, agg_backend=backend, time_agg=True),
        )
        out[backend] = sg
        st = EdgeChunkStream(edges, n, 128, block_size=cfg.block_size)
        assert stats.agg_chunks == st.n_chunks  # one supergraph pass
        assert stats.agg_update_s > 0.0
    for field in ("edges", "weights", "sizes", "labels"):
        np.testing.assert_array_equal(
            np.asarray(getattr(out["lexsort"], field)),
            np.asarray(getattr(out["merge"], field)),
        )
    assert int(out["lexsort"].n_superedges) == int(out["merge"].n_superedges)


def test_chunked_modularity_matches_oneshot(graph):
    edges, n = graph
    rng = np.random.default_rng(4)
    labels = jnp.asarray(rng.integers(0, 30, n).astype(np.int32))
    ej = jnp.asarray(pad_edges(edges, len(edges), n))
    q1 = modularity(ej, labels, n)
    labels_ext = jnp.concatenate([labels, jnp.array([-1], jnp.int32)])
    state = modularity_init(n)
    for chunk in EdgeChunkStream(edges, n, 64):
        state = modularity_update(state, jnp.asarray(chunk), labels_ext)
    q2 = modularity_finalize(state)
    assert float(q1) == float(q2)


def test_stream_supergraph_matches_build_supergraph(graph):
    edges, n = graph
    cfg = _scoda_cfg(edges, n)
    ej = jnp.asarray(pad_edges(edges, len(edges), n))
    labels, _ = detect_communities(ej, n, cfg)
    deg = degrees(ej, n)
    s_cap, cap = 512, 2048
    from repro.core.cms import CMSConfig

    cms_cfg = CMSConfig(rows=4, cols=256)
    sg1 = build_supergraph(ej, labels, deg, n, s_cap, cap, cms_cfg)
    st = EdgeChunkStream(edges, n, 128, block_size=cfg.block_size)
    sg2, q = stream_supergraph(st, labels, deg, n, s_cap, cap, cms_cfg)
    np.testing.assert_array_equal(np.asarray(sg1.edges), np.asarray(sg2.edges))
    np.testing.assert_array_equal(np.asarray(sg1.weights), np.asarray(sg2.weights))
    np.testing.assert_array_equal(np.asarray(sg1.sizes), np.asarray(sg2.sizes))
    np.testing.assert_array_equal(np.asarray(sg1.labels), np.asarray(sg2.labels))
    assert int(sg1.n_supernodes) == int(sg2.n_supernodes)
    assert int(sg1.n_superedges) == int(sg2.n_superedges)
    assert np.isfinite(float(q))


# ------------------------------------------------- pipeline-level equivalence


def test_stream_pipeline_matches_oneshot(graph):
    """Full driver: streamed (chunk < |E|/4) == one-shot, bit-for-bit."""
    edges, n = graph
    from dataclasses import replace

    cfg = default_config(n, len(edges), max(2, mode_degree(edges, n)),
                         rounds=4, iterations=20, s_cap=512)
    cfg = replace(cfg, scoda=replace(cfg.scoda, block_size=64))
    assert 128 < len(edges) / 4
    r1 = biggraphvis(edges, n, cfg)
    r2 = biggraphvis(edges, n, cfg, stream=StreamConfig(chunk_size=128))
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.sizes, r2.sizes)
    np.testing.assert_array_equal(r1.groups, r2.groups)
    np.testing.assert_array_equal(
        np.asarray(r1.supergraph.edges), np.asarray(r2.supergraph.edges)
    )
    np.testing.assert_array_equal(
        np.asarray(r1.supergraph.weights), np.asarray(r2.supergraph.weights)
    )
    np.testing.assert_array_equal(r1.positions, r2.positions)
    assert r1.modularity == r2.modularity
    assert r1.n_supernodes == r2.n_supernodes
    assert r1.n_superedges == r2.n_superedges


def test_multi_pass_stats_and_residency(graph):
    """Chunk ≪ |E|: rounds+1 passes over the stream, and the engine's peak
    device residency is below the one-shot full-edge materialization."""
    edges, n = graph
    cfg = _scoda_cfg(edges, n, block_size=64, rounds=3)
    from repro.core.cms import CMSConfig

    labels, gdeg, sg, q, stats = stream_pipeline(
        edges, n, cfg, CMSConfig(rows=4, cols=256), 512, 2048,
        StreamConfig(chunk_size=64),
    )
    st = EdgeChunkStream(edges, n, 64, block_size=64)
    assert stats.passes == cfg.rounds + 1
    assert stats.chunks == (cfg.rounds + 1) * st.n_chunks
    assert stats.edges_streamed == stats.chunks * 64
    assert stats.chunk_size == 64

    _, _, _, _, stats_one = stream_pipeline(
        edges, n, cfg, CMSConfig(rows=4, cols=256), 512, 2048, None,
    )
    assert stats_one.passes == cfg.rounds + 1
    assert stats.peak_device_bytes < stats_one.peak_device_bytes


def test_prefetch_depth_zero_identical(graph):
    edges, n = graph
    cfg = _scoda_cfg(edges, n, rounds=2)
    lab1, _, _ = stream_detect(
        EdgeChunkStream(edges, n, 128, block_size=64), n, cfg, prefetch=0
    )
    lab2, _, _ = stream_detect(
        EdgeChunkStream(edges, n, 128, block_size=64), n, cfg, prefetch=3
    )
    np.testing.assert_array_equal(np.asarray(lab1), np.asarray(lab2))


def test_oneshot_device_bytes_scales_with_edges():
    assert oneshot_device_bytes(10**6, 10**4) > oneshot_device_bytes(10**5, 10**4)


def test_memory_path_host_bytes_and_overlap_stats(graph):
    """In-memory sources pin the edge list on the host and never stage, so
    fill/stall time stays zero and peak_host_bytes covers the array."""
    edges, n = graph
    cfg = _scoda_cfg(edges, n, rounds=2)
    from repro.core.cms import CMSConfig

    _, _, _, _, stats = stream_pipeline(
        edges, n, cfg, CMSConfig(rows=4, cols=256), 512, 2048,
        StreamConfig(chunk_size=128),
    )
    assert stats.peak_host_bytes >= edges.size * 4
    assert stats.host_fill_s == 0.0
    assert stats.copy_stall_s == 0.0


def test_stream_rejects_wrong_dtype_at_construction(graph):
    """A float edge array must fail up front with a clear message, not deep
    inside a kernel (and not silently truncate node ids)."""
    edges, n = graph
    with pytest.raises(ValueError, match="integer dtype"):
        EdgeChunkStream(edges.astype(np.float64), n, 128)
    with pytest.raises(ValueError, match=r"shape \[E, 2\]"):
        EdgeChunkStream(edges.reshape(-1), n, 128)
