"""Integrity of the multi-pod dry-run evidence (experiments/dryrun/*.json).

Skipped when the evidence directory is absent (fresh checkout) — generate
it with ``PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both``.
"""
import glob
import json
import os

import pytest

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(DRYRUN, "*.json")),
    reason="dry-run evidence not generated",
)


def _records():
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            yield json.load(f)


def test_every_cell_ok_or_documented_skip():
    statuses = {}
    for rec in _records():
        key = (rec["arch"], rec["shape"], rec["mesh"])
        statuses[key] = rec["status"]
        if rec["status"] == "skipped":
            assert rec.get("skip_reason"), key
        else:
            assert rec["status"] == "ok", (key, rec.get("error", "")[:200])
    # 11 archs × 4 shapes × 2 meshes
    assert len(statuses) == 88
    assert sum(1 for s in statuses.values() if s == "ok") == 80
    assert sum(1 for s in statuses.values() if s == "skipped") == 8


def test_ok_cells_carry_roofline_inputs():
    for rec in _records():
        if rec["status"] != "ok":
            continue
        assert rec["n_devices"] in (256, 512)
        assert rec["memory"]["argument_size_in_bytes"] >= 0
        assert "flops" in rec["cost"]
        assert rec["hlo_dot_flops"] >= 0
        assert rec["collective_bytes"] >= 0
        assert rec["meta"].get("model_flops", 0) > 0


def test_multi_pod_uses_512_devices():
    for rec in _records():
        if rec["status"] != "ok":
            continue
        assert rec["n_devices"] == (512 if rec["mesh"] == "multi" else 256)


def test_long_context_cell_runs_for_hybrid_arch_only():
    saw_gemma_long = False
    for rec in _records():
        if rec["shape"] != "long_500k":
            continue
        if rec["arch"] == "gemma3-4b":
            assert rec["status"] == "ok"
            saw_gemma_long = True
        else:
            assert rec["status"] == "skipped"
    assert saw_gemma_long
