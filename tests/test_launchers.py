"""Integration tests for the launchers: training driver (checkpoint +
restore cycle through the real CLI path) and the layout CLI loader."""
import os

import numpy as np

from repro.launch.train import run as train_run
from repro.launch.layout import load_edges


def test_train_driver_end_to_end(tmp_path):
    out = train_run(
        "granite-moe-1b-a400m", steps=6, batch=2, seq=16,
        ckpt_dir=str(tmp_path), ckpt_every=3, lr=1e-3, log_every=100,
    )
    assert len(out["losses"]) == 6
    assert np.isfinite(out["losses"]).all()
    assert any(f.endswith(".npz") for f in os.listdir(tmp_path))
    # restart resumes from the checkpoint instead of step 0
    out2 = train_run(
        "granite-moe-1b-a400m", steps=8, batch=2, seq=16,
        ckpt_dir=str(tmp_path), ckpt_every=3, lr=1e-3, log_every=100,
    )
    assert len(out2["losses"]) < 8  # resumed mid-run


def test_train_driver_gnn_and_recsys(tmp_path):
    for arch in ("gin-tu", "sasrec"):
        out = train_run(arch, steps=3, batch=4, seq=12,
                        ckpt_dir=str(tmp_path / arch), ckpt_every=100, lr=1e-3)
        assert np.isfinite(out["losses"]).all()


def test_layout_cli_loaders(tmp_path):
    edges, n = load_edges("synthetic:200:4")
    assert n == 200 and len(edges) > 100
    # SNAP-format file with comments and sparse ids
    p = tmp_path / "g.txt"
    p.write_text("# comment\n10 20\n20 30\n10 30\n40 10\n")
    edges, n = load_edges(str(p))
    assert n == 4  # compacted ids
    assert len(edges) == 4
    assert edges.max() < 4
