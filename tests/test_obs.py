"""repro.obs: tracer nesting/threading/export, metrics registry semantics,
the compile-meter's idempotent registration, and the stats invariants the
instrumented subsystems promise (StreamStats stage accounting, RenderStats
timing keys, BGVResult layout-iteration agreement)."""
import json
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import biggraphvis, default_config, layout_supergraph
from repro.graph import mode_degree, planted_partition
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import NULL_SPAN, Tracer, get_tracer, set_tracer
from repro.render import RenderConfig, render_arrays


# ---------------------------------------------------------------------------
# Tracer


def test_span_nesting_and_parenting():
    tr = Tracer()
    with tr.span("a"):
        with tr.span("b"):
            with tr.span("c"):
                pass
        with tr.span("b2"):
            pass
    spans = {s.name: s for s in tr.spans()}
    assert spans["a"].parent is None
    assert spans["b"].parent == spans["a"].span_id
    assert spans["c"].parent == spans["b"].span_id
    assert spans["b2"].parent == spans["a"].span_id
    assert spans["a"].t0 <= spans["b"].t0
    assert spans["b"].t1 <= spans["a"].t1
    assert all(s.duration >= 0 for s in spans.values())


def test_span_attrs_and_set():
    tr = Tracer()
    with tr.span("x", chunk=3) as sp:
        sp.set(extra="y")
    (s,) = tr.spans()
    assert s.attrs == {"chunk": 3, "extra": "y"}


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    h = tr.span("anything", a=1)
    assert h is NULL_SPAN
    with h:
        pass
    assert tr.spans() == []


def test_thread_local_span_stacks():
    tr = Tracer()
    err = []

    def worker(name):
        try:
            with tr.span(name):
                time.sleep(0.01)
                with tr.span(name + ".child"):
                    pass
        except Exception as e:  # pragma: no cover
            err.append(e)

    threads = [
        threading.Thread(target=worker, args=(f"t{i}",)) for i in range(4)
    ]
    with tr.span("main"):
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not err
    spans = {s.name: s for s in tr.spans()}
    # Each thread's child parents to its own root — never to another
    # thread's open span (including main's).
    for i in range(4):
        root, child = spans[f"t{i}"], spans[f"t{i}.child"]
        assert root.parent is None
        assert child.parent == root.span_id
        assert child.tid == root.tid


def test_chrome_export_valid(tmp_path):
    tr = Tracer()
    with tr.span("outer", n=np.int64(7)):
        with tr.span("inner"):
            pass
    path = tr.to_chrome(str(tmp_path / "t.trace.json"))
    doc = json.load(open(path))
    assert "traceEvents" in doc
    events = doc["traceEvents"]
    assert len(events) == 2
    for e in events:
        assert e["ph"] == "X"
        assert e["dur"] >= 0 and e["ts"] >= 0
        assert {"name", "pid", "tid", "args"} <= set(e)
    outer = next(e for e in events if e["name"] == "outer")
    assert outer["args"]["n"] == 7  # numpy scalar coerced to JSON int


def test_jsonl_export(tmp_path):
    tr = Tracer()
    with tr.span("a"):
        pass
    path = tr.to_jsonl(str(tmp_path / "t.jsonl"))
    rows = [json.loads(line) for line in open(path)]
    assert rows[0]["name"] == "a"
    assert rows[0]["parent"] is None


def test_global_tracer_default_disabled():
    assert get_tracer().enabled is False or get_tracer().span("x") is not None
    # set/reset round-trips
    tr = Tracer()
    assert set_tracer(tr) is tr
    assert get_tracer() is tr
    set_tracer(None)
    assert get_tracer().enabled is False


# ---------------------------------------------------------------------------
# Metrics


def test_counter_gauge_basics():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    assert reg.value("c") == 5
    reg.gauge("g").set(2.5)
    reg.gauge("g").set_max(1.0)  # lower: no change
    assert reg.value("g") == 2.5
    reg.gauge("g").set_max(9.0)
    assert reg.value("g") == 9.0
    assert reg.value("missing", default=-1) == -1


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_histogram_quantiles():
    h = Histogram("h")
    values = [0.001 * (i + 1) for i in range(1000)]  # 1ms .. 1s uniform
    for v in values:
        h.record(v)
    assert h.count == 1000
    assert h.vmin == pytest.approx(0.001)
    assert h.vmax == pytest.approx(1.0)
    # log2 buckets: worst-case relative error is the bucket width (2x)
    assert h.p50 == pytest.approx(0.5, rel=1.0)
    assert h.p99 == pytest.approx(0.99, rel=1.0)
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(1.0) <= h.vmax
    assert h.mean == pytest.approx(np.mean(values), rel=1e-6)


def test_histogram_underflow_and_nan():
    h = Histogram("h")
    h.record(0.0)
    h.record(-3.0)
    h.record(float("nan"))
    assert h.count == 0 and h.underflow == 3
    assert h.p50 == 0.0  # no positive samples


def test_registry_dump_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("a.n").inc(2)
    reg.gauge("a.g").set(1.5)
    reg.histogram("b.h").record(0.25)
    text = reg.dump_text()
    assert "a.n 2" in text
    assert "a.g 1.5" in text
    assert "b.h count=1" in text
    snap = reg.snapshot(prefix="a.")
    assert set(snap) == {"a.n", "a.g"}
    assert reg.names(prefix="b.") == ["b.h"]


# ---------------------------------------------------------------------------
# Compile meter (moved from repro.serve.tiles — satellite invariants)


def test_jit_compile_count_reexported_from_serve():
    from repro.obs.meters import jit_compile_count as obs_fn
    from repro.serve.tiles import jit_compile_count as tiles_fn
    import repro.serve as serve

    assert tiles_fn is obs_fn  # the deprecation re-export is the same object
    assert serve.jit_compile_count is obs_fn


def test_compile_listener_idempotent():
    from repro.obs import meters

    first = meters.register_compile_listener()
    # Whatever happened before this test, a second registration in the
    # same process must be refused.
    assert meters.register_compile_listener() is False
    assert first in (True, False)
    # and the counter is readable + monotone
    c0 = meters.jit_compile_count()
    assert meters.jit_compile_count() >= c0


# ---------------------------------------------------------------------------
# Stats invariants (the documented contracts CI relies on)


@pytest.fixture(scope="module")
def small_result():
    n = 400
    edges, _ = planted_partition(n, 8, 0.2, 1e-3, seed=3)
    cfg = default_config(n, len(edges), mode_degree(edges, n),
                         rounds=2, iterations=8)
    t0 = time.perf_counter()
    res = biggraphvis(edges, n, cfg)
    wall = time.perf_counter() - t0
    return res, cfg, wall


def test_stream_stats_stage_seconds_invariants(small_result):
    res, _cfg, wall = small_result
    s = res.stream
    assert s is not None
    for stage, secs in s.stage_seconds.items():
        assert secs >= 0.0, stage
    assert sum(s.stage_seconds.values()) == pytest.approx(s.seconds)
    # stage time is measured inside the pipeline call: never more than the
    # whole call's wall clock
    assert s.seconds <= wall


def test_bgv_layout_iterations_matches_layout(small_result):
    res, cfg, _wall = small_result
    _pos, iters = layout_supergraph(res.supergraph, cfg)
    assert res.timings["layout_iterations"] == iters


def test_render_stats_timings_keys():
    pos = np.array([[0.0, 0.0], [1.0, 1.0], [0.0, 1.0]], np.float32)
    radii = np.array([1.0, 2.0, 1.0], np.float32)
    groups = np.array([0, 1, 2], np.int32)
    edges = np.array([[0, 1], [1, 2]], np.int32)
    _img, stats = render_arrays(
        pos, radii, groups, edges,
        cfg=RenderConfig(width=64, height=64),
    )
    # The documented timing set — launch/render_runner and the CI summary
    # read exactly these keys.
    assert set(stats.timings) == {
        "node_raster_s", "edge_raster_s", "compose_s"
    }
    assert all(v >= 0.0 for v in stats.timings.values())
    assert stats.seconds >= sum(stats.timings.values()) * 0.0  # non-negative


# ---------------------------------------------------------------------------
# End-to-end traced pipeline


def test_traced_pipeline_phase_coverage(tmp_path):
    n = 300
    edges, _ = planted_partition(n, 6, 0.25, 1e-3, seed=4)
    cfg = default_config(n, len(edges), mode_degree(edges, n),
                         rounds=2, iterations=5)
    tr = Tracer(enabled=True)
    from dataclasses import replace

    res = biggraphvis(edges, n, replace(cfg, obs=tr))
    res.render(str(tmp_path / "out.png"))
    names = tr.span_names()
    for phase in ("biggraphvis", "detect", "detect.chunk", "supergraph",
                  "supergraph.chunk", "layout", "render", "render.compose"):
        assert phase in names, (phase, sorted(names))
    # span tree: biggraphvis is an ancestor of the detect chunks
    spans = tr.spans()
    by_id = {s.span_id: s for s in spans}
    chunk = next(s for s in spans if s.name == "detect.chunk")
    seen = set()
    node = chunk
    while node.parent is not None and node.parent not in seen:
        seen.add(node.parent)
        node = by_id[node.parent]
    assert node.name == "biggraphvis"
    # and the publishing side-effects landed in the global registry
    from repro.obs.metrics import REGISTRY

    assert REGISTRY.value("layout.iterations_run") >= 1
    assert REGISTRY.value("stream.chunks") >= 1
    assert REGISTRY.value("render.renders") >= 1
