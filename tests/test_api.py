"""Public API surface: top-level exports, signature snapshots, the
render-kwarg deprecation shims, and the default_config CMS-width formula
(pinning the docstring/code reconciliation)."""
import inspect
import warnings

import numpy as np
import pytest

import repro
import repro.serve
from repro.core.pipeline import default_cms_cols
from repro.graph import mode_degree, planted_partition

# The stable surface promised by the API redesign: importing any of these
# from the top-level package must keep working.
STABLE_EXPORTS = [
    "biggraphvis",
    "default_config",
    "BGVConfig",
    "BGVResult",
    "render",
    "EdgeStore",
    "StreamConfig",
    "TileEngine",
]


def test_stable_exports_in_all():
    assert set(STABLE_EXPORTS) <= set(repro.__all__)


def test_all_exports_resolve():
    for name in repro.__all__:
        assert getattr(repro, name) is not None
    for name in repro.serve.__all__:
        assert getattr(repro.serve, name) is not None


def test_dir_includes_exports():
    assert set(repro.__all__) <= set(dir(repro))


def test_unknown_attribute_raises():
    with pytest.raises(AttributeError):
        repro.not_an_export


def test_lazy_exports_are_canonical_objects():
    # The lazy __getattr__ must hand out the same objects as the deep
    # module paths — not copies or wrappers.
    from repro.core.pipeline import BGVConfig, biggraphvis
    from repro.data.edge_store import EdgeStore
    from repro.serve.tiles import TileEngine

    assert repro.biggraphvis is biggraphvis
    assert repro.BGVConfig is BGVConfig
    assert repro.EdgeStore is EdgeStore
    assert repro.TileEngine is TileEngine
    assert repro.serve.TileEngine is TileEngine


def test_signature_snapshot():
    """Keyword-level compatibility snapshot of the stable entry points —
    renaming/removing a parameter is an API break and must show up here."""
    assert list(inspect.signature(repro.biggraphvis).parameters) == [
        "source", "n_nodes", "cfg", "stream", "put",
        "render_path", "render_cfg", "checkpoint", "resume",
    ]
    assert list(inspect.signature(repro.default_config).parameters) == [
        "n_nodes", "n_edges", "degree_threshold", "rounds", "iterations",
        "s_cap", "repulsion", "grid_size", "grid_window", "grid_rebuild",
        "stop_tolerance", "min_iterations", "init", "nan_guard",
    ]
    assert list(
        inspect.signature(repro.BGVResult.render).parameters
    ) == ["self", "path", "cfg"]


def test_default_cms_cols_formula():
    """Regression for the docstring/code mismatch: the implemented formula
    is max(256, |E| // 1000) — NOT the 1e-4·|E| the seed docstring
    claimed."""
    assert default_cms_cols(0) == 256
    assert default_cms_cols(255_999) == 256
    assert default_cms_cols(1_000_000) == 1000
    assert default_cms_cols(34_000_000) == 34_000  # paper-scale graph
    cfg = repro.default_config(1000, 2_000_000, 4)
    assert cfg.cms.cols == default_cms_cols(2_000_000) == 2000


@pytest.fixture(scope="module")
def tiny_scene():
    n = 120
    edges, _ = planted_partition(n, 4, 0.3, 0.01, seed=3)
    cfg = repro.default_config(
        n, len(edges), mode_degree(edges, n), iterations=5, s_cap=32
    )
    return edges, n, cfg


def test_render_method_replaces_kwargs(tiny_scene, tmp_path, monkeypatch):
    """BGVResult.render() is the entry point; the old render_path=/
    render_cfg= kwargs still work but warn exactly once per process."""
    import repro.core.pipeline as pipeline

    edges, n, cfg = tiny_scene
    res = repro.biggraphvis(edges, n, cfg)
    img, stats = res.render(str(tmp_path / "direct.png"))
    assert img.dtype == np.uint8 and img.ndim == 3
    assert (tmp_path / "direct.png").exists()
    assert res.timings["render_s"] > 0

    monkeypatch.setattr(pipeline, "_RENDER_KWARGS_WARNED", False)
    with pytest.warns(DeprecationWarning, match=r"\.render\(path"):
        repro.biggraphvis(
            edges, n, cfg, render_path=str(tmp_path / "shim.png")
        )
    assert (tmp_path / "shim.png").exists()

    # Second shim use in the same process: silent (warn-once).
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        repro.biggraphvis(
            edges, n, cfg, render_path=str(tmp_path / "shim2.png")
        )
    assert (tmp_path / "shim2.png").exists()


def test_shim_and_method_agree(tiny_scene, tmp_path, monkeypatch):
    import repro.core.pipeline as pipeline

    edges, n, cfg = tiny_scene
    monkeypatch.setattr(pipeline, "_RENDER_KWARGS_WARNED", True)
    res = repro.biggraphvis(
        edges, n, cfg, render_path=str(tmp_path / "a.png")
    )
    img_method, _ = res.render(str(tmp_path / "b.png"))
    a = (tmp_path / "a.png").read_bytes()
    b = (tmp_path / "b.png").read_bytes()
    assert a == b
    assert img_method.shape[2] == 3
