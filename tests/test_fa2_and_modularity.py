"""ForceAtlas2 layout behaviour + modularity vs networkx oracle, plus the
tiled grid-repulsion family (kernels/grid): ref/Pallas parity on
adversarial inputs, grid-vs-exact agreement, and layout-level contracts
(backend parity, dtype threading, rebuild cadence)."""
import dataclasses

import jax
import networkx as nx
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import forceatlas2 as fa2
from repro.core.coloring import color_groups
from repro.core.modularity import modularity
from repro.graph import planted_partition, pad_edges
from repro.graph.utils import degrees
from repro.kernels.grid import ops as grid_ops


def test_modularity_matches_networkx():
    edges_np, true_labels = planted_partition(200, 4, 0.3, 0.02, seed=5)
    n = 200
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    q = float(modularity(edges, jnp.asarray(true_labels), n))
    g = nx.Graph()
    g.add_nodes_from(range(n))
    g.add_edges_from(map(tuple, edges_np))
    comms = [set(np.where(true_labels == c)[0]) for c in np.unique(true_labels)]
    q_nx = nx.algorithms.community.modularity(g, comms)
    assert abs(q - q_nx) < 1e-3, (q, q_nx)


def test_layout_finite_and_converging():
    edges_np, _ = planted_partition(120, 4, 0.4, 0.02, seed=2)
    n = 120
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    mass = degrees(edges, n).astype(jnp.float32) + 1.0
    w = jnp.ones(edges.shape[0], jnp.float32)
    cfg = fa2.FA2Config(iterations=60, repulsion="exact", use_radii=False)
    pos, trace, iters = fa2.layout(edges, w, mass, n, cfg)
    pos = np.asarray(pos)
    assert np.isfinite(pos).all()
    assert int(iters) == cfg.iterations  # non-adaptive: every slot is live
    # Global swing (trace column 0) in the last quarter below the first
    # quarter: system relaxing.
    t = np.asarray(trace)
    assert t.shape == (cfg.iterations, 3)
    swing = t[:, 0]
    assert swing[-len(swing) // 4 :].mean() < swing[: len(swing) // 4].mean()


def test_layout_separates_communities():
    """Force layouts place intra-community pairs closer than inter pairs."""
    edges_np, labels = planted_partition(120, 3, 0.5, 0.01, seed=9)
    n = 120
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    mass = degrees(edges, n).astype(jnp.float32) + 1.0
    w = jnp.ones(edges.shape[0], jnp.float32)
    cfg = fa2.FA2Config(iterations=150, repulsion="exact", use_radii=False, seed=3)
    pos, _, _ = fa2.layout(edges, w, mass, n, cfg)
    pos = np.asarray(pos)
    d = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
    same = labels[:, None] == labels[None, :]
    off = ~np.eye(n, dtype=bool)
    assert d[same & off].mean() < 0.7 * d[~same].mean()


def test_grid_repulsion_close_to_exact():
    """The uniform-grid far-field (Barnes–Hut analogue) approximates exact
    repulsion directionally: cosine similarity of force vectors ≥ 0.8."""
    rng = np.random.default_rng(4)
    n = 256
    pos = jnp.asarray(rng.uniform(-500, 500, size=(n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(1, 5, size=n).astype(np.float32))
    cfg = fa2.FA2Config(repulsion="grid", grid_size=16, use_radii=False)
    f_grid = fa2._grid_repulsion(pos, mass, cfg)
    from repro.kernels.repulsion.ref import repulsion_ref

    f_exact = repulsion_ref(pos, mass, cfg.repulsion_k)
    f_grid, f_exact = np.asarray(f_grid), np.asarray(f_exact)
    cos = np.sum(f_grid * f_exact, -1) / (
        np.linalg.norm(f_grid, axis=-1) * np.linalg.norm(f_exact, axis=-1) + 1e-9
    )
    assert np.median(cos) > 0.8


def _grid_cases():
    """Adversarial inputs for the grid kernels (name, pos, mass, g, window)."""
    rng = np.random.default_rng(11)
    uniform = rng.uniform(-500, 500, (300, 2)).astype(np.float32)
    return [
        # every node in the single cell of a 1×1 grid: far field vanishes,
        # near field is exact pairwise within the band
        ("one-cell", uniform[:64], np.full(64, 2.0, np.float32), 1, 64),
        # zero-extent layout: all positions identical
        ("zero-extent", np.full((32, 2), 3.5, np.float32),
         np.ones(32, np.float32), 8, 4),
        # most cells empty (n ≪ G²)
        ("empty-cells", uniform[:48], np.ones(48, np.float32), 32, 8),
        # cell occupancy far above the window: band truncates, both
        # backends must truncate identically
        ("occupancy>window", rng.uniform(-1, 1, (256, 2)).astype(np.float32),
         np.ones(256, np.float32), 2, 4),
        # window 0: far field only
        ("window-0", uniform, rng.uniform(1, 3, 300).astype(np.float32), 8, 0),
        ("generic", uniform, rng.uniform(1, 5, 300).astype(np.float32), 16, 32),
    ]


@pytest.mark.parametrize("name,pos,mass,g,window", _grid_cases(),
                         ids=[c[0] for c in _grid_cases()])
def test_grid_kernels_interpret_vs_ref(name, pos, mass, g, window):
    """Pallas grid kernels (interpret mode) match the XLA ref path on
    adversarial inputs."""
    pos, mass = jnp.asarray(pos), jnp.asarray(mass)
    f_ref = np.asarray(
        grid_ops.grid_repulsion(pos, mass, 80.0, g, window, backend="ref"))
    f_pal = np.asarray(
        grid_ops.grid_repulsion(pos, mass, 80.0, g, window, backend="interpret"))
    assert np.isfinite(f_ref).all() and np.isfinite(f_pal).all()
    scale = max(np.abs(f_ref).max(), 1.0)
    np.testing.assert_allclose(f_pal, f_ref, rtol=2e-4, atol=2e-4 * scale)


def test_grid_tiled_matches_dense():
    """The tiled grid path reproduces the dense [n, G², 2] formulation
    (grid_dense) to float32 tolerance — same binning, same band."""
    rng = np.random.default_rng(6)
    n = 400
    pos = jnp.asarray(rng.uniform(-800, 800, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(1, 6, n).astype(np.float32))
    cfg = fa2.FA2Config(repulsion="grid_dense", grid_size=16, grid_window=32,
                        use_radii=False)
    f_dense = np.asarray(fa2._grid_repulsion(pos, mass, cfg))
    f_tiled = np.asarray(grid_ops.grid_repulsion(
        pos, mass, cfg.repulsion_k, cfg.grid_size, cfg.grid_window,
        backend="ref"))
    scale = np.abs(f_dense).max()
    np.testing.assert_allclose(f_tiled, f_dense, rtol=1e-3, atol=1e-4 * scale)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_grid_backends_close_to_exact(backend):
    """Tolerance-bounded grid-vs-exact agreement: the tiled far+near field
    approximates exact pairwise repulsion directionally (median cosine
    similarity of force vectors ≥ 0.8), like the dense grid before it."""
    rng = np.random.default_rng(4)
    n = 256
    pos = jnp.asarray(rng.uniform(-500, 500, size=(n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(1, 5, size=n).astype(np.float32))
    f_grid = np.asarray(
        grid_ops.grid_repulsion(pos, mass, 80.0, 16, 32, backend=backend))
    from repro.kernels.repulsion.ref import repulsion_ref

    f_exact = np.asarray(repulsion_ref(pos, mass, 80.0))
    cos = np.sum(f_grid * f_exact, -1) / (
        np.linalg.norm(f_grid, axis=-1) * np.linalg.norm(f_exact, axis=-1) + 1e-9
    )
    assert np.median(cos) > 0.8


def _small_layout_inputs(n=220, seed=8):
    edges_np, _ = planted_partition(n, 4, 0.3, 0.02, seed=seed)
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    mass = degrees(edges, n).astype(jnp.float32) + 1.0
    w = jnp.ones(edges.shape[0], jnp.float32)
    return edges, w, mass, n


def test_layout_grid_pallas_matches_grid():
    """Layout parity: repulsion="grid_pallas" (interpret off-TPU) matches
    repulsion="grid" to float32 tolerance on a fixed seed."""
    edges, w, mass, n = _small_layout_inputs()
    base = fa2.FA2Config(iterations=8, repulsion="grid", grid_size=8,
                         use_radii=False, seed=7)
    pos_ref, _, _ = fa2.layout(edges, w, mass, n, base)
    pal = dataclasses.replace(base, repulsion="grid_pallas")
    pos_pal, _, _ = fa2.layout(edges, w, mass, n, pal)
    pos_ref, pos_pal = np.asarray(pos_ref), np.asarray(pos_pal)
    assert np.isfinite(pos_ref).all()
    scale = np.abs(pos_ref).max()
    np.testing.assert_allclose(pos_pal, pos_ref, rtol=1e-3, atol=1e-3 * scale)


def test_layout_dtype_threaded():
    """FA2Config.dtype drives the position dtype end to end (it used to be
    declared and ignored)."""
    edges, w, mass, n = _small_layout_inputs(n=96)
    for dt in ("float32", "bfloat16"):
        cfg = fa2.FA2Config(iterations=3, repulsion="exact", use_radii=False,
                            dtype=dt)
        pos, trace, _ = fa2.layout(edges, w, mass, n, cfg)
        assert pos.dtype == jnp.dtype(dt), (dt, pos.dtype)
        assert trace.dtype == jnp.dtype(dt)
        assert np.isfinite(np.asarray(pos, np.float32)).all()
    key = jax.random.PRNGKey(0)
    assert fa2.init_positions(8, key, dtype="bfloat16").dtype == jnp.bfloat16


def test_layout_grid_rebuild_amortized():
    """grid_rebuild > 1 reuses the carried binning between rebuilds: the
    layout stays finite and, over a single rebuild period, is identical to
    the rebuild-every-iteration path (binning only goes stale after the
    first rebuild interval elapses)."""
    edges, w, mass, n = _small_layout_inputs(n=180, seed=3)
    every = fa2.FA2Config(iterations=3, repulsion="grid", grid_size=8,
                          use_radii=False, grid_rebuild=1, seed=1)
    pos_1, _, _ = fa2.layout(edges, w, mass, n, every)
    # 3 iterations with rebuild cadence 1 vs a cadence longer than the run:
    # the stale path must diverge (it keeps iteration-0 binning throughout).
    stale = dataclasses.replace(every, grid_rebuild=50)
    pos_stale, _, _ = fa2.layout(edges, w, mass, n, stale)
    assert np.isfinite(np.asarray(pos_stale)).all()
    assert not np.allclose(np.asarray(pos_stale), np.asarray(pos_1))
    # cadence == 1 via the cond path (rebuild every iteration) must agree
    # with the unconditional path bit-for-bit after one iteration.
    one_it = dataclasses.replace(every, iterations=1)
    one_it_stale = dataclasses.replace(stale, iterations=1)
    p1, _, _ = fa2.layout(edges, w, mass, n, one_it)
    p2, _, _ = fa2.layout(edges, w, mass, n, one_it_stale)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-6, atol=1e-4)


def test_attraction_sorted_matches_scatter():
    """The pre-sorted segment-sum attraction equals the legacy two-scatter
    form (padded trash slots dropped identically)."""
    rng = np.random.default_rng(2)
    n, e = 70, 200
    edges_np = rng.integers(0, n, (e, 2)).astype(np.int32)
    edges_np = edges_np[edges_np[:, 0] != edges_np[:, 1]]
    edges = jnp.asarray(pad_edges(edges_np, e, n))
    w = jnp.concatenate([
        jnp.asarray(rng.uniform(0.5, 2.0, len(edges_np)).astype(np.float32)),
        jnp.ones(e - len(edges_np), jnp.float32),  # weights on trash slots
    ])
    pos = jnp.asarray(rng.uniform(-10, 10, (n, 2)).astype(np.float32))
    legacy = np.asarray(fa2._attraction(pos, edges, w, n))
    src, dst, w2 = fa2._attraction_edge_layout(edges, w)
    sorted_ = np.asarray(fa2._attraction_sorted(pos, src, dst, w2, n))
    np.testing.assert_allclose(sorted_, legacy, rtol=1e-5, atol=1e-4)


def test_color_groups_bulk_and_range():
    sizes = jnp.asarray(np.random.default_rng(0).pareto(1.5, 500).astype(np.float32) + 0.1)
    groups = np.asarray(color_groups(sizes))
    assert groups.min() >= 0 and groups.max() <= 10
    s = np.asarray(sizes)
    bulk_mass = s[groups == 0].sum() / s.sum()
    assert 0.3 < bulk_mass < 0.7  # "smaller communities covering 50% of α"
    # biggest community gets the biggest color bucket
    assert groups[np.argmax(s)] == 10


def test_grid_window_configurable_and_threaded():
    """FA2Config.grid_window drives the near-field band of grid repulsion:
    a window wide enough for every cell's occupancy reproduces the default,
    a zero window (far-field only) does not."""
    rng = np.random.default_rng(7)
    n = 128
    pos = jnp.asarray(rng.uniform(-300, 300, size=(n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(1, 3, size=n).astype(np.float32))
    base = fa2.FA2Config(repulsion="grid", grid_size=8, use_radii=False)
    f32 = np.asarray(fa2._grid_repulsion(pos, mass, base))
    import dataclasses

    wide = dataclasses.replace(base, grid_window=n)
    f_wide = np.asarray(fa2._grid_repulsion(pos, mass, wide))
    narrow = dataclasses.replace(base, grid_window=0)
    f0 = np.asarray(fa2._grid_repulsion(pos, mass, narrow))
    # window = n covers every same-cell pair the default window=32 covers
    # for cells with <= 32 members (n=128 over 64 cells: essentially all).
    np.testing.assert_allclose(f_wide, f32, rtol=1e-4, atol=1e-3)
    assert np.abs(f0 - f32).max() > 1e-3  # near field actually contributes


def test_full_layout_colored_threads_grid_window():
    from dataclasses import replace as drep

    from repro.core import default_config, full_layout_colored
    from repro.graph import mode_degree

    edges_np, _ = planted_partition(150, 5, 0.3, 0.01, seed=3)
    n = 150
    cfg = default_config(n, len(edges_np), mode_degree(edges_np, n),
                         rounds=2, iterations=5)
    cfg = drep(cfg, layout=drep(cfg.layout, grid_window=4))
    pos, groups = full_layout_colored(edges_np, n, cfg, iterations=5)
    assert np.isfinite(pos).all() and len(groups) == n
