"""ForceAtlas2 layout behaviour + modularity vs networkx oracle."""
import networkx as nx
import numpy as np
import jax.numpy as jnp

from repro.core import forceatlas2 as fa2
from repro.core.coloring import color_groups
from repro.core.modularity import modularity
from repro.graph import planted_partition, pad_edges
from repro.graph.utils import degrees


def test_modularity_matches_networkx():
    edges_np, true_labels = planted_partition(200, 4, 0.3, 0.02, seed=5)
    n = 200
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    q = float(modularity(edges, jnp.asarray(true_labels), n))
    g = nx.Graph()
    g.add_nodes_from(range(n))
    g.add_edges_from(map(tuple, edges_np))
    comms = [set(np.where(true_labels == c)[0]) for c in np.unique(true_labels)]
    q_nx = nx.algorithms.community.modularity(g, comms)
    assert abs(q - q_nx) < 1e-3, (q, q_nx)


def test_layout_finite_and_converging():
    edges_np, _ = planted_partition(120, 4, 0.4, 0.02, seed=2)
    n = 120
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    mass = degrees(edges, n).astype(jnp.float32) + 1.0
    w = jnp.ones(edges.shape[0], jnp.float32)
    cfg = fa2.FA2Config(iterations=60, repulsion="exact", use_radii=False)
    pos, trace = fa2.layout(edges, w, mass, n, cfg)
    pos = np.asarray(pos)
    assert np.isfinite(pos).all()
    # Max force in the last quarter below the first quarter: system relaxing.
    t = np.asarray(trace)
    assert t[-len(t) // 4 :].mean() < t[: len(t) // 4].mean()


def test_layout_separates_communities():
    """Force layouts place intra-community pairs closer than inter pairs."""
    edges_np, labels = planted_partition(120, 3, 0.5, 0.01, seed=9)
    n = 120
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    mass = degrees(edges, n).astype(jnp.float32) + 1.0
    w = jnp.ones(edges.shape[0], jnp.float32)
    cfg = fa2.FA2Config(iterations=150, repulsion="exact", use_radii=False, seed=3)
    pos, _ = fa2.layout(edges, w, mass, n, cfg)
    pos = np.asarray(pos)
    d = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
    same = labels[:, None] == labels[None, :]
    off = ~np.eye(n, dtype=bool)
    assert d[same & off].mean() < 0.7 * d[~same].mean()


def test_grid_repulsion_close_to_exact():
    """The uniform-grid far-field (Barnes–Hut analogue) approximates exact
    repulsion directionally: cosine similarity of force vectors ≥ 0.8."""
    rng = np.random.default_rng(4)
    n = 256
    pos = jnp.asarray(rng.uniform(-500, 500, size=(n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(1, 5, size=n).astype(np.float32))
    cfg = fa2.FA2Config(repulsion="grid", grid_size=16, use_radii=False)
    f_grid = fa2._grid_repulsion(pos, mass, cfg)
    from repro.kernels.repulsion.ref import repulsion_ref

    f_exact = repulsion_ref(pos, mass, cfg.repulsion_k)
    f_grid, f_exact = np.asarray(f_grid), np.asarray(f_exact)
    cos = np.sum(f_grid * f_exact, -1) / (
        np.linalg.norm(f_grid, axis=-1) * np.linalg.norm(f_exact, axis=-1) + 1e-9
    )
    assert np.median(cos) > 0.8


def test_color_groups_bulk_and_range():
    sizes = jnp.asarray(np.random.default_rng(0).pareto(1.5, 500).astype(np.float32) + 0.1)
    groups = np.asarray(color_groups(sizes))
    assert groups.min() >= 0 and groups.max() <= 10
    s = np.asarray(sizes)
    bulk_mass = s[groups == 0].sum() / s.sum()
    assert 0.3 < bulk_mass < 0.7  # "smaller communities covering 50% of α"
    # biggest community gets the biggest color bucket
    assert groups[np.argmax(s)] == 10


def test_grid_window_configurable_and_threaded():
    """FA2Config.grid_window drives the near-field band of grid repulsion:
    a window wide enough for every cell's occupancy reproduces the default,
    a zero window (far-field only) does not."""
    rng = np.random.default_rng(7)
    n = 128
    pos = jnp.asarray(rng.uniform(-300, 300, size=(n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(1, 3, size=n).astype(np.float32))
    base = fa2.FA2Config(repulsion="grid", grid_size=8, use_radii=False)
    f32 = np.asarray(fa2._grid_repulsion(pos, mass, base))
    import dataclasses

    wide = dataclasses.replace(base, grid_window=n)
    f_wide = np.asarray(fa2._grid_repulsion(pos, mass, wide))
    narrow = dataclasses.replace(base, grid_window=0)
    f0 = np.asarray(fa2._grid_repulsion(pos, mass, narrow))
    # window = n covers every same-cell pair the default window=32 covers
    # for cells with <= 32 members (n=128 over 64 cells: essentially all).
    np.testing.assert_allclose(f_wide, f32, rtol=1e-4, atol=1e-3)
    assert np.abs(f0 - f32).max() > 1e-3  # near field actually contributes


def test_full_layout_colored_threads_grid_window():
    from dataclasses import replace as drep

    from repro.core import default_config, full_layout_colored
    from repro.graph import mode_degree

    edges_np, _ = planted_partition(150, 5, 0.3, 0.01, seed=3)
    n = 150
    cfg = default_config(n, len(edges_np), mode_degree(edges_np, n),
                         rounds=2, iterations=5)
    cfg = drep(cfg, layout=drep(cfg.layout, grid_window=4))
    pos, groups = full_layout_colored(edges_np, n, cfg, iterations=5)
    assert np.isfinite(pos).all() and len(groups) == n
