"""Hypothesis property tests on system invariants."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels.repulsion.ref import repulsion_ref
from repro.kernels.repulsion import ops as rep_ops
from repro.core.modularity import modularity
from repro.core.coloring import color_groups
from repro.graph.utils import pad_edges


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(8, 64))
def test_repulsion_conserves_momentum(seed, n):
    """Newton's third law: pairwise forces cancel — Σᵢ fᵢ ≈ 0."""
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.uniform(-50, 50, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 3.0, n).astype(np.float32))
    f = repulsion_ref(pos, mass, kr=80.0)
    total = np.asarray(jnp.sum(f, axis=0))
    scale = float(jnp.max(jnp.linalg.norm(f, axis=-1))) + 1e-6
    assert np.abs(total).max() < 1e-3 * scale * n


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_repulsion_translation_invariant(seed):
    rng = np.random.default_rng(seed)
    n = 32
    pos = jnp.asarray(rng.uniform(-10, 10, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    f1 = np.asarray(rep_ops.repulsion(pos, mass, 80.0, backend="ref"))
    f2 = np.asarray(rep_ops.repulsion(pos + 100.0, mass, 80.0, backend="ref"))
    # f32: the shift costs mantissa bits in the pairwise differences, so
    # compare directionally + in magnitude rather than elementwise-tight.
    cos = np.sum(f1 * f2, -1) / (
        np.linalg.norm(f1, axis=-1) * np.linalg.norm(f2, axis=-1) + 1e-9
    )
    assert np.median(cos) > 0.999
    ratio = (np.linalg.norm(f2, axis=-1) + 1e-9) / (np.linalg.norm(f1, axis=-1) + 1e-9)
    assert 0.9 < np.median(ratio) < 1.1


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_modularity_bounds_and_singletons(seed):
    """Q ∈ [-1, 1); all-singleton partition of a simple graph has Q ≤ 0."""
    rng = np.random.default_rng(seed)
    n, e = 40, 80
    edges_np = rng.integers(0, n, (e, 2)).astype(np.int32)
    edges_np = edges_np[edges_np[:, 0] != edges_np[:, 1]]
    if len(edges_np) == 0:
        return
    edges = jnp.asarray(pad_edges(edges_np, e, n))
    singles = jnp.arange(n, dtype=jnp.int32)
    q = float(modularity(edges, singles, n))
    assert -1.0 <= q <= 0.0 + 1e-6
    one = jnp.zeros(n, jnp.int32)
    q_one = float(modularity(edges, one, n))
    assert abs(q_one) < 1e-5  # single community: Q = 0 exactly


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_color_groups_monotone_in_size(seed):
    """Bigger communities never get a smaller color bucket."""
    rng = np.random.default_rng(seed)
    sizes = jnp.asarray(rng.pareto(1.5, 200).astype(np.float32) + 0.01)
    groups = np.asarray(color_groups(sizes))
    order = np.argsort(np.asarray(sizes))
    g_sorted = groups[order]
    assert (np.diff(g_sorted) >= 0).all()
