"""Fault tolerance (ISSUE 10): kill-and-resume bit-identity of the
streaming pipeline (including across device counts), retry/quarantine at
the EdgeStore boundary under injected faults, corrupt-store diagnostics,
the FA2 divergence sentinel, tile-engine degradation, and the errors.*
observability surface."""
import hashlib
import os
import subprocess
import sys
import tempfile
import textwrap
import time
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import biggraphvis, default_config
from repro.core.cms import CMSConfig
from repro.core import forceatlas2 as fa2
from repro.core.scoda import ScodaConfig
from repro.core.stream import StreamConfig, stream_pipeline
from repro.data.edge_store import (
    CorruptStoreError,
    open_edge_store,
    write_bin,
    write_npy,
    write_shards,
)
from repro.obs.metrics import (
    ERROR_COUNTERS,
    MetricsRegistry,
    REGISTRY,
    ensure_error_counters,
)
from repro.resilience import (
    ChaosConfig,
    ChaosEdgeStore,
    CheckpointMismatchError,
    KillSwitch,
    SimulatedPreemption,
    StreamCheckpointer,
    ValidationError,
    ValidationPolicy,
    latest_step,
    load_arrays,
    restore_latest_valid,
    save,
)

# Small enough to stream in seconds, large enough for multiple chunks per
# pass: 32 chunks/pass × (ROUNDS detect passes + 1 supergraph pass) = 96
# chunk boundaries to kill at.
N, E, CHUNK, ROUNDS, BLOCK = 240, 2000, 64, 2, 32
N_CHUNKS = -(-E // CHUNK)
N_BOUNDARIES = (ROUNDS + 1) * N_CHUNKS
SCODA = ScodaConfig(degree_threshold=8, rounds=ROUNDS, block_size=BLOCK)
CMS = CMSConfig(rows=4, cols=256)
S_CAP, MAX_SE = 512, 2048


def _edges():
    rng = np.random.default_rng(7)
    return rng.integers(0, N, (E, 2), dtype=np.int32)


def _run(source, checkpoint=None, resume=False, stream_cfg=None):
    return stream_pipeline(
        source, N, SCODA, CMS, S_CAP, MAX_SE,
        stream_cfg or StreamConfig(chunk_size=CHUNK),
        checkpoint=checkpoint, resume=resume,
    )


def _digest(labels, gdeg, sg, q) -> str:
    h = hashlib.sha256()
    for a in (labels, gdeg, sg.edges, sg.weights, sg.sizes, sg.labels):
        h.update(np.asarray(a).tobytes())
    h.update(np.float64(q).tobytes())
    return h.hexdigest()


_BASELINE: dict = {}


def _baseline_digest() -> str:
    """Uninterrupted-run digest, computed once per process (module-level
    cache rather than a fixture so the hypothesis property test — whose
    stub wrapper takes no fixture arguments — can use it too)."""
    if "digest" not in _BASELINE:
        labels, gdeg, sg, q, _ = _run(_edges())
        _BASELINE["digest"] = _digest(labels, gdeg, sg, q)
    return _BASELINE["digest"]


# ------------------------------------------------------ checkpoint mechanics


def test_checkpoint_atomic_roundtrip_and_prune(tmp_path):
    d = str(tmp_path)
    tree = {"com": np.arange(10, dtype=np.int32),
            "deg": np.ones(10, dtype=np.int32)}
    for step in range(1, 6):
        save(d, step, tree, extra={"phase": "detect", "chunk": step}, keep=2)
    assert latest_step(d) == 5
    kept = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert kept == ["step_00000004.npz", "step_00000005.npz"]
    assert not any(f.endswith(".tmp") for f in os.listdir(d))
    arrays, meta = load_arrays(d, 5)
    np.testing.assert_array_equal(arrays["com"], tree["com"])
    np.testing.assert_array_equal(arrays["deg"], tree["deg"])
    assert meta["chunk"] == 5 and meta["phase"] == "detect"


def test_restore_latest_valid_walks_back_past_corruption(tmp_path):
    d = str(tmp_path)
    save(d, 1, {"x": np.arange(3)}, extra={"chunk": 1})
    save(d, 2, {"x": np.arange(4)}, extra={"chunk": 2})
    bad = tmp_path / "step_00000002.npz"
    bad.write_bytes(b"not an npz at all")  # post-rename bit-rot
    arrays, meta = restore_latest_valid(d)
    assert meta["step"] == 1 and len(arrays["x"]) == 3
    assert not bad.exists()  # the corrupt newest was unlinked...
    assert not (tmp_path / "step_00000002.npz.meta.json").exists()  # +meta
    assert restore_latest_valid(str(tmp_path / "empty")) is None


def test_restore_latest_valid_predicate_walks_back(tmp_path):
    """A loadable npz whose meta lacks the resume cursor (lost to a crash)
    must be walked back past, not handed to the caller to KeyError on."""
    d = str(tmp_path)
    save(d, 1, {"x": np.arange(3)}, extra={"chunk": 1})
    save(d, 2, {"x": np.arange(4)}, extra={"chunk": 2})
    (tmp_path / "step_00000002.npz.meta.json").unlink()
    arrays, meta = restore_latest_valid(d, valid=lambda a, m: "chunk" in m)
    assert meta["step"] == 1 and meta["chunk"] == 1
    assert not (tmp_path / "step_00000002.npz").exists()


def test_prune_removes_orphaned_meta(tmp_path):
    """A kill between the meta rename and the npz rename leaves a meta
    with no npz; the next save's prune sweeps it."""
    d = str(tmp_path)
    save(d, 1, {"x": np.arange(3)}, extra={"chunk": 1}, keep=2)
    orphan = tmp_path / "step_00000099.npz.meta.json"
    orphan.write_text("{}")
    save(d, 2, {"x": np.arange(3)}, extra={"chunk": 2}, keep=2)
    assert not orphan.exists()
    assert latest_step(d) == 2  # discovery keys off .npz, never the meta


def test_checkpointer_seq_seeded_on_restore(tmp_path):
    """``restore_latest`` must continue the save sequence past the restored
    step (regression: a resumed process restarted _seq at 0, so its saves
    sorted below the on-disk window and were pruned on arrival)."""
    d = str(tmp_path)
    ck = StreamCheckpointer(d, every_chunks=1, keep=3)
    for i in range(5):
        ck.boundary("detect", 0, i, False, lambda: {"x": np.arange(3)})
    assert latest_step(d) == 5
    ck2 = StreamCheckpointer(d, every_chunks=1, keep=3)
    found = ck2.restore_latest()
    assert found is not None and found[1]["step"] == 5
    ck2.boundary("detect", 0, 5, False, lambda: {"x": np.arange(3)})
    assert latest_step(d) == 6  # not pruned-on-arrival under steps 3..5


def test_train_checkpoint_shim_reexports():
    """The deprecated old path must expose the same objects (same format,
    same functions) so existing imports keep working."""
    from repro.resilience import checkpoint as new
    from repro.train import checkpoint as old
    from repro.train.fault_tolerance import CheckpointManager

    assert old.save is new.save
    assert old.restore is new.restore
    assert old.latest_step is new.latest_step
    import repro.resilience as rz

    assert rz.CheckpointManager is CheckpointManager


# ----------------------------------------------------- kill/resume identity


@pytest.mark.parametrize(
    "kill_at", [0, N_CHUNKS - 1, N_CHUNKS + 15, ROUNDS * N_CHUNKS + 5,
                N_BOUNDARIES - 1],
)
def test_kill_and_resume_bit_identical(tmp_path, kill_at):
    """Kill at chunk boundary ``kill_at`` (first chunk, round boundary,
    mid-round, supergraph phase, last boundary), resume, and require the
    final digest to match the uninterrupted run exactly."""
    want = _baseline_digest()
    ks = KillSwitch(kill_at)
    ck = StreamCheckpointer(str(tmp_path), every_chunks=1, on_boundary=ks)
    with pytest.raises(SimulatedPreemption):
        _run(_edges(), checkpoint=ck)
    assert ks.fired and ck.saves > 0
    ck2 = StreamCheckpointer(str(tmp_path), every_chunks=1)
    labels, gdeg, sg, q, stats = _run(_edges(), checkpoint=ck2, resume=True)
    assert stats.resumed_at, "resume should report the restored cursor"
    assert _digest(labels, gdeg, sg, q) == want


@settings(max_examples=6, deadline=None)
@given(st.integers(0, N_BOUNDARIES - 1))
def test_kill_and_resume_property(kill_at):
    """Property over *random* kill points: every chunk boundary is a safe
    preemption point (resume is bit-identical, labels/supergraph/Q)."""
    want = _baseline_digest()
    with tempfile.TemporaryDirectory() as d:
        ks = KillSwitch(kill_at)
        ck = StreamCheckpointer(d, every_chunks=1, on_boundary=ks)
        try:
            _run(_edges(), checkpoint=ck)
            raised = False
        except SimulatedPreemption:
            raised = True
        assert raised and ks.fired
        labels, gdeg, sg, q, stats = _run(
            _edges(), checkpoint=StreamCheckpointer(d, every_chunks=1),
            resume=True,
        )
        assert stats.resumed_at
        assert _digest(labels, gdeg, sg, q) == want


def test_resume_layout_bit_identical(tmp_path):
    """End-to-end through ``biggraphvis``: the resumed run's *layout* (not
    just labels/supergraph) matches the uninterrupted run byte for byte."""
    edges = _edges()
    cfg = default_config(N, E, 8, rounds=ROUNDS, iterations=5)
    from dataclasses import replace

    cfg = replace(cfg, scoda=replace(cfg.scoda, block_size=BLOCK))
    res = biggraphvis(edges, N, cfg, stream=StreamConfig(chunk_size=CHUNK))
    ck = StreamCheckpointer(str(tmp_path), every_chunks=1,
                            on_boundary=KillSwitch(40))
    with pytest.raises(SimulatedPreemption):
        biggraphvis(edges, N, cfg, stream=StreamConfig(chunk_size=CHUNK),
                    checkpoint=ck)
    res2 = biggraphvis(
        edges, N, cfg, stream=StreamConfig(chunk_size=CHUNK),
        checkpoint=StreamCheckpointer(str(tmp_path), every_chunks=1),
        resume=True,
    )
    assert res2.stream.resumed_at
    assert np.asarray(res2.labels).tolist() == np.asarray(res.labels).tolist()
    assert (np.asarray(res2.positions).tobytes()
            == np.asarray(res.positions).tobytes())
    assert res2.modularity == res.modularity


def test_post_resume_checkpoints_advance_past_kill_point(tmp_path):
    """The resumed run's own checkpoints must land *after* the pre-kill
    steps: a second preemption then resumes from post-resume progress,
    not from the first kill point."""
    ck = StreamCheckpointer(str(tmp_path), every_chunks=1,
                            on_boundary=KillSwitch(10))
    with pytest.raises(SimulatedPreemption):
        _run(_edges(), checkpoint=ck)
    stale = latest_step(str(tmp_path))
    ck2 = StreamCheckpointer(str(tmp_path), every_chunks=1)
    labels, gdeg, sg, q, stats = _run(_edges(), checkpoint=ck2, resume=True)
    assert stats.resumed_at and ck2.saves > 0
    assert latest_step(str(tmp_path)) > stale
    _, meta = restore_latest_valid(str(tmp_path))
    assert meta["step"] > stale
    assert _digest(labels, gdeg, sg, q) == _baseline_digest()


def test_resume_walks_back_past_metaless_checkpoint(tmp_path):
    """A checkpoint npz whose meta.json is gone (bit-rot / legacy crash)
    has no resume cursor: ``stream_pipeline`` must fall back to the
    previous checkpoint, not KeyError or skip the fingerprint check."""
    want = _baseline_digest()
    ck = StreamCheckpointer(str(tmp_path), every_chunks=1,
                            on_boundary=KillSwitch(10))
    with pytest.raises(SimulatedPreemption):
        _run(_edges(), checkpoint=ck)
    step = latest_step(str(tmp_path))
    (tmp_path / f"step_{step:08d}.npz.meta.json").unlink()
    labels, gdeg, sg, q, stats = _run(
        _edges(), checkpoint=StreamCheckpointer(str(tmp_path), every_chunks=1),
        resume=True,
    )
    assert stats.resumed_at
    assert _digest(labels, gdeg, sg, q) == want


def test_resume_fingerprint_mismatch_raises(tmp_path):
    ck = StreamCheckpointer(str(tmp_path), every_chunks=1,
                            on_boundary=KillSwitch(2))
    with pytest.raises(SimulatedPreemption):
        _run(_edges(), checkpoint=ck)
    with pytest.raises(CheckpointMismatchError):
        _run(_edges(),
             checkpoint=StreamCheckpointer(str(tmp_path), every_chunks=1),
             resume=True,
             stream_cfg=StreamConfig(chunk_size=2 * CHUNK))


def test_resume_with_no_checkpoint_starts_fresh(tmp_path):
    labels, gdeg, sg, q, stats = _run(
        _edges(), checkpoint=StreamCheckpointer(str(tmp_path)), resume=True,
    )
    assert stats.resumed_at == ""
    assert _digest(labels, gdeg, sg, q) == _baseline_digest()


def test_resume_across_device_counts(tmp_path):
    """A checkpoint written on one device resumes bit-identically on a
    forced-4-device mesh (arrays are stored unsharded; the sharded detect
    path is the engine's bit-identity contract)."""
    want = _baseline_digest()
    ck = StreamCheckpointer(str(tmp_path), every_chunks=1,
                            on_boundary=KillSwitch(N_CHUNKS + 7))
    with pytest.raises(SimulatedPreemption):
        _run(_edges(), checkpoint=ck)
    script = textwrap.dedent("""
        import hashlib, sys
        import numpy as np
        import jax
        from repro.core.cms import CMSConfig
        from repro.core.scoda import ScodaConfig
        from repro.core.stream import StreamConfig, stream_pipeline
        from repro.launch.mesh import make_stream_mesh
        from repro.resilience import StreamCheckpointer

        assert jax.device_count() == 4, jax.device_count()
        rng = np.random.default_rng(7)
        edges = rng.integers(0, {n}, ({e}, 2), dtype=np.int32)
        labels, gdeg, sg, q, stats = stream_pipeline(
            edges, {n}, ScodaConfig(degree_threshold=8, rounds={rounds},
                                    block_size={block}),
            CMSConfig(rows=4, cols=256), {s_cap}, {max_se},
            StreamConfig(chunk_size={chunk}, shard_detect=True,
                         mesh=make_stream_mesh()),
            checkpoint=StreamCheckpointer({d!r}, every_chunks=1),
            resume=True,
        )
        assert stats.resumed_at, "subprocess did not resume"
        h = hashlib.sha256()
        for a in (labels, gdeg, sg.edges, sg.weights, sg.sizes, sg.labels):
            h.update(np.asarray(a).tobytes())
        h.update(np.float64(q).tobytes())
        sys.stdout.write(h.hexdigest())
    """).format(n=N, e=E, rounds=ROUNDS, block=BLOCK, s_cap=S_CAP,
                max_se=MAX_SE, chunk=CHUNK, d=str(tmp_path))
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == want


# ------------------------------------------------ validation & quarantine


def test_transient_io_error_retried_to_identical_result():
    store = ChaosEdgeStore(_edges(), ChaosConfig(
        io_error_offsets=(5 * CHUNK,), transient_attempts=1))
    pol = ValidationPolicy(max_retries=2, retry_backoff_s=0.001)
    labels, gdeg, sg, q, stats = _run(
        store, stream_cfg=StreamConfig(chunk_size=CHUNK, validation=pol))
    assert stats.retries >= 1
    assert stats.quarantined_chunks == 0
    assert store.injected[("io", 5 * CHUNK)] >= 1
    assert _digest(labels, gdeg, sg, q) == _baseline_digest()


def test_permanent_io_error_quarantines_and_completes():
    store = ChaosEdgeStore(_edges(), ChaosConfig(io_error_offsets=(3 * CHUNK,)))
    pol = ValidationPolicy(max_retries=1, retry_backoff_s=0.001)
    reg_before = REGISTRY.counter("errors.quarantined_chunks").value
    labels, gdeg, sg, q, stats = _run(
        store, stream_cfg=StreamConfig(chunk_size=CHUNK, validation=pol))
    # chunk 3 is unreadable on every pass (ROUNDS detect + 1 supergraph):
    # the obs counter tallies per-occurrence, StreamStats reports the
    # distinct chunks (regression: the stats mirror double-counted).
    assert stats.quarantined_chunks == 1
    assert stats.quarantined_chunk_ids == [3]
    assert REGISTRY.counter("errors.quarantined_chunks").value - reg_before \
        == ROUNDS + 1
    labels = np.asarray(labels)
    assert labels.shape == (N,) and (labels >= 0).all()
    assert np.isfinite(q)


def test_quarantine_disabled_propagates_io_error():
    store = ChaosEdgeStore(_edges(), ChaosConfig(io_error_offsets=(0,)))
    pol = ValidationPolicy(max_retries=1, retry_backoff_s=0.001,
                           quarantine=False)
    with pytest.raises(OSError, match="injected I/O error"):
        _run(store, stream_cfg=StreamConfig(chunk_size=CHUNK, validation=pol))


def test_truncated_read_is_io_error_with_byte_offset():
    store = ChaosEdgeStore(_edges(), ChaosConfig(
        truncate_offsets=(2 * CHUNK,), truncate_rows=10))
    pol = ValidationPolicy(max_retries=0, quarantine=False)
    with pytest.raises(OSError, match="short read") as ei:
        _run(store, stream_cfg=StreamConfig(chunk_size=CHUNK, validation=pol))
    assert f"byte offset {(2 * CHUNK + 10) * 8}" in str(ei.value)


def test_bitflip_out_of_range_id_dropped_or_raised():
    cfg = ChaosConfig(bitflip_offsets=(0,))
    pol = ValidationPolicy(retry_backoff_s=0.001)
    store = ChaosEdgeStore(_edges(), cfg)
    labels, gdeg, sg, q, stats = _run(
        store, stream_cfg=StreamConfig(chunk_size=CHUNK, validation=pol))
    # the flip recurs on every pass over chunk 0
    assert stats.dropped_edges >= ROUNDS + 1
    assert np.asarray(labels).shape == (N,)
    with pytest.raises(ValidationError, match="invalid rows"):
        _run(ChaosEdgeStore(_edges(), cfg),
             stream_cfg=StreamConfig(
                 chunk_size=CHUNK,
                 validation=ValidationPolicy(on_invalid="error")))


def test_self_loop_policy():
    edges = _edges()
    edges[::100, 1] = edges[::100, 0]  # plant 20 self-loops
    n_loops = int((edges[:, 0] == edges[:, 1]).sum())
    pol = ValidationPolicy(self_loops="drop")
    _, _, _, _, stats = _run(
        edges.copy(), stream_cfg=StreamConfig(chunk_size=CHUNK, validation=pol))
    assert stats.dropped_edges >= n_loops  # dropped on every pass
    with pytest.raises(ValidationError, match="self-loop"):
        _run(edges.copy(), stream_cfg=StreamConfig(
            chunk_size=CHUNK,
            validation=ValidationPolicy(self_loops="error")))


# ------------------------------------------------- corrupt-store diagnostics


def test_corrupt_npy_store_names_file_and_offset(tmp_path):
    edges = _edges()
    path = write_npy(str(tmp_path / "edges.npy"), edges)
    size = os.path.getsize(path) - 100
    with open(path, "r+b") as f:
        f.truncate(size)
    with pytest.raises(CorruptStoreError) as ei:
        open_edge_store(path)
    msg = str(ei.value)
    assert "edges.npy" in msg and str(size) in msg


def test_corrupt_bin_store_names_trailing_record(tmp_path):
    path = write_bin(str(tmp_path / "edges.bin"), _edges())
    good = os.path.getsize(path)
    with open(path, "ab") as f:
        f.write(b"\x01\x02\x03")  # partial 8-byte record
    with pytest.raises(CorruptStoreError) as ei:
        open_edge_store(path)
    msg = str(ei.value)
    assert "trailing partial record" in msg and str(good) in msg


def test_sharded_store_manifest_validation(tmp_path):
    import json

    d = str(tmp_path / "shards")
    write_shards(d, _edges(), shard_edges=E // 4)
    assert open_edge_store(d).n_edges == E  # clean manifest opens fine

    man = Path(d) / "manifest.json"
    doc = json.loads(man.read_text())
    doc["shards"][1]["edges"] += 7
    man.write_text(json.dumps(doc))
    with pytest.raises(CorruptStoreError, match="shard"):
        open_edge_store(d)

    doc["shards"][1]["edges"] -= 7
    missing = Path(d) / doc["shards"][0]["file"]
    man.write_text(json.dumps(doc))
    missing.unlink()
    with pytest.raises(CorruptStoreError, match="missing"):
        open_edge_store(d)


# -------------------------------------------------- FA2 divergence sentinel


def _layout_inputs(poison: bool):
    from repro.resilience import poison_weights

    rng = np.random.default_rng(3)
    n = 40
    e = rng.integers(0, n, (120, 2), dtype=np.int32)
    w = np.abs(rng.normal(1.0, 0.2, 120)).astype(np.float32)
    if poison:
        w = poison_weights(w, k=4, seed=1)
    mass = np.ones(n, np.float32)
    return e, w, mass, n


def test_nan_guard_off_is_bit_identical_when_clean():
    e, w, mass, n = _layout_inputs(poison=False)
    cfg_off = fa2.FA2Config(iterations=20)
    cfg_on = fa2.FA2Config(iterations=20, nan_guard=True)
    p_off, tr_off, _ = fa2.layout(e, w, mass, n, cfg_off)
    p_on, tr_on, _ = fa2.layout(e, w, mass, n, cfg_on)
    assert np.asarray(p_off).tobytes() == np.asarray(p_on).tobytes()
    assert fa2.recovery_count(tr_on) == 0


def test_nan_guard_recovers_from_poisoned_forces():
    e, w, mass, n = _layout_inputs(poison=True)
    p_off, _, _ = fa2.layout(e, w, mass, n, fa2.FA2Config(iterations=20))
    assert not np.isfinite(np.asarray(p_off)).all()  # unguarded diverges
    p_on, tr_on, _ = fa2.layout(
        e, w, mass, n, fa2.FA2Config(iterations=20, nan_guard=True))
    assert np.isfinite(np.asarray(p_on)).all()  # guarded stays finite
    assert fa2.recovery_count(tr_on) > 0
    # recovery rows never satisfy the adaptive stop (regression: a -1
    # sentinel row must not read as "converged")
    cfg = fa2.FA2Config(iterations=20, nan_guard=True, stop_tolerance=1e9,
                        min_iterations=1)
    _, tr, iters = fa2.layout(e, w, mass, n, cfg)
    assert fa2.recovery_count(tr[:int(iters)]) == int(iters)
    # tol ≤ 1 is the sharp case: a recovery row [-1,-1,s] satisfies
    # row[0] <= tol*row[1] (-1 <= -tol), so without the row[0] >= 0 guard
    # the layout froze right after the first rollback
    cfg = fa2.FA2Config(iterations=20, nan_guard=True, stop_tolerance=0.5,
                        min_iterations=1)
    _, tr, iters = fa2.layout(e, w, mass, n, cfg)
    assert int(iters) == 20, "layout froze on a nan_guard recovery row"
    assert fa2.recovery_count(tr) == 20


# ------------------------------------------------- tile-engine degradation


@pytest.fixture(scope="module")
def pyramid():
    from repro.graph import mode_degree, planted_partition
    from repro.serve.tiles import TileConfig, TilePyramid

    edges, _ = planted_partition(150, 4, 0.3, 0.01, seed=1)
    cfg = default_config(150, len(edges), mode_degree(edges, 150),
                         iterations=5, s_cap=32)
    result = biggraphvis(edges, 150, cfg)
    return TilePyramid(result, TileConfig(tile_size=64, depth=2))


def test_tile_render_failure_isolated_and_not_cached(pyramid):
    from repro.serve.tiles import TileEngine, TileRequest, error_tile

    eng = TileEngine(pyramid, slots=4)
    specs = list(pyramid.specs())
    bad, good = specs[1], specs[2]
    orig = pyramid.render_tile
    before = REGISTRY.counter("errors.failed_tiles").value
    try:
        pyramid.render_tile = lambda s: (_ for _ in ()).throw(
            RuntimeError("render boom")) if s == bad else orig(s)
        rb, rg = TileRequest(bad), TileRequest(good)
        eng.submit(rb)
        eng.submit(rg)
        eng.tick()
        # the failing spec is isolated: its waiter gets the error tile,
        # the healthy spec in the same batch still renders
        assert rb.done and rb.failed
        np.testing.assert_array_equal(rb.tile, error_tile(64))
        assert rg.done and not rg.failed
        assert eng.failed == 1
        assert REGISTRY.counter("errors.failed_tiles").value == before + 1
        assert bad not in eng.cache and good in eng.cache
    finally:
        pyramid.render_tile = orig
    # transient failure: the next request re-renders successfully
    req = TileRequest(bad)
    eng.submit(req)
    eng.tick()
    assert req.done and not req.failed


def test_tile_engine_sheds_overdue_requests(pyramid):
    from repro.serve.tiles import TileEngine, TileRequest

    eng = TileEngine(pyramid, slots=4, deadline_s=0.005)
    req = TileRequest(list(pyramid.specs())[3])
    eng.submit(req)
    time.sleep(0.02)
    done = eng.tick()
    assert req in done and req.failed and req.tile is not None
    assert eng.shed == 1
    with pytest.raises(ValueError, match="deadline_s"):
        TileEngine(pyramid, deadline_s=0.0)


# --------------------------------------------------------- errors.* surface


def test_error_counters_registered_and_dumped():
    reg = MetricsRegistry()
    ensure_error_counters(reg)
    txt = reg.dump_text(prefix="errors.")
    for name in ERROR_COUNTERS:
        assert f"{name} 0" in txt
    # idempotent and non-destructive
    reg.counter("errors.io_retries").inc(3)
    ensure_error_counters(reg)
    assert reg.counter("errors.io_retries").value == 3
