import os
import sys

# Tests are documented to run with PYTHONPATH=src; this makes them robust
# without it. Do NOT set XLA_FLAGS here — smoke tests must see 1 device;
# only launch/dryrun.py forces 512 host devices (and runs out-of-process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
