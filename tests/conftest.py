import os
import sys

# Tests are documented to run with PYTHONPATH=src; this makes them robust
# without it. Do NOT set XLA_FLAGS here — smoke tests must see 1 device;
# only launch/dryrun.py forces 512 host devices (and runs out-of-process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The property tests use hypothesis (a dev-extra dependency — CI installs it
# via `pip install -e .[dev]`). Environments without it still run the whole
# suite through this minimal deterministic stand-in: @given replays a fixed
# spread of examples per strategy instead of searching. Only the API surface
# the suite uses (given / settings / strategies.integers / strategies.floats)
# is provided.
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    import random
    import types

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def examples(self, rng, k):
            vals = [self.lo, self.hi, (self.lo + self.hi) // 2]
            vals += [rng.randint(self.lo, self.hi) for _ in range(max(0, k - 3))]
            return vals[:k]

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def examples(self, rng, k):
            vals = [self.lo, self.hi, 0.5 * (self.lo + self.hi)]
            vals += [rng.uniform(self.lo, self.hi) for _ in range(max(0, k - 3))]
            return vals[:k]

    def _given(*strategies):
        def deco(fn):
            # Zero-arg wrapper: pytest must not mistake strategy-filled
            # parameters for fixtures.
            def run():
                rng = random.Random(fn.__qualname__)
                cols = [s.examples(rng, 5) for s in strategies]
                for args in zip(*cols):
                    fn(*args)

            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run

        return deco

    def _settings(**_kwargs):
        return lambda fn: fn

    _stub = types.ModuleType("hypothesis")
    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.integers = lambda lo, hi: _Integers(lo, hi)
    _strategies.floats = lambda lo, hi, **_kw: _Floats(lo, hi)
    _stub.given = _given
    _stub.settings = _settings
    _stub.strategies = _strategies
    _stub.__is_repro_stub__ = True
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies
