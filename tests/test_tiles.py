"""Tile-pyramid service: LRU cache accounting, pyramid addressing,
served-tile bit-identity vs direct renders (pyramid and drill-down),
zero-recompile steady state, and engine tick batching."""
import numpy as np
import pytest

from repro.core import biggraphvis, default_config, full_layout_colored
from repro.graph import mode_degree, planted_partition
from repro.render import RenderConfig, render_arrays
from repro.serve.tiles import (
    DrillSpec,
    TileCache,
    TileConfig,
    TileEngine,
    TilePyramid,
    TileRequest,
    TileSpec,
    jit_compile_count,
    synthetic_trace,
)

N, COMMUNITIES = 300, 6


@pytest.fixture(scope="module")
def scene():
    edges, _ = planted_partition(N, COMMUNITIES, 0.3, 0.01, seed=1)
    cfg = default_config(
        N, len(edges), mode_degree(edges, N), iterations=10, s_cap=64
    )
    result = biggraphvis(edges, N, cfg)
    return edges, cfg, result


@pytest.fixture(scope="module")
def pyramid(scene):
    edges, cfg, result = scene
    return TilePyramid(
        result,
        TileConfig(tile_size=64, depth=2, drill_iterations=5),
        source=edges,
        bgv_cfg=cfg,
    )


# -- TileCache ---------------------------------------------------------------


def _tile(fill=0):
    return np.full((2, 2), fill, np.uint8)  # 4 bytes


def test_cache_lru_eviction_order_and_accounting():
    cache = TileCache(capacity_bytes=8)  # room for two 4-byte tiles
    cache.put("a", _tile(1))
    cache.put("b", _tile(2))
    assert cache.get("a")[0, 0] == 1  # freshens "a": "b" is now LRU
    cache.put("c", _tile(3))  # evicts "b"
    assert cache.keys() == ["a", "c"]
    assert cache.get("b") is None
    assert (cache.hits, cache.misses, cache.evictions) == (1, 1, 1)
    assert cache.bytes == 8 and len(cache) == 2
    assert cache.hit_rate == 0.5


def test_cache_replace_same_key_updates_bytes():
    cache = TileCache(capacity_bytes=64)
    cache.put("k", _tile())
    cache.put("k", np.zeros((4, 4), np.uint8))  # 16 bytes, same key
    assert len(cache) == 1 and cache.bytes == 16
    assert cache.evictions == 0


def test_cache_zero_capacity_caches_nothing():
    cache = TileCache(capacity_bytes=0)
    cache.put("k", _tile())
    assert len(cache) == 0 and cache.bytes == 0
    assert cache.get("k") is None


def test_cache_contains_is_stats_neutral():
    cache = TileCache(capacity_bytes=64)
    cache.put("k", _tile())
    assert "k" in cache and "z" not in cache
    assert cache.hits == 0 and cache.misses == 0


# -- pyramid addressing ------------------------------------------------------


def test_level0_viewport_is_world_bounds(pyramid):
    assert pyramid.tile_viewport(0, 0, 0) == pytest.approx(pyramid.bounds)


def test_level1_quadrants_partition_bounds(pyramid):
    bx0, by0, bx1, by1 = pyramid.bounds
    mx, my = (bx0 + bx1) / 2, (by0 + by1) / 2
    # y=0 is the TOP row (max world y): raster order, world y-up.
    assert pyramid.tile_viewport(1, 0, 0) == pytest.approx((bx0, my, mx, by1))
    assert pyramid.tile_viewport(1, 1, 1) == pytest.approx((mx, by0, bx1, my))
    with pytest.raises(ValueError):
        pyramid.tile_viewport(1, 2, 0)


def test_specs_enumerates_level_major(pyramid):
    specs = list(pyramid.specs())
    assert len(specs) == 1 + 4
    assert specs[0] == TileSpec(0, 0, 0)
    assert specs[1] == TileSpec(1, 0, 0)  # then x-major within a row


# -- bit-identity ------------------------------------------------------------


def test_served_tile_bit_identical_to_direct_render(pyramid):
    engine = TileEngine(pyramid, cache_bytes=1 << 20, slots=4)
    for spec in (TileSpec(0, 0, 0), TileSpec(1, 1, 0)):
        served = engine.request(spec)
        direct, _ = render_arrays(
            pyramid.result.positions,
            np.sqrt(np.maximum(np.asarray(pyramid.result.sizes), 0.0)),
            pyramid.result.groups,
            np.asarray(pyramid.result.supergraph.edges),
            edge_weights=np.asarray(pyramid.result.supergraph.weights),
            cfg=pyramid.render_config(spec),
        )
        assert served.shape == (64, 64, 3)
        assert np.array_equal(served, direct)
        # And a cache hit returns the same buffer content.
        assert np.array_equal(engine.request(spec), direct)


def test_drill_tile_bit_identical_to_direct_composition(scene, pyramid):
    """A served drill tile equals an independently derived
    full_layout_colored + fitted render of the same community (the member
    mask and id remap are recomputed here, not via community_subgraph)."""
    edges, cfg, result = scene
    community = int(pyramid.drillable_communities()[0])
    served = pyramid.render_tile(DrillSpec(community))

    labels = np.asarray(result.labels)
    members = np.nonzero(labels == community)[0]
    e = np.asarray(edges)
    internal = e[(labels[e[:, 0]] == community)
                 & (labels[e[:, 1]] == community)]
    remap = {int(v): i for i, v in enumerate(members)}
    sub = np.array(
        [[remap[int(u)], remap[int(v)]] for u, v in internal], np.int32
    )
    pos, groups = full_layout_colored(sub, len(members), cfg, iterations=5)
    direct, _ = render_arrays(
        pos,
        np.full(len(members), 2.0, np.float32),
        groups,
        sub,
        cfg=RenderConfig(width=64, height=64),
    )
    assert np.array_equal(served, direct)


def test_drill_requires_source_and_cfg(scene):
    _, _, result = scene
    bare = TilePyramid(result, TileConfig(tile_size=64, depth=1))
    with pytest.raises(RuntimeError, match="source"):
        bare.render_tile(DrillSpec(0))
    assert len(bare.drillable_communities()) == 0


def test_drill_rejects_empty_community(pyramid):
    labels = np.asarray(pyramid.result.labels)
    empty = next(
        c for c in range(len(pyramid.result.sizes))
        if not np.any(labels == c)
    )
    with pytest.raises(ValueError, match="nothing to drill"):
        pyramid.render_tile(DrillSpec(empty))


def test_render_tile_rejects_unknown_spec(pyramid):
    with pytest.raises(TypeError):
        pyramid.render_tile("level0")


# -- recompile meter ---------------------------------------------------------


def test_rerender_triggers_no_recompile(pyramid):
    for spec in pyramid.specs():
        pyramid.render_tile(spec)  # warm every fixed-shape jit entry
    c0 = jit_compile_count()
    for spec in pyramid.specs():
        pyramid.render_tile(spec)
    assert jit_compile_count() - c0 == 0


# -- engine ------------------------------------------------------------------


def test_engine_slot_cap_and_duplicate_collapse(pyramid):
    engine = TileEngine(pyramid, cache_bytes=1 << 20, slots=2)
    specs = [TileSpec(1, 0, 0), TileSpec(1, 0, 0), TileSpec(1, 1, 0),
             TileSpec(1, 0, 1)]
    reqs = [TileRequest(s) for s in specs]
    for r in reqs:
        assert engine.submit(r)
    assert engine.n_pending == 4
    done = engine.tick()
    # Two slots, but the duplicate collapses: 3 requests complete off 2
    # renders; the 4th distinct address waits for the next tick.
    assert len(done) == 3 and engine.rendered == 2
    assert engine.n_pending == 1
    assert engine.tick() and all(r.done for r in reqs)
    assert all(r.tile is not None and not r.hit for r in reqs)
    assert all(r.latency_s > 0 for r in reqs)

    # Resubmitting any of them is now a cache hit: done before tick.
    hit = TileRequest(specs[0])
    engine.submit(hit)
    assert hit.done and hit.hit and engine.n_pending == 0
    assert engine.tick() == []


def test_engine_warmup_fills_cache_and_is_idempotent(pyramid):
    engine = TileEngine(pyramid, cache_bytes=1 << 20, slots=4)
    n = engine.warmup()
    assert n == len(list(pyramid.specs())) == len(engine.cache)
    assert engine.warmup() == 0  # everything already cached
    assert engine.cache.misses == 0  # warmup probes are stats-neutral


def test_engine_rejects_bad_slots(pyramid):
    with pytest.raises(ValueError):
        TileEngine(pyramid, slots=0)


def test_synthetic_trace_deterministic_and_in_range(pyramid):
    a = synthetic_trace(pyramid, 200, seed=5)
    b = synthetic_trace(pyramid, 200, seed=5)
    assert a == b
    assert len(a) == 200
    drillable = set(int(c) for c in pyramid.drillable_communities()[:8])
    for spec in a:
        if isinstance(spec, DrillSpec):
            assert spec.community in drillable
        else:
            n = pyramid.n_tiles(spec.level)
            assert 0 <= spec.level < pyramid.cfg.depth
            assert 0 <= spec.x < n and 0 <= spec.y < n
    assert synthetic_trace(pyramid, 200, seed=6) != a
