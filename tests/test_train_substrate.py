"""Training substrate: AdamW (fp32 + int8 states), gradient compression,
microbatch accumulation, checkpoint/restore + elastic resharding,
preemption handling, straggler watchdog."""
import os
import signal

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt
from repro.train.compression import compress_decompress, topk_sparsify
from repro.train.fault_tolerance import CheckpointManager, ElasticPlan
from repro.train.train_loop import StepWatchdog, TrainConfig, make_train_step


def quad_loss(params, batch):
    err = params["w"] - batch["target"]
    return jnp.sum(err * err)


def _params():
    return {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 512)), jnp.float32)}


def test_adamw_converges_quadratic():
    params = _params()
    batch = {"target": jnp.zeros((8, 512))}
    tcfg = TrainConfig(adamw=opt.AdamWConfig(lr=0.05))
    step = jax.jit(make_train_step(quad_loss, tcfg))
    state = opt.init_opt_state(params, tcfg.adamw)
    for _ in range(200):
        params, state, m = step(params, state, batch)
    assert float(m["loss"]) < 1e-2


def test_adamw_int8_tracks_fp32():
    """8-bit momentum + factored-v must converge like fp32 on a quadratic.
    (Straight int8 v diverges — that failure drove the factored design;
    see optimizer.py docstring.)"""
    batch = {"target": jnp.zeros((8, 512))}
    trajs = {}
    for bits in (32, 8):
        params = _params()
        tcfg = TrainConfig(adamw=opt.AdamWConfig(lr=0.05, state_bits=bits))
        step = jax.jit(make_train_step(quad_loss, tcfg))
        state = opt.init_opt_state(params, tcfg.adamw)
        losses = []
        for _ in range(150):
            params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
        trajs[bits] = losses
    assert trajs[8][-1] < 0.05 * trajs[8][0], trajs[8][-1]
    assert trajs[32][-1] < 0.05 * trajs[32][0]


def test_abstract_opt_state_matches_init():
    params = _params()
    for bits in (32, 8):
        cfg = opt.AdamWConfig(state_bits=bits)
        real = opt.init_opt_state(params, cfg)
        abstract = opt.abstract_opt_state(
            jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
            cfg,
        )
        real_s = jax.tree_util.tree_map(lambda x: (x.shape, str(x.dtype)), real)
        abs_s = jax.tree_util.tree_map(lambda x: (x.shape, str(x.dtype)), abstract)
        assert real_s == abs_s


def test_microbatch_accumulation_matches_full_batch():
    rng = np.random.default_rng(3)
    params = {"w": jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)}
    batch = {
        "x": jnp.asarray(rng.standard_normal((4, 64)), jnp.float32),
        "y": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
    }

    def loss(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    outs = {}
    for mb in (0, 2):
        tcfg = TrainConfig(adamw=opt.AdamWConfig(lr=0.1), microbatch=mb)
        step = jax.jit(make_train_step(loss, tcfg))
        state = opt.init_opt_state(params, tcfg.adamw)
        p2, _, m = step(params, state, batch)
        outs[mb] = np.asarray(p2["w"])
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_int8_roundtrip_error_bounded(seed):
    """Row-wise int8: |err| ≤ half a quantization step (row_max/127/2)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((3, 512)).astype(np.float32) * 10)
    out = compress_decompress(g)
    err = np.abs(np.asarray(out) - np.asarray(g))
    scale = np.abs(np.asarray(g)).max(-1, keepdims=True) / 127.0
    assert (err <= scale * 0.51 + 1e-6).all()


def test_topk_error_feedback_preserves_mass():
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    err = jnp.zeros_like(g)
    kept, err2 = topk_sparsify(g, err, k_frac=0.1)
    # decomposition: kept + error == original
    np.testing.assert_allclose(np.asarray(kept + err2), np.asarray(g), rtol=1e-6)
    assert float((np.asarray(kept) != 0).mean()) <= 0.11


def test_checkpoint_roundtrip_and_prune(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    for step in (1, 2, 3, 4):
        ckpt_lib.save(str(tmp_path), step, tree, extra={"x": step}, keep=2)
    assert ckpt_lib.latest_step(str(tmp_path)) == 4
    # pruned to last 2
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) == 2
    like = jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, meta = ckpt_lib.restore(str(tmp_path), 4, like)
    assert meta["x"] == 4
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10, dtype=np.float32))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_elastic_reshard(tmp_path):
    """Save on one 'mesh', restore onto a different sharding (elastic)."""
    mesh1 = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt_lib.save(str(tmp_path), 7, tree)
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    shardings = {"w": NamedSharding(mesh1, P("data", None))}
    restored, _ = ckpt_lib.restore(str(tmp_path), 7, like, shardings)
    assert restored["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_manager_restores_latest_and_preemption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every_steps=5)
    tree = {"w": jnp.ones(4)}
    mgr.save(5, tree)
    mgr.save(10, {"w": jnp.full(4, 2.0)})
    step, restored, _ = mgr.restore_latest({"w": jax.ShapeDtypeStruct((4,), jnp.float32)})
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full(4, 2.0))
    # preemption signal forces a save at the next opportunity
    mgr.install_preemption_handler()
    os.kill(os.getpid(), signal.SIGTERM)
    assert mgr.should_save(12)  # not a multiple of every_steps — preempted


def test_elastic_plan_batch_schedule():
    plan = ElasticPlan(global_batch=256, n_pods=2)
    assert plan.batch_per_pod() == 128
    s0 = plan.data_shard_for(0, step=3)
    s1 = plan.data_shard_for(1, step=3)
    assert s0 == (0, 128) and s1 == (128, 128)
    with pytest.raises(AssertionError):
        ElasticPlan(global_batch=255, n_pods=2).batch_per_pod()


def test_watchdog_flags_straggler():
    import time

    wd = StepWatchdog(threshold=3.0, warmup=2)
    for _ in range(3):
        wd.start()
        time.sleep(0.01)
        assert not wd.stop()
    wd.start()
    time.sleep(0.08)
    assert wd.stop()
