"""Sharding rules + step builders + HLO analysis + data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, all_cells
from repro.configs.base import input_specs
from repro.launch.hlo_analysis import analyze_hlo
from repro.sharding.rules import (
    PROFILES,
    block_chunk_spec,
    filter_spec,
    linear_axis_index,
    row_chunk_spec,
    spec_for,
)


@pytest.fixture(scope="module")
def mesh22():
    return jax.make_mesh((1, 1), ("data", "model"))


def _mesh_like(shape, names):
    # an abstract mesh for rule resolution only (no devices needed):
    # jax ≥ 0.5 takes (axis_sizes, axis_names), older takes ((name, size), ...)
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(names))
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


def test_spec_for_divisibility_fallback():
    mesh = _mesh_like((16, 16), ("data", "model"))
    prof = PROFILES["tp"]
    # heads=8 on a 16-way model axis must degrade to None (gemma3 case)
    s = spec_for((34, 2560, 8, 256), ("layer", "embed", "heads", "head_dim"), prof, mesh)
    assert s == P(None, None, None, None) or s[2] is None
    # heads=32 shards fine (yi case); embed falls to data
    s = spec_for((32, 4096, 32, 128), ("layer", "embed", "heads", "head_dim"), prof, mesh)
    assert s[2] == ("model",) or s[2] == "model"
    assert s[1] in (("data",), "data")


def test_spec_for_no_axis_reuse():
    mesh = _mesh_like((16, 16), ("data", "model"))
    prof = PROFILES["tp"]
    # expert takes model first; mlp must NOT reuse it
    s = spec_for((61, 384, 7168, 2048), ("layer", "expert", "embed", "mlp"), prof, mesh)
    flat = [a for entry in s if entry for a in (entry if isinstance(entry, tuple) else (entry,))]
    assert len(flat) == len(set(flat))
    assert s[1] in ("model", ("model",))


def test_filter_spec_drops_missing_axes():
    mesh = _mesh_like((16, 16), ("data", "model"))
    s = filter_spec(P(("pod", "data"), None, "model"), mesh)
    assert s == P(("data",), None, "model")


def test_filter_spec_multi_axis_entries():
    mesh = _mesh_like((2, 8, 16), ("pod", "data", "model"))
    # every axis present: spec passes through untouched
    s = filter_spec(P(("pod", "data"), None, "model"), mesh)
    assert s == P(("pod", "data"), None, "model")
    # none of an entry's axes present → that entry degrades to None
    s = filter_spec(P(("expert",), "replica", None), mesh)
    assert s == P(None, None, None)


def test_spec_for_non_divisible_on_multi_axis_extent():
    # embed maps to ("pod", "data") = 32-way; 4096 % 32 == 0 shards,
    # 4100 % 32 != 0 degrades that dim (and only that dim) to None.
    mesh = _mesh_like((2, 16, 16), ("pod", "data", "model"))
    prof = PROFILES["tp"]
    s = spec_for((4096, 64), ("embed", "heads"), prof, mesh)
    assert s[0] == ("pod", "data")
    s = spec_for((4100, 64), ("embed", "heads"), prof, mesh)
    assert s[0] is None
    assert s[1] in ("model", ("model",))


def test_spec_for_axis_reuse_across_mapped_tuples():
    # "embed" already consumed "data"; a later dim whose mapping is only
    # "data" must not reuse it even though its size divides the extent.
    mesh = _mesh_like((4, 4), ("data", "model"))
    prof = {"embed": ("data",), "mlp": ("data",)}
    s = spec_for((64, 64), ("embed", "mlp"), prof, mesh)
    assert s == P("data", None)


def test_chunk_specs_cover_all_mesh_axes():
    for shape, names in (((8,), ("data",)), ((2, 4), ("data", "model"))):
        mesh = _mesh_like(shape, names)
        assert row_chunk_spec(mesh) == P(tuple(names), None)
        assert block_chunk_spec(mesh) == P(None, tuple(names), None)


def test_stream_mesh_and_linear_axis_index():
    """make_stream_mesh over the local devices; linear_axis_index inside a
    shard_map body enumerates shards in the row order ``all_gather`` tiles
    them (the identity the sharded engine's row slicing rests on)."""
    from repro.kernels.compat import shard_map_compat
    from repro.launch.mesh import make_stream_mesh

    mesh = make_stream_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.size == jax.device_count()
    assert make_stream_mesh(devices=1).size == 1  # cap honored

    axes = tuple(mesh.axis_names)
    sizes = tuple(mesh.shape[a] for a in axes)

    def body():
        idx = linear_axis_index(axes, sizes)
        return jax.lax.all_gather(idx, axes, tiled=False)

    got = shard_map_compat(body, mesh, in_specs=(), out_specs=P())()
    np.testing.assert_array_equal(np.asarray(got), np.arange(mesh.size))


def test_host_mesh_compatible_with_stream_chunk_specs():
    """The production-named host mesh must accept the chunk placements and
    the tp profile (the same code paths the real meshes run)."""
    from jax.sharding import NamedSharding
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    assert set(mesh.axis_names) == {"data", "model"}
    arr = jax.device_put(
        jnp.zeros((4, 2), jnp.int32), NamedSharding(mesh, row_chunk_spec(mesh))
    )
    assert arr.shape == (4, 2)
    s = spec_for((4096, 64, 16), ("embed", "heads", "head_dim"),
                 PROFILES["tp"], mesh)
    NamedSharding(mesh, filter_spec(s, mesh))  # constructible, no raise


def test_all_runnable_cells_have_specs_and_builders():
    """Every non-skipped cell must produce abstract inputs (cheap check —
    the full lower+compile proof is launch/dryrun.py). The biggraphvis
    cells build their abstract args inside launch/steps.py instead."""
    n_run = n_skip = n_bgv = 0
    for arch, shape in all_cells():
        if shape.skip:
            n_skip += 1
            continue
        if arch.family == "bgv":
            n_bgv += 1
            continue
        specs = input_specs(arch, shape)
        assert all(hasattr(v, "shape") for v in specs.values())
        n_run += 1
    assert n_run == 36  # the assigned 40 minus 4 documented skips
    assert n_skip == 4  # long_500k on the pure full-attention archs
    assert n_bgv == 4  # the paper's own workload cells


def test_host_mesh_step_builder_runs_real_data():
    """build_step on a 1×1 mesh with REAL (tiny-shape) data: the same
    sharded step functions that the dry-run lowers actually execute."""
    from dataclasses import replace
    from repro.launch.steps import build_step
    from repro.configs.base import ShapeSpec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    arch = get_config("granite-moe-1b-a400m")
    small_model = replace(arch.model, n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, head_dim=16, d_ff=64, vocab=97,
                          vocab_padded=112, q_chunk=0,
                          moe=replace(arch.model.moe, n_experts=4, top_k=2,
                                      d_ff_expert=32))
    arch = replace(arch, model=small_model,
                   shapes={"train_4k": ShapeSpec("train_4k", "train",
                                                 seq_len=16, global_batch=2)})
    shape = arch.shapes["train_4k"]
    built = build_step(arch, shape, mesh)
    from repro.models.param import init_params
    from repro.models import transformer as tfm
    from repro.train.optimizer import AdamWConfig, init_opt_state

    params = init_params(jax.random.PRNGKey(0), tfm.param_specs(small_model))
    state = init_opt_state(params, AdamWConfig(state_bits=arch.opt_state_bits))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(1, 97, (2, 16)), jnp.int32),
        "loss_mask": jnp.ones((2, 16), jnp.float32),
    }
    with mesh:
        step = jax.jit(built.fn, in_shardings=built.in_shardings,
                       out_shardings=built.out_shardings,
                       donate_argnums=built.donate)
        params, state, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_hlo_analysis_loop_adjustment():
    """The analyzer must multiply scan-body dots by the trip count."""
    def scanned(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    compiled = jax.jit(scanned).lower(w, x).compile()
    stats = analyze_hlo(compiled.as_text())
    per_dot = 2 * 32 * 128 * 128
    assert stats.n_whiles >= 1
    assert abs(stats.dot_flops - 8 * per_dot) / (8 * per_dot) < 0.05, stats.dot_flops


def test_hlo_analysis_collectives():
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding

    def f(x):
        return jax.lax.with_sharding_constraint(x * 2, NamedSharding(mesh, P()))

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    with mesh:
        compiled = jax.jit(
            f, in_shardings=NamedSharding(mesh, P("d", None))
        ).lower(x).compile()
    stats = analyze_hlo(compiled.as_text())
    assert stats.collective_bytes >= 0  # single-device: no collectives required


def test_lm_stream_deterministic_and_sharded():
    from repro.data.pipeline import LMStream

    s = LMStream(vocab=100, batch=8, seq_len=16, seed=3)
    a = s.batch_at(5)
    b = s.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch_at(6)
    assert (a["tokens"] != c["tokens"]).any()
    # host shards tile the global batch exactly
    left = s.batch_at(5, shard=(0, 4))["tokens"]
    right = s.batch_at(5, shard=(4, 4))["tokens"]
    np.testing.assert_array_equal(np.concatenate([left, right]), a["tokens"])
