"""Adaptive FA2 convergence (stop_tolerance/min_iterations), speed-controller
invariants, structured inits, the precomputed-grid ``step`` path, and the
repro/quality metric suite that gates the convergence claim."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import forceatlas2 as fa2
from repro.graph import pad_edges, planted_partition
from repro.graph.utils import degrees
from repro.quality import (
    bfs_hops,
    crossing_proxy,
    edge_length_cv,
    layout_quality,
    neighborhood_preservation,
    sampled_stress,
)
from repro.quality.metrics import _csr


def _inputs(n=160, seed=8, communities=4):
    edges_np, _ = planted_partition(n, communities, 0.3, 0.02, seed=seed)
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    mass = degrees(edges, n).astype(jnp.float32) + 1.0
    w = jnp.ones(edges.shape[0], jnp.float32)
    return edges_np, edges, w, mass, n


# ------------------------------------------------------------ adaptive stop

def test_adaptive_stop_prefix_bit_identical():
    """A run frozen at min_iterations is bitwise the fixed run of that
    length: live rows match, frozen rows trace zero, positions agree."""
    _, edges, w, mass, n = _inputs()
    base = fa2.FA2Config(iterations=40, repulsion="exact", use_radii=False)
    # An always-true tolerance isolates the freeze machinery: the stop
    # fires the moment min_iterations allows.
    adapt = dataclasses.replace(base, stop_tolerance=1e9, min_iterations=12)
    pos_a, trace_a, it_a = fa2.layout(edges, w, mass, n, adapt)
    assert int(it_a) == 12
    fixed = dataclasses.replace(base, iterations=12)
    pos_f, trace_f, it_f = fa2.layout(edges, w, mass, n, fixed)
    assert int(it_f) == 12
    assert np.array_equal(np.asarray(pos_a), np.asarray(pos_f))
    trace_a = np.asarray(trace_a)
    assert np.array_equal(trace_a[:12], np.asarray(trace_f))
    assert (trace_a[12:] == 0.0).all()


def test_adaptive_machinery_neutral_when_never_triggered():
    """With a tolerance too tight to ever fire, the lax.cond-wrapped body
    reproduces the non-adaptive scan bit for bit and reports a full run."""
    _, edges, w, mass, n = _inputs(n=120, seed=3)
    base = fa2.FA2Config(iterations=15, repulsion="exact", use_radii=False)
    never = dataclasses.replace(base, stop_tolerance=1e-12, min_iterations=0)
    pos_b, trace_b, it_b = fa2.layout(edges, w, mass, n, base)
    pos_n, trace_n, it_n = fa2.layout(edges, w, mass, n, never)
    assert int(it_b) == int(it_n) == 15
    assert np.array_equal(np.asarray(pos_b), np.asarray(pos_n))
    assert np.array_equal(np.asarray(trace_b), np.asarray(trace_n))


def test_adaptive_stop_grid_backend_with_carry():
    """The adaptive carry composes with the grid (cell, order) carry."""
    _, edges, w, mass, n = _inputs(n=180, seed=6)
    base = fa2.FA2Config(iterations=20, repulsion="grid", grid_size=8,
                         grid_window=8, grid_rebuild=2, use_radii=False)
    adapt = dataclasses.replace(base, stop_tolerance=1e9, min_iterations=6)
    pos_a, trace_a, it_a = fa2.layout(edges, w, mass, n, adapt)
    assert int(it_a) == 6
    fixed = dataclasses.replace(base, iterations=6)
    pos_f, _, _ = fa2.layout(edges, w, mass, n, fixed)
    assert np.array_equal(np.asarray(pos_a), np.asarray(pos_f))
    assert (np.asarray(trace_a)[6:] == 0.0).all()


def test_pipeline_reports_layout_iterations():
    """biggraphvis threads the adaptive knobs to the supergraph layout and
    records the live iteration count in timings."""
    from repro.core.pipeline import biggraphvis, default_config
    from repro.graph import mode_degree

    n = 150
    edges_np, _ = planted_partition(n, 5, 0.3, 0.01, seed=3)
    cfg = default_config(n, len(edges_np), mode_degree(edges_np, n),
                         rounds=2, iterations=8, stop_tolerance=1e9,
                         min_iterations=3)
    res = biggraphvis(edges_np, n, cfg)
    assert res.timings["layout_iterations"] == 3
    assert np.isfinite(res.positions).all()


def test_full_layout_colored_adaptive_override():
    """The per-call stop_tolerance/min_iterations overrides reach the
    full-graph layout (a frozen 1-iteration run differs from the default)."""
    from repro.core import default_config, full_layout_colored
    from repro.graph import mode_degree

    n = 120
    edges_np, _ = planted_partition(n, 4, 0.3, 0.01, seed=2)
    cfg = default_config(n, len(edges_np), mode_degree(edges_np, n),
                         rounds=2, iterations=5)
    pos_full, _ = full_layout_colored(edges_np, n, cfg, iterations=30)
    pos_one, _ = full_layout_colored(edges_np, n, cfg, iterations=30,
                                     stop_tolerance=1e9, min_iterations=1)
    assert np.isfinite(pos_one).all()
    assert not np.array_equal(pos_full, pos_one)


# ------------------------------------------------- speed-controller algebra

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64),
       st.floats(0.0, 1e3, allow_nan=False))
def test_apply_speed_invariants(seed, n, prev_gs):
    """FA2 Algorithm 1 controller invariants on arbitrary force fields:
    the displacement cap |Δx| ≤ 10 (speed ≤ 10/|f|), the global-speed
    clamp min(τ·traction/swing, 1.5·prev + 1e-3), the force passthrough,
    and the (g_swing, g_traction, global_speed) trace row."""
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.normal(0, 100, (n, 2)).astype(np.float32))
    prev_f = jnp.asarray(rng.normal(0, 5, (n, 2)).astype(np.float32))
    f = jnp.asarray(rng.normal(0, 5, (n, 2)).astype(np.float32))
    mass = jnp.asarray(rng.uniform(0.5, 4.0, n).astype(np.float32))
    cfg = fa2.FA2Config()
    state = (pos, prev_f, jnp.float32(prev_gs))
    (new_pos, kept_f, gs), row = fa2._apply_speed(state, f, mass, cfg)

    disp = np.linalg.norm(np.asarray(new_pos) - np.asarray(pos), axis=-1)
    assert (disp <= 10.0 * (1.0 + 1e-4) + 1e-6).all()

    swing = np.linalg.norm(np.asarray(f - prev_f, np.float64), axis=-1)
    traction = 0.5 * np.linalg.norm(np.asarray(f + prev_f, np.float64), axis=-1)
    m = np.asarray(mass, np.float64)
    g_sw = float((m * swing).sum()) + 1e-9
    g_tr = float((m * traction).sum())
    expect = min(cfg.jitter_tolerance * g_tr / g_sw, 1.5 * prev_gs + 1e-3)
    assert np.isclose(float(gs), expect, rtol=1e-2, atol=1e-6)
    assert np.array_equal(np.asarray(kept_f), np.asarray(f))
    np.testing.assert_allclose(
        np.asarray(row, np.float64), [g_sw, g_tr, float(gs)],
        rtol=1e-2, atol=1e-8,
    )


def test_apply_speed_zero_force_is_stationary():
    """No force and no history → zero global speed, positions untouched."""
    pos = jnp.asarray(np.random.default_rng(0).normal(0, 10, (5, 2)),
                      jnp.float32)
    zero = jnp.zeros_like(pos)
    state = (pos, zero, jnp.float32(1.0))
    (new_pos, _, gs), row = fa2._apply_speed(
        state, zero, jnp.ones(5, jnp.float32), fa2.FA2Config())
    assert np.array_equal(np.asarray(new_pos), np.asarray(pos))
    assert float(gs) == 0.0
    assert float(row[1]) == 0.0  # no traction either


def test_apply_speed_single_node():
    """n=1 (a one-community supergraph) stays finite and capped."""
    pos = jnp.asarray([[3.0, -4.0]], jnp.float32)
    f = jnp.asarray([[1e6, 0.0]], jnp.float32)  # huge force → cap binds
    state = (pos, jnp.zeros_like(pos), jnp.float32(1.0))
    (new_pos, _, gs), row = fa2._apply_speed(
        state, f, jnp.ones(1, jnp.float32), fa2.FA2Config())
    new_pos = np.asarray(new_pos)
    assert np.isfinite(new_pos).all() and np.isfinite(float(gs))
    assert np.linalg.norm(new_pos - np.asarray(pos)) <= 10.0 * (1 + 1e-4)
    assert np.isfinite(np.asarray(row)).all()


@pytest.mark.parametrize("init", ["random", "degree", "bfs"])
def test_layout_all_isolated_nodes(init):
    """An edgeless graph (every padded slot is trash) must not NaN out —
    repulsion-only dynamics, every init mode."""
    n = 16
    edges = jnp.asarray(pad_edges(np.empty((0, 2), np.int32), 8, n))
    w = jnp.ones(8, jnp.float32)
    mass = jnp.ones(n, jnp.float32)
    cfg = fa2.FA2Config(iterations=5, repulsion="exact", use_radii=False,
                        init=init)
    pos, trace, it = fa2.layout(edges, w, mass, n, cfg)
    assert np.isfinite(np.asarray(pos)).all()
    assert np.isfinite(np.asarray(trace)).all()
    assert int(it) == 5


# ------------------------------------------------------------- init modes

def test_init_modes_deterministic_and_dispatch():
    _, edges, w, mass, n = _inputs(n=96, seed=2)
    for init in ("random", "degree", "bfs"):
        cfg = fa2.FA2Config(init=init, dtype="float32")
        a = fa2.initial_positions(edges, mass, n, cfg)
        b = fa2.initial_positions(edges, mass, n, cfg)
        assert a.shape == (n, 2) and a.dtype == jnp.float32
        assert np.isfinite(np.asarray(a)).all()
        assert np.array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="unknown init"):
        fa2.initial_positions(edges, mass, n, fa2.FA2Config(init="spectral"))


def test_layout_sharded_bit_identical_per_init():
    """layout vs layout_sharded start from the same compiled init, so the
    bit-identity contract survives every init mode (regression: an
    eagerly-computed degree init differed from the traced one in the low
    bits — FMA contraction — and broke sharded bit-identity). On one
    device the sharded call falls back; the shard-smoke CI matrix re-runs
    this with real multi-device meshes (96 divides 2 and 8)."""
    from repro.launch.mesh import make_stream_mesh

    _, edges, w, mass, n = _inputs(n=96, seed=2)
    for init in ("random", "degree", "bfs"):
        cfg = fa2.FA2Config(iterations=4, repulsion="exact", init=init)
        pos, trace, it = fa2.layout(edges, w, mass, n, cfg)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # 1-device mesh warns fallback
            pos_s, trace_s, it_s = fa2.layout_sharded(
                edges, w, mass, n, cfg, make_stream_mesh())
        assert np.array_equal(np.asarray(pos), np.asarray(pos_s)), init
        assert np.array_equal(np.asarray(trace), np.asarray(trace_s)), init
        assert int(it) == int(it_s)


def test_init_degree_places_hubs_centrally():
    n = 50
    mass = jnp.asarray(np.arange(1, n + 1, dtype=np.float32))
    pos = np.asarray(fa2.init_positions_degree(n, mass))
    r = np.linalg.norm(pos, axis=1)
    # Heaviest node sits at the innermost spiral slot.
    assert r[n - 1] == r.min()
    assert r[0] > np.median(r)


def test_init_bfs_groups_communities():
    """Smoothed BFS init starts communities co-located: mean intra-community
    distance well under mean inter-community distance before any FA2 step."""
    n = 300
    edges_np, labels = planted_partition(n, 5, 0.4, 0.002, seed=7)
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    mass = degrees(edges, n).astype(jnp.float32) + 1.0
    pos = np.asarray(fa2.init_positions_bfs(
        edges, mass, n, jax.random.PRNGKey(0)))
    d = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
    same = labels[:, None] == labels[None, :]
    off = ~np.eye(n, dtype=bool)
    assert d[same & off].mean() < 0.8 * d[~same].mean()


# -------------------------------------------- precomputed grid step inputs

def test_step_precomputed_cell_order_parity():
    """step(cell=, order=) with fresh bin_and_sort inputs is bitwise the
    internal-binning step."""
    from repro.kernels.grid import ops as grid_ops

    _, edges, w, mass, n = _inputs(n=180, seed=5)
    cfg = fa2.FA2Config(repulsion="grid", grid_size=8, grid_window=8,
                        use_radii=False)
    rng = np.random.default_rng(1)
    pos = jnp.asarray(rng.uniform(-500, 500, (n, 2)).astype(np.float32))
    radii = jnp.sqrt(mass)
    state = (pos, jnp.zeros_like(pos), jnp.float32(1.0))
    (p1, f1, g1), r1 = fa2.step(state, edges, w, mass, radii, cfg, n)
    cell, order = grid_ops.bin_and_sort(pos, cfg.grid_size)
    (p2, f2, g2), r2 = fa2.step(state, edges, w, mass, radii, cfg, n,
                                cell=cell, order=order)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
    assert np.array_equal(np.asarray(r1), np.asarray(r2))


def test_bgv_layout_cell_threads_grid_binning():
    """The dry-run bgv_layout cell for grid backends takes (cell, order)
    operands and matches a direct fa2.step with the same precomputed
    binning."""
    from repro.configs.base import ArchConfig, ShapeSpec
    from repro.configs.biggraphvis import BGVDryConfig
    from repro.kernels.grid import ops as grid_ops
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_bgv_step

    n, e = 128, 256
    shape = ShapeSpec("t", "bgv_layout", n_nodes=n, n_edges=e)
    mesh = make_host_mesh()
    exact = build_bgv_step(
        ArchConfig("t", "bgv", "gnn", BGVDryConfig()), shape, mesh)
    grid = build_bgv_step(
        ArchConfig("t", "bgv", "gnn",
                   BGVDryConfig(layout_repulsion="grid", layout_grid_size=8,
                                layout_grid_window=8)),
        shape, mesh)
    assert len(grid.abstract_args) == len(exact.abstract_args) + 2
    for extra in grid.abstract_args[-2:]:
        assert extra.shape == (n,) and extra.dtype == jnp.int32

    rng = np.random.default_rng(3)
    pos = jnp.asarray(rng.uniform(-300, 300, (n, 2)).astype(np.float32))
    prev_f = jnp.zeros_like(pos)
    mass = jnp.asarray(rng.uniform(1, 4, n).astype(np.float32))
    radii = jnp.sqrt(mass)
    edges = jnp.asarray(rng.integers(0, n, (e, 2)).astype(np.int32))
    w = jnp.ones(e, jnp.float32)
    cell, order = grid_ops.bin_and_sort(pos, 8)
    got_pos, got_f = grid.fn(pos, prev_f, mass, radii, edges, w, cell, order)
    cfg = fa2.FA2Config(iterations=1, use_radii=True, repulsion="grid",
                        grid_size=8, grid_window=8)
    (want_pos, want_f, _), _ = fa2.step(
        (pos, prev_f, jnp.float32(1.0)), edges, w, mass, radii, cfg, n,
        cell=cell, order=order)
    assert np.array_equal(np.asarray(got_pos), np.asarray(want_pos))
    assert np.array_equal(np.asarray(got_f), np.asarray(want_f))


# --------------------------------------------------------- quality metrics

def _path_graph(n=50):
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    pos = np.stack([np.arange(n, dtype=np.float64), np.zeros(n)], axis=1)
    return edges.astype(np.int32), pos


def test_quality_perfect_path_layout():
    """A path laid out as a unit-spaced line realizes its graph distances
    exactly: zero stress, full neighborhood preservation, uniform edges,
    no crossings."""
    edges, pos = _path_graph()
    n = len(pos)
    assert sampled_stress(pos, edges, n, seed=0) < 1e-6
    assert neighborhood_preservation(pos, edges, n, seed=0) == 1.0
    assert edge_length_cv(pos, edges) < 1e-9
    assert crossing_proxy(pos, edges, seed=0) == 0.0


def test_bfs_hops_on_path():
    edges, _ = _path_graph(20)
    indptr, indices = _csr(edges, 20)
    d = bfs_hops(indptr, indices, 0, 20)
    assert np.array_equal(d, np.arange(20))
    d3 = bfs_hops(indptr, indices, 0, 20, max_hops=3)
    assert (d3[:4] == np.arange(4)).all() and (d3[4:] == -1).all()


def test_sampled_stress_scale_invariant():
    edges_np, _, _, _, n = _inputs(n=200, seed=4)
    rng = np.random.default_rng(0)
    pos = rng.normal(0, 50, (n, 2))
    s1 = sampled_stress(pos, edges_np, n, seed=1)
    s2 = sampled_stress(pos * 37.0, edges_np, n, seed=1)
    assert np.isclose(s1, s2, rtol=1e-9)
    assert 0.0 <= s1 <= 1.0


def test_quality_separates_good_from_random():
    """A community-blob layout scores better than a random scatter on both
    gated metrics — the discriminative power the bench's ratio gate rests
    on."""
    n = 400
    edges_np, labels = planted_partition(n, 8, 0.3, 0.002, seed=9)
    rng = np.random.default_rng(0)
    centers = rng.uniform(-500, 500, (8, 2))
    good = centers[labels] + rng.normal(0, 18, (n, 2))
    rand = rng.uniform(-500, 500, (n, 2))
    q_good = layout_quality(good, edges_np, n, seed=0)
    q_rand = layout_quality(rand, edges_np, n, seed=0)
    assert q_good["neighborhood"] > 2.0 * q_rand["neighborhood"]
    assert q_good["stress"] < q_rand["stress"]


def test_quality_bench_check_rejects_bad_records():
    """The bench's gate actually fails on a quality regression."""
    from benchmarks.quality_bench import _check

    base = [
        {"graph": "g", "arm": "fixed", "iterations_run": 500,
         "stress": 0.2, "neighborhood": 0.25},
        {"graph": "g", "arm": "adaptive", "iterations_run": 200,
         "stress": 0.2, "neighborhood": 0.25},
        {"graph": "g", "arm": "recompile", "repeat_calls": 2,
         "compile_delta": 0},
    ]
    lines = _check([dict(r) for r in base])
    assert any("adaptive stopped" in ln for ln in lines)
    bad = [dict(r) for r in base]
    bad[1]["neighborhood"] = 0.1  # 0.4x the baseline: must trip the bar
    with pytest.raises(AssertionError, match="neighborhood"):
        _check(bad)
    slow = [dict(r) for r in base]
    slow[1]["iterations_run"] = 400  # over the half-cap budget
    with pytest.raises(AssertionError, match="budget"):
        _check(slow)
    recompiled = [dict(r) for r in base]
    recompiled[2]["compile_delta"] = 3
    with pytest.raises(AssertionError, match="recompile"):
        _check(recompiled)


def test_warn_fallback_warns_once_per_reason():
    fa2._FALLBACK_WARNED.clear()
    with pytest.warns(UserWarning, match="reason-a"):
        fa2._warn_fallback("reason-a")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        fa2._warn_fallback("reason-a")  # second time: silent
    with pytest.warns(UserWarning, match="reason-b"):
        fa2._warn_fallback("reason-b")
    fa2._FALLBACK_WARNED.clear()
