"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward/train step on CPU, asserting output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, get_config
from repro.configs.base import input_specs
from repro.configs.gnn_archs import smoke_gnn
from repro.configs.lm_archs import smoke_lm
from repro.configs.sasrec import smoke_sasrec
from repro.models import gnn as gnn_lib
from repro.models import sasrec as sas_lib
from repro.models import transformer as tfm
from repro.models.param import init_params
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import TrainConfig, make_train_step

KEY = jax.random.PRNGKey(0)


def _run_train(loss_fn, params, batch, state_bits=32):
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, state_bits=state_bits))
    step = jax.jit(make_train_step(loss_fn, tcfg))
    state = init_opt_state(params, tcfg.adamw)
    params, state, m = step(params, state, batch)
    loss0 = float(m["loss"])
    params, state, m = step(params, state, batch)
    assert np.isfinite(loss0) and np.isfinite(float(m["loss"]))
    return loss0, float(m["loss"])


# --------------------------------------------------------------- LM family
@pytest.mark.parametrize("arch", ["yi-6b", "mistral-large-123b"])
def test_smoke_dense_lm(arch):
    """Reduced dense GQA transformer (same family as yi/mistral)."""
    cfg = smoke_lm(moe=False)
    params = init_params(KEY, tfm.param_specs(cfg))
    batch = {
        "tokens": jnp.asarray(np.random.randint(1, cfg.vocab, (2, 16)), jnp.int32),
        "loss_mask": jnp.ones((2, 16), jnp.float32),
    }
    loss_fn = functools.partial(tfm.lm_loss, cfg, tfm.Constraints())
    l0, l1 = _run_train(loss_fn, params, batch)
    assert l1 < l0 + 0.5


@pytest.mark.parametrize("arch,bits", [("kimi-k2-1t-a32b", 8), ("granite-moe-1b-a400m", 32)])
def test_smoke_moe_lm(arch, bits):
    """Reduced MoE (same family as kimi/granite), incl. 8-bit Adam for kimi."""
    full = get_config(arch)
    assert full.model.moe is not None
    cfg = smoke_lm(moe=True)
    params = init_params(KEY, tfm.param_specs(cfg))
    batch = {
        "tokens": jnp.asarray(np.random.randint(1, cfg.vocab, (2, 16)), jnp.int32),
        "loss_mask": jnp.ones((2, 16), jnp.float32),
    }
    loss_fn = functools.partial(tfm.lm_loss, cfg, tfm.Constraints())
    l0, l1 = _run_train(loss_fn, params, batch, state_bits=bits)
    assert np.isfinite(l1)


def test_smoke_gemma3_sliding():
    """Reduced 5:1-ish local:global sliding-window arch + decode path."""
    cfg = smoke_lm(moe=False, sliding=True)
    params = init_params(KEY, tfm.param_specs(cfg))
    prefill = jax.jit(tfm.make_prefill(cfg))
    tokens = jnp.asarray(np.random.randint(1, cfg.vocab, (2, 16)), jnp.int32)
    logits = prefill(params, {"tokens": tokens})
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    # decode against a KV cache
    dec = jax.jit(tfm.make_decode_step(cfg))
    cache = {
        k: jnp.zeros(v.shape, v.dtype)
        for k, v in tfm.abstract_kv_cache(cfg, 2, 32).items()
    }
    lg, cache = dec(params, cache, {"tokens": tokens[:, :1], "cur_len": jnp.int32(3)})
    assert lg.shape == (2, 1, cfg.vocab_padded)
    assert bool(jnp.isfinite(lg).all())


def test_sliding_window_masks_old_tokens():
    """A local-only arch must ignore context beyond the window."""
    from dataclasses import replace
    # global_every large ⇒ no global layers: pure local attention.
    cfg = replace(smoke_lm(moe=False, sliding=True), global_every=1000, sliding_window=4)
    params = init_params(KEY, tfm.param_specs(cfg))
    prefill = jax.jit(tfm.make_prefill(cfg))
    t1 = jnp.asarray(np.random.randint(1, cfg.vocab, (1, 16)), jnp.int32)
    t2 = t1.at[:, 0:4].set((t1[:, 0:4] % (cfg.vocab - 1)) + 1)  # perturb far past
    l1 = prefill(params, {"tokens": t1})
    l2 = prefill(params, {"tokens": t2})
    np.testing.assert_allclose(
        np.asarray(l1[:, -1], np.float32), np.asarray(l2[:, -1], np.float32),
        rtol=1e-4, atol=1e-4,
    )


# -------------------------------------------------------------- GNN family
@pytest.mark.parametrize("arch", ["gin-tu", "gat-cora", "meshgraphnet", "graphcast"])
def test_smoke_gnn(arch):
    full = get_config(arch)
    cfg = smoke_gnn(full.model.arch)
    params = init_params(KEY, gnn_lib.param_specs(cfg))
    n, e = 64, 128
    rng = np.random.default_rng(1)
    edges = rng.integers(0, n, (e, 2)).astype(np.int32)
    batch = {
        "feats": jnp.asarray(rng.standard_normal((n, cfg.d_feat)).astype(np.float32)),
        "edges": jnp.asarray(edges),
        "labels": jnp.asarray(rng.integers(0, cfg.n_out, n).astype(np.int32)),
        "mask": jnp.ones(n, jnp.float32),
    }
    loss_fn = functools.partial(gnn_lib.gnn_loss, cfg)
    l0, l1 = _run_train(loss_fn, params, batch)
    assert l1 < l0 + 0.5

    out = gnn_lib.forward(cfg, params, batch)
    assert out.shape == (n, cfg.n_out)
    assert bool(jnp.isfinite(out).all())


def test_smoke_gnn_regression_and_graph_tasks():
    from dataclasses import replace
    rng = np.random.default_rng(2)
    n, e, b = 60, 100, 6
    cfg = replace(smoke_gnn("meshgraphnet"), task="node_reg", n_out=3)
    params = init_params(KEY, gnn_lib.param_specs(cfg))
    batch = {
        "feats": jnp.asarray(rng.standard_normal((n, cfg.d_feat)).astype(np.float32)),
        "edges": jnp.asarray(rng.integers(0, n, (e, 2)).astype(np.int32)),
        "labels": jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32)),
        "mask": jnp.ones(n, jnp.float32),
    }
    loss_fn = functools.partial(gnn_lib.gnn_loss, cfg)
    l0, l1 = _run_train(loss_fn, params, batch)
    assert l1 < l0

    cfg = replace(smoke_gnn("gin"), task="graph_class", n_out=2)
    params = init_params(KEY, gnn_lib.param_specs(cfg))
    batch = {
        "feats": jnp.asarray(rng.standard_normal((n, cfg.d_feat)).astype(np.float32)),
        "edges": jnp.asarray(rng.integers(0, n, (e, 2)).astype(np.int32)),
        "graph_ids": jnp.asarray(np.repeat(np.arange(b), n // b).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, 2, b).astype(np.int32)),
        "mask": jnp.ones(b, jnp.float32),
    }
    loss_fn = functools.partial(gnn_lib.gnn_loss, cfg)
    l0, l1 = _run_train(loss_fn, params, batch)
    assert np.isfinite(l1)


def test_smoke_minibatch_sampler_feeds_model():
    """Real CSR fanout sampler → padded subgraph → GIN train step."""
    from repro.graph import planted_partition, NeighborSampler
    from repro.graph.utils import to_csr

    edges, _ = planted_partition(500, 10, 0.2, 0.01, seed=3)
    indptr, indices = to_csr(edges, 500)
    sampler = NeighborSampler(indptr, indices, fanouts=(5, 3))
    rng = np.random.default_rng(0)
    sub = sampler.sample(np.arange(32), rng)
    assert sub.n_nodes <= sampler.max_capacity(32)[0]
    assert (sub.edges[: sub.n_edges] < sub.n_nodes).all()

    cfg = smoke_gnn("gin")
    params = init_params(KEY, gnn_lib.param_specs(cfg))
    n_cap = sub.nodes.shape[0]
    feats = rng.standard_normal((n_cap, cfg.d_feat)).astype(np.float32)
    batch = {
        "feats": jnp.asarray(feats),
        "edges": jnp.asarray(sub.edges),
        "labels": jnp.asarray(rng.integers(0, cfg.n_out, n_cap).astype(np.int32)),
        "mask": jnp.asarray(sub.seed_mask.astype(np.float32)),
    }
    loss_fn = functools.partial(gnn_lib.gnn_loss, cfg)
    l0, l1 = _run_train(loss_fn, params, batch)
    assert np.isfinite(l1)


# ------------------------------------------------------------------- recsys
def test_smoke_sasrec():
    cfg = smoke_sasrec()
    params = init_params(KEY, sas_lib.param_specs(cfg))
    rng = np.random.default_rng(4)
    b, s = 8, cfg.seq_len
    batch = {
        "seq": jnp.asarray(rng.integers(1, cfg.n_items, (b, s)).astype(np.int32)),
        "pos": jnp.asarray(rng.integers(1, cfg.n_items, (b, s)).astype(np.int32)),
        "neg": jnp.asarray(rng.integers(1, cfg.n_items, (b, s)).astype(np.int32)),
    }
    loss_fn = functools.partial(sas_lib.sasrec_loss, cfg)
    l0, l1 = _run_train(loss_fn, params, batch)
    assert l1 < l0

    serve = jax.jit(sas_lib.make_serve_step(cfg))
    scores = serve(params, {"seq": batch["seq"]})
    assert scores.shape == (b, cfg.n_items)
    assert bool(jnp.isfinite(scores).all())

    retr = jax.jit(sas_lib.make_retrieval_step(cfg))
    cand = jnp.asarray(rng.integers(1, cfg.n_items, 100).astype(np.int32))
    sc = retr(params, {"seq": batch["seq"][:1], "candidates": cand})
    assert sc.shape == (100,)
    # retrieval scores must equal the serve scores at those candidates
    np.testing.assert_allclose(
        np.asarray(sc), np.asarray(scores[0][cand]), rtol=1e-5, atol=1e-5
    )


# --------------------------------------------------------------- registry
def test_registry_covers_assignment():
    assigned = {
        "kimi-k2-1t-a32b", "granite-moe-1b-a400m", "yi-6b", "gemma3-4b",
        "mistral-large-123b", "gin-tu", "meshgraphnet", "graphcast",
        "gat-cora", "sasrec",
    }
    assert assigned <= set(REGISTRY)
    for name in assigned:
        arch = get_config(name)
        assert len(arch.shapes) == 4
        for shape in arch.shapes.values():
            if not shape.skip:
                specs = input_specs(arch, shape)
                assert specs  # every runnable cell has input stand-ins


def test_exact_configs_match_assignment():
    k = get_config("kimi-k2-1t-a32b").model
    assert (k.n_layers, k.d_model, k.n_heads, k.n_kv_heads) == (61, 7168, 64, 8)
    assert (k.moe.n_experts, k.moe.top_k, k.vocab) == (384, 8, 163840)
    y = get_config("yi-6b").model
    assert (y.n_layers, y.d_model, y.n_heads, y.n_kv_heads, y.d_ff, y.vocab) == \
        (32, 4096, 32, 4, 11008, 64000)
    g = get_config("gemma3-4b").model
    assert (g.n_layers, g.d_model, g.n_heads, g.vocab, g.global_every) == \
        (34, 2560, 8, 262144, 6)
    m = get_config("mistral-large-123b").model
    assert (m.n_layers, m.d_model, m.n_heads, m.d_ff, m.vocab) == \
        (88, 12288, 96, 28672, 32768)
    gr = get_config("granite-moe-1b-a400m").model
    assert (gr.n_layers, gr.d_model, gr.moe.n_experts, gr.moe.top_k, gr.vocab) == \
        (24, 1024, 32, 8, 49155)
    s = get_config("sasrec").model
    assert (s.embed_dim, s.n_blocks, s.n_heads, s.seq_len) == (50, 2, 1, 50)
    gc = get_config("graphcast").model
    assert (gc.n_layers, gc.d_hidden) == (16, 512)
    mg = get_config("meshgraphnet").model
    assert (mg.n_layers, mg.d_hidden) == (15, 128)
    gi = get_config("gin-tu").model
    assert (gi.n_layers, gi.d_hidden) == (5, 64)
    ga = get_config("gat-cora").model
    assert (ga.n_layers, ga.n_heads) == (2, 8)
