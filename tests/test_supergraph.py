"""Supergraph aggregation invariants, for both ``agg_backend`` values:
oracle parity, the chunked == one-shot property (random graphs, chunk
sizes, and chunk orderings), the capacity-overflow truncation contract,
and the all-invalid-chunk short-circuit."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cms as cms_lib
from repro.core.supergraph import (
    agg_finalize,
    agg_init,
    agg_update,
    aggregate_edges,
    build_supergraph,
)
from repro.graph import planted_partition, pad_edges
from repro.graph.utils import degrees

BACKENDS = ("lexsort", "merge")


def _oracle_aggregate(edges, labels):
    pairs = {}
    for u, v in edges:
        a, b = labels[u], labels[v]
        if a == b:
            continue
        key = (min(a, b), max(a, b))
        pairs[key] = pairs.get(key, 0) + 1
    return pairs


def _labels_ext(labels, s_cap):
    return jnp.concatenate(
        [jnp.asarray(labels), jnp.array([s_cap], jnp.int32)]
    )


def _run_chunked(chunks, labels, s_cap, cap, backend):
    ext = _labels_ext(labels, s_cap)
    state = agg_init(s_cap, cap)
    for chunk in chunks:
        state = agg_update(state, jnp.asarray(chunk), ext, s_cap, cap, backend)
    return agg_finalize(state)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_aggregate_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n, e = 60, 150
    edges_np = rng.integers(0, n, size=(e, 2)).astype(np.int32)
    edges_np = edges_np[edges_np[:, 0] != edges_np[:, 1]]
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    edges = jnp.asarray(pad_edges(edges_np, e, n))
    s_cap, cap = 16, 256
    oracle = _oracle_aggregate(edges_np, labels)
    for backend in BACKENDS:
        se, sw, n_se = aggregate_edges(
            edges, jnp.asarray(labels), s_cap, cap, backend
        )
        se, sw = np.asarray(se), np.asarray(sw)
        assert int(n_se) == len(oracle)
        got = {}
        for (a, b), w in zip(se, sw):
            if a < s_cap and b < s_cap and w > 0:
                got[(int(a), int(b))] = got.get((int(a), int(b)), 0) + w
        assert got == {k: float(v) for k, v in oracle.items()}


# ------------------------------------------------- chunked == one-shot property

_E_PAD = 192
_CHUNK_SIZES = (16, 32, 64, 96, 192)  # small palette keeps the jit cache warm


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_chunked_equals_oneshot_any_order(seed):
    """Chunked aggregation == one-shot, bit-for-bit, for random graphs,
    random chunk sizes, and random chunk orderings — both backends (the
    merge path inherits the order-independence contract). Capacity holds
    every possible pair, so truncation never engages."""
    rng = np.random.default_rng(seed)
    n, s_cap, cap = 48, 16, 128  # ≤ C(13,2) = 78 unique pairs < cap
    e = int(rng.integers(1, _E_PAD + 1))
    edges_np = rng.integers(0, n, size=(e, 2)).astype(np.int32)
    labels = rng.integers(0, 13, size=n).astype(np.int32)
    padded = np.asarray(pad_edges(edges_np, _E_PAD, n))

    se1, sw1, n1 = aggregate_edges(
        jnp.asarray(padded), jnp.asarray(labels), s_cap, cap, "lexsort"
    )

    chunk_size = int(rng.choice(_CHUNK_SIZES))
    chunks = padded.reshape(-1, chunk_size, 2)
    order = rng.permutation(len(chunks))
    for backend in BACKENDS:
        se2, sw2, n2 = _run_chunked(
            [chunks[i] for i in order], labels, s_cap, cap, backend
        )
        assert int(n1) == int(n2), backend
        np.testing.assert_array_equal(np.asarray(se1), np.asarray(se2), err_msg=backend)
        np.testing.assert_array_equal(np.asarray(sw1), np.asarray(sw2), err_msg=backend)


# ----------------------------------------------- capacity-overflow contract

def _oracle_overflow_update(pairs: dict, chunk_pairs: list, cap: int):
    """The documented truncation contract, in plain python: union the
    chunk's pair counts into the state, keep the ``cap`` lexicographically
    smallest pairs (the weight of dropped pairs is lost), and report the
    union's unique-pair count (which may exceed ``cap``)."""
    union = dict(pairs)
    for p in chunk_pairs:
        union[p] = union.get(p, 0) + 1
    n = len(union)
    kept = dict(sorted(union.items())[:cap])
    return kept, n


def _finalized_pairs(se, sw, s_cap):
    out = {}
    for (a, b), w in zip(np.asarray(se), np.asarray(sw)):
        if a < s_cap:
            out[(int(a), int(b))] = float(w)
    return out


@pytest.mark.parametrize("backend", BACKENDS)
def test_overflow_oneshot_keeps_smallest_pairs(backend):
    """Above capacity the sorted tail is truncated: the state holds the
    lexicographically smallest ``cap`` pairs while n counts all of them."""
    n, s_cap, cap = 16, 16, 8
    labels = np.arange(n, dtype=np.int32)  # one community per node
    edges_np = np.array(
        [(i, j) for i in range(n) for j in range(i + 1, n)], np.int32
    )  # 120 unique pairs ≫ cap
    se, sw, n_se = aggregate_edges(
        jnp.asarray(edges_np), jnp.asarray(labels), s_cap, cap, backend
    )
    assert int(n_se) == 120
    want = [(0, j) for j in range(1, cap + 1)]  # lexicographically first 8
    np.testing.assert_array_equal(np.asarray(se), np.array(want, np.int32))
    np.testing.assert_array_equal(np.asarray(sw), np.ones(cap, np.float32))


@pytest.mark.parametrize("backend", BACKENDS)
def test_overflow_chunked_follows_truncation_oracle(backend):
    """Pin the over-capacity chunked behavior: every update truncates to
    the smallest ``cap`` pairs of (truncated state ∪ chunk), so the result
    depends on chunk order — and both backends agree exactly."""
    n, s_cap, cap = 12, 16, 4
    labels = np.arange(n, dtype=np.int32)
    high = np.array([(6, j) for j in range(7, 12)], np.int32)  # pairs (6,7)…(6,11)
    low = np.array([(0, j) for j in range(1, 6)], np.int32)  # pairs (0,1)…(0,5)
    mixed = np.concatenate([high[:2], low[:3]])  # re-adds (6,7),(6,8)

    for chunks in ([high, low, mixed], [mixed, high, low], [low, mixed, high]):
        oracle, oracle_n = {}, 0
        ext = _labels_ext(labels, s_cap)
        state = agg_init(s_cap, cap)
        for chunk in chunks:
            state = agg_update(state, jnp.asarray(chunk), ext, s_cap, cap, backend)
            oracle, oracle_n = _oracle_overflow_update(
                oracle, [tuple(e) for e in chunk], cap
            )
            se, sw, n_se = agg_finalize(tuple(jnp.asarray(x) for x in state))
            assert int(n_se) == oracle_n
            assert _finalized_pairs(se, sw, s_cap) == oracle

    # Chunk order changes the truncated result (the documented caveat):
    # the last update's union — and so its n_superedges — differs between
    # orderings once earlier truncation has dropped pairs.
    a = _run_chunked([high, low, mixed], labels, s_cap, cap, backend)
    b = _run_chunked([low, mixed, high], labels, s_cap, cap, backend)
    assert int(a[2]) != int(b[2])


def test_overflow_backends_agree_bit_for_bit():
    """Even above capacity (where chunked ≠ one-shot), both backends see
    the same truncation at every update, for any fixed chunk sequence."""
    rng = np.random.default_rng(11)
    n, s_cap, cap = 40, 16, 16
    edges_np = rng.integers(0, n, size=(256, 2)).astype(np.int32)
    labels = rng.integers(0, 16, size=n).astype(np.int32)  # up to 120 pairs > cap
    chunks = np.asarray(pad_edges(edges_np, 256, n)).reshape(-1, 64, 2)
    out = {
        backend: _run_chunked(chunks, labels, s_cap, cap, backend)
        for backend in BACKENDS
    }
    for x, y in zip(out["lexsort"], out["merge"]):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------- all-invalid chunk short-circuit

@pytest.mark.parametrize("backend", BACKENDS)
def test_all_invalid_chunk_is_identity(backend):
    """A chunk of only trash-padding or intra-community edges must leave
    the aggregation state exactly unchanged (the update short-circuits
    instead of rewriting the whole state)."""
    rng = np.random.default_rng(2)
    n, s_cap, cap = 30, 8, 64
    labels = rng.integers(0, 8, size=n).astype(np.int32)
    edges_np = rng.integers(0, n, size=(50, 2)).astype(np.int32)
    ext = _labels_ext(labels, s_cap)
    state = agg_init(s_cap, cap)
    state = agg_update(
        state, jnp.asarray(pad_edges(edges_np, 64, n)), ext, s_cap, cap, backend
    )
    before = tuple(np.asarray(x) for x in state)

    trash_chunk = jnp.full((64, 2), n, jnp.int32)
    same = rng.integers(0, n, size=64).astype(np.int32)
    intra_chunk = jnp.asarray(np.stack([same, same], axis=1))  # self loops: intra
    for chunk in (trash_chunk, intra_chunk):
        state = tuple(jnp.asarray(x) for x in before)
        state = agg_update(state, chunk, ext, s_cap, cap, backend)
        for got, want in zip(state, before):
            np.testing.assert_array_equal(np.asarray(got), want)


# ------------------------------------------------------------- legacy checks

def test_no_self_loops_and_canonical_order():
    edges_np, _ = planted_partition(200, 5, 0.3, 0.02, seed=1)
    n = 200
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    labels = jnp.asarray(np.random.default_rng(0).integers(0, 8, n).astype(np.int32))
    se, sw, n_se = aggregate_edges(edges, labels, 8, 64)
    se = np.asarray(se)
    live = np.asarray(sw) > 0
    assert (se[live, 0] < se[live, 1]).all()  # canonical + no self loops


def test_build_supergraph_sizes_upper_bound_degree_sum():
    """CMS never underestimates ⇒ supernode size ≥ Σ member degrees."""
    edges_np, _ = planted_partition(300, 6, 0.3, 0.01, seed=3)
    n = 300
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    deg = degrees(edges, n)
    labels = jnp.asarray(np.random.default_rng(1).integers(0, 300, n).astype(np.int32))
    cfg = cms_lib.CMSConfig(rows=4, cols=2048, seed=0)
    sg = build_supergraph(edges, labels, deg, n, 300, 4096, cfg)
    sizes = np.asarray(sg.sizes)
    labd = np.asarray(sg.labels)
    true = np.zeros(300)
    np.add.at(true, labd, np.asarray(deg))
    live = np.arange(300) < int(sg.n_supernodes)
    assert (sizes[live] >= true[live] - 1e-3).all()
    # wide sketch ⇒ near-exact
    np.testing.assert_allclose(sizes[live], true[live], rtol=0.05)
