"""Supergraph aggregation invariants."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import cms as cms_lib
from repro.core.supergraph import aggregate_edges, build_supergraph
from repro.graph import planted_partition, pad_edges
from repro.graph.utils import degrees


def _oracle_aggregate(edges, labels):
    pairs = {}
    for u, v in edges:
        a, b = labels[u], labels[v]
        if a == b:
            continue
        key = (min(a, b), max(a, b))
        pairs[key] = pairs.get(key, 0) + 1
    return pairs


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_aggregate_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    n, e = 60, 150
    edges_np = rng.integers(0, n, size=(e, 2)).astype(np.int32)
    edges_np = edges_np[edges_np[:, 0] != edges_np[:, 1]]
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    edges = jnp.asarray(pad_edges(edges_np, e, n))
    s_cap, cap = 16, 256
    se, sw, n_se = aggregate_edges(edges, jnp.asarray(labels), s_cap, cap)
    se, sw = np.asarray(se), np.asarray(sw)
    oracle = _oracle_aggregate(edges_np, labels)
    assert int(n_se) == len(oracle)
    got = {}
    for (a, b), w in zip(se, sw):
        if a < s_cap and b < s_cap and w > 0:
            got[(int(a), int(b))] = got.get((int(a), int(b)), 0) + w
    assert got == {k: float(v) for k, v in oracle.items()}


def test_no_self_loops_and_canonical_order():
    edges_np, _ = planted_partition(200, 5, 0.3, 0.02, seed=1)
    n = 200
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    labels = jnp.asarray(np.random.default_rng(0).integers(0, 8, n).astype(np.int32))
    se, sw, n_se = aggregate_edges(edges, labels, 8, 64)
    se = np.asarray(se)
    live = np.asarray(sw) > 0
    assert (se[live, 0] < se[live, 1]).all()  # canonical + no self loops


def test_build_supergraph_sizes_upper_bound_degree_sum():
    """CMS never underestimates ⇒ supernode size ≥ Σ member degrees."""
    edges_np, _ = planted_partition(300, 6, 0.3, 0.01, seed=3)
    n = 300
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    deg = degrees(edges, n)
    labels = jnp.asarray(np.random.default_rng(1).integers(0, 300, n).astype(np.int32))
    cfg = cms_lib.CMSConfig(rows=4, cols=2048, seed=0)
    sg = build_supergraph(edges, labels, deg, n, 300, 4096, cfg)
    sizes = np.asarray(sg.sizes)
    labd = np.asarray(sg.labels)
    true = np.zeros(300)
    np.add.at(true, labd, np.asarray(deg))
    live = np.arange(300) < int(sg.n_supernodes)
    assert (sizes[live] >= true[live] - 1e-3).all()
    # wide sketch ⇒ near-exact
    np.testing.assert_allclose(sizes[live], true[live], rtol=0.05)
