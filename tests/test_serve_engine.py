"""Continuous-batching LM serve engine: slot lifecycle + decode parity
with one-shot prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.lm_archs import smoke_lm
from repro.models import transformer as tfm
from repro.models.param import init_params
from repro.serve.engine import LMEngine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_lm(moe=False)
    params = init_params(jax.random.PRNGKey(2), tfm.param_specs(cfg))
    return cfg, params


def test_engine_serves_all_requests(setup):
    cfg, params = setup
    engine = LMEngine(cfg, params, n_slots=3, max_len=48)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=4), max_new=5)
            for _ in range(5)]
    backlog = list(reqs)
    done, ticks = [], 0
    while (backlog or engine.n_live) and ticks < 100:
        while backlog and engine.submit(backlog[0]):
            backlog.pop(0)
        done += engine.tick()
        ticks += 1
    assert len(done) == 5
    for r in done:
        assert len(r.out) == 5
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_engine_matches_prefill_argmax(setup):
    """The engine's first generated token must equal greedy argmax from a
    one-shot prefill of the same prompt — decode-path correctness."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg.vocab, size=6)

    prefill = jax.jit(tfm.make_prefill(cfg))
    logits = prefill(params, {"tokens": jnp.asarray(prompt)[None, :]})
    want = int(jnp.argmax(logits[0, -1, : cfg.vocab]))

    engine = LMEngine(cfg, params, n_slots=2, max_len=32)
    req = Request(prompt=prompt, max_new=1)
    assert engine.submit(req)
    (done,) = engine.tick()
    assert done.out[0] == want


def test_slot_reuse_is_clean(setup):
    """A new tenant in a freed slot must not see the old tenant's KV."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, cfg.vocab, size=5)

    fresh = LMEngine(cfg, params, n_slots=1, max_len=32)
    fresh.submit(Request(prompt=prompt, max_new=3))
    ref_out = []
    while fresh.n_live:
        ref_out += [r.out for r in fresh.tick()]

    reused = LMEngine(cfg, params, n_slots=1, max_len=32)
    reused.submit(Request(prompt=rng.integers(1, cfg.vocab, size=9), max_new=2))
    while reused.n_live:
        reused.tick()
    reused.submit(Request(prompt=prompt, max_new=3))
    out2 = []
    while reused.n_live:
        out2 += [r.out for r in reused.tick()]
    assert ref_out == out2
