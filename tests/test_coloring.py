"""Property tests for the §4.3 coloring strategy (core/coloring.py):
the brown bulk holds at most half the total mass, the remaining ten
buckets are equal-count, and grouping is a function of the size multiset
(invariant under permutation of the size vector)."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.coloring import color_groups


def _sizes(rng, n: int) -> np.ndarray:
    return (rng.pareto(1.2, n) * 10 + 1).astype(np.float32)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_bulk_holds_at_most_half_the_mass(seed):
    rng = np.random.default_rng(seed)
    sizes = _sizes(rng, 200)
    groups = np.asarray(color_groups(jnp.asarray(sizes)))
    bulk = float(sizes[groups == 0].sum())
    total = float(sizes.sum())
    assert bulk <= 0.5 * total * (1 + 1e-5), (bulk, total)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_groups_1_to_10_equal_count(seed):
    rng = np.random.default_rng(seed)
    sizes = _sizes(rng, 64 + 37 * (seed % 3))  # few shapes: bounded retraces
    groups = np.asarray(color_groups(jnp.asarray(sizes)))
    counts = np.bincount(groups, minlength=11)[1:]
    assert counts.max() - counts.min() <= 1, counts


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_grouping_invariant_under_permutation(seed):
    """color_groups(sizes[perm]) == color_groups(sizes)[perm] — grouping
    depends on a community's size, not its slot. Distinct sizes make the
    per-index form exact (ties may legitimately swap across the bulk
    boundary); tied vectors are covered by the multiset check below."""
    rng = np.random.default_rng(seed)
    n = 150
    sizes = rng.choice(np.arange(1, 100 * n), size=n, replace=False).astype(
        np.float32
    )
    perm = rng.permutation(n)
    g = np.asarray(color_groups(jnp.asarray(sizes)))
    g_perm = np.asarray(color_groups(jnp.asarray(sizes[perm])))
    np.testing.assert_array_equal(g_perm, g[perm])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_group_multiset_invariant_with_ties(seed):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, 8, 120).astype(np.float32)  # heavy ties
    perm = rng.permutation(len(sizes))
    g = np.asarray(color_groups(jnp.asarray(sizes)))
    g_perm = np.asarray(color_groups(jnp.asarray(sizes[perm])))
    np.testing.assert_array_equal(
        np.bincount(g, minlength=11), np.bincount(g_perm, minlength=11)
    )


def test_zero_sizes_stay_brown():
    sizes = jnp.asarray([0.0, 0.0, 5.0, 1.0, 0.0, 9.0])
    groups = np.asarray(color_groups(sizes))
    assert (groups[np.asarray(sizes) == 0] == 0).all()
    assert groups.min() >= 0 and groups.max() <= 10
