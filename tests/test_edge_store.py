"""Out-of-core edge stores: write → mmap-read round trips must be
bit-for-bit identical to the in-memory path through every engine stage
(labels, supergraph, modularity), including partial final chunks, empty
shards, and the converter CLI."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import StreamConfig, biggraphvis, default_config
from repro.core.stream import EdgeChunkStream
from repro.data.edge_store import (
    BinEdgeStore,
    EdgeStoreError,
    InMemoryEdgeStore,
    NpyEdgeStore,
    ShardedEdgeStore,
    as_edge_store,
    main as edge_store_cli,
    open_edge_store,
    write_bin,
    write_npy,
    write_shards,
)
from repro.graph import mode_degree, planted_partition


@pytest.fixture(scope="module")
def graph():
    edges, _ = planted_partition(300, 6, 0.25, 0.005, seed=7)
    return edges, 300


@pytest.fixture(scope="module")
def stores_dir(tmp_path_factory):
    return tmp_path_factory.mktemp("edge_stores")


def _bgv_config(edges, n):
    from dataclasses import replace

    cfg = default_config(n, len(edges), max(2, mode_degree(edges, n)),
                         rounds=3, iterations=10, s_cap=512)
    return replace(cfg, scoda=replace(cfg.scoda, block_size=64))


def _assert_same_result(r1, r2):
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.sizes, r2.sizes)
    np.testing.assert_array_equal(
        np.asarray(r1.supergraph.edges), np.asarray(r2.supergraph.edges)
    )
    np.testing.assert_array_equal(
        np.asarray(r1.supergraph.weights), np.asarray(r2.supergraph.weights)
    )
    assert r1.modularity == r2.modularity
    assert r1.n_supernodes == r2.n_supernodes
    assert r1.n_superedges == r2.n_superedges


# ------------------------------------------------------------- store readers


def test_npy_roundtrip_reads_identical(graph, stores_dir):
    edges, _ = graph
    path = write_npy(stores_dir / "rt.npy", edges)
    store = NpyEdgeStore(path)
    assert store.n_edges == len(edges)
    assert store.resident_bytes == 0  # page-cache backed, not host-resident
    np.testing.assert_array_equal(store.read(0, len(edges)), edges)
    # reads past the tail return only the remaining rows
    assert len(store.read(len(edges) - 3, 100)) == 3


def test_bin_roundtrip_reads_identical(graph, stores_dir):
    edges, _ = graph
    path = write_bin(stores_dir / "rt.bin", edges)
    store = BinEdgeStore(path)
    assert store.n_edges == len(edges)
    np.testing.assert_array_equal(store.read(0, len(edges)), edges)


def test_sharded_reads_span_boundaries_and_empty_shards(graph, stores_dir):
    edges, _ = graph
    d = stores_dir / "mixed_shards"
    d.mkdir()
    # uneven shards with an empty one in the middle
    cuts = [0, 101, 101, 250, len(edges)]
    paths = []
    for i in range(len(cuts) - 1):
        paths.append(write_npy(d / f"shard-{i:05d}.npy", edges[cuts[i]:cuts[i + 1]]))
    store = open_edge_store(d)
    assert isinstance(store, ShardedEdgeStore)
    assert store.n_edges == len(edges)
    np.testing.assert_array_equal(store.read(0, len(edges)), edges)
    # a read crossing shard 0 → 2 (through the empty shard 1)
    np.testing.assert_array_equal(store.read(90, 40), edges[90:130])
    # per-shard empty store works standalone too
    empty = NpyEdgeStore(paths[1])
    assert empty.n_edges == 0
    assert empty.read(0, 8).shape == (0, 2)


def test_write_shards_roundtrip(graph, stores_dir):
    edges, _ = graph
    d = stores_dir / "written_shards"
    paths = write_shards(d, edges, shard_edges=77)
    assert len(paths) == -(-len(edges) // 77)
    store = open_edge_store(d)
    np.testing.assert_array_equal(store.read(0, len(edges)), edges)


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 97), st.integers(0, 59))
def test_property_roundtrip_any_shard_and_read_size(shard_edges, offset):
    """Property: for any shard split and read offset, sharded mmap reads
    reconstruct the original rows exactly."""
    import tempfile

    rng = np.random.default_rng(shard_edges * 64 + offset)
    edges = rng.integers(0, 200, size=(173, 2)).astype(np.int32)
    with tempfile.TemporaryDirectory() as d:
        write_shards(d, edges, shard_edges=shard_edges)
        store = open_edge_store(d)
        assert store.n_edges == len(edges)
        np.testing.assert_array_equal(
            store.read(offset, len(edges)), edges[offset:]
        )


# ------------------------------------------------- engine-level equivalence


def test_bgv_from_mmap_bit_identical(graph, stores_dir):
    """Acceptance: biggraphvis() driven from a memory-mapped .npy edge file
    produces bit-for-bit identical labels, supergraph, and modularity."""
    edges, n = graph
    cfg = _bgv_config(edges, n)
    path = write_npy(stores_dir / "bgv.npy", edges)
    r_mem = biggraphvis(edges, n, cfg, stream=StreamConfig(chunk_size=128))
    r_mmap = biggraphvis(path, n, cfg, stream=StreamConfig(chunk_size=128))
    _assert_same_result(r_mem, r_mmap)
    # host residency of the disk path is the staging ring, not the edge list
    assert r_mmap.stream.peak_host_bytes < r_mem.stream.peak_host_bytes
    assert r_mmap.stream.peak_host_bytes == 2 * 128 * 2 * 4


def test_bgv_from_bin_and_shards_bit_identical(graph, stores_dir):
    edges, n = graph
    cfg = _bgv_config(edges, n)
    r_mem = biggraphvis(edges, n, cfg, stream=StreamConfig(chunk_size=128))
    bin_path = write_bin(stores_dir / "bgv.bin", edges)
    r_bin = biggraphvis(bin_path, n, cfg,
                        stream=StreamConfig(chunk_size=128, prefetch=2))
    _assert_same_result(r_mem, r_bin)
    d = stores_dir / "bgv_shards"
    write_shards(d, edges, shard_edges=121)
    r_sh = biggraphvis(str(d), n, cfg,
                       stream=StreamConfig(chunk_size=128, prefetch=0))
    _assert_same_result(r_mem, r_sh)


def test_partial_final_chunk_padding(graph, stores_dir):
    """|E| not a multiple of the chunk: the staged tail chunk is padded with
    the trash node, exactly like the in-memory tail buffer."""
    edges, n = graph
    path = write_npy(stores_dir / "tail.npy", edges)
    st_mem = EdgeChunkStream(edges, n, 97)
    st_disk = EdgeChunkStream(NpyEdgeStore(path), n, 97)
    assert st_mem.chunk_size == st_disk.chunk_size
    assert len(edges) % st_mem.chunk_size != 0  # a genuinely partial tail
    for a, b in zip(st_mem, st_disk):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_device_chunks_match_host_chunks(graph, stores_dir):
    edges, n = graph
    path = write_npy(stores_dir / "dev.npy", edges)
    st_host = EdgeChunkStream(edges, n, 128)
    st_dev = EdgeChunkStream(NpyEdgeStore(path), n, 128)
    host = [np.asarray(c).copy() for c in st_host]
    # copy: a bare np.asarray view does not keep the device buffer alive
    # once the loop variable is rebound, so the allocator may reuse it
    dev = [np.asarray(c).copy() for c in st_dev.device_chunks(prefetch=1)]
    assert len(host) == len(dev)
    for a, b in zip(host, dev):
        np.testing.assert_array_equal(a, b)
    assert st_dev.passes == 1


# ------------------------------------------------------------- validation


def test_rejects_float_edges():
    with pytest.raises(EdgeStoreError, match="integer dtype"):
        EdgeChunkStream(np.zeros((10, 2), np.float32), 5, 4)


def test_rejects_bad_shape():
    with pytest.raises(EdgeStoreError, match=r"shape \[E, 2\]"):
        EdgeChunkStream(np.zeros((10, 3), np.int32), 5, 4)
    with pytest.raises(EdgeStoreError, match=r"shape \[E, 2\]"):
        InMemoryEdgeStore(np.zeros((4, 2, 2), np.int32))


def test_rejects_non_int32_npy_file(stores_dir):
    path = stores_dir / "wide.npy"
    np.save(path, np.zeros((10, 2), np.int64))
    with pytest.raises(EdgeStoreError, match="int32"):
        NpyEdgeStore(path)


def test_rejects_misaligned_bin_file(stores_dir):
    path = stores_dir / "ragged.bin"
    path.write_bytes(b"\x00" * 13)
    with pytest.raises(EdgeStoreError, match="multiple"):
        BinEdgeStore(path)


def test_rejects_unknown_source_type():
    with pytest.raises(EdgeStoreError, match="edge source"):
        as_edge_store({"not": "edges"})


def test_int64_in_memory_is_converted(graph):
    edges, n = graph
    st = as_edge_store(edges.astype(np.int64))
    assert st.array.dtype == np.int32
    np.testing.assert_array_equal(st.array, edges)


def test_rejects_out_of_int32_range_ids():
    bad = np.array([[0, 2**31 + 5]], dtype=np.int64)
    with pytest.raises(EdgeStoreError, match="int32 range"):
        InMemoryEdgeStore(bad)


# ------------------------------------------------------------ converter CLI


def test_cli_convert_and_info(graph, stores_dir, capsys):
    edges, _ = graph
    src = write_bin(stores_dir / "cli.bin", edges)
    dst = str(stores_dir / "cli.npy")
    edge_store_cli(["convert", str(src), dst])
    np.testing.assert_array_equal(NpyEdgeStore(dst).read(0, len(edges)), edges)
    edge_store_cli(["info", dst])
    out = capsys.readouterr().out
    assert f"{len(edges)} edges" in out

    shard_dir = str(stores_dir / "cli_shards")
    edge_store_cli(["convert", dst, shard_dir, "--format", "shards",
                    "--shard-edges", "100"])
    store = open_edge_store(shard_dir)
    np.testing.assert_array_equal(store.read(0, len(edges)), edges)
