"""Count–min sketch invariants: never underestimates, exact without
collisions, linear/mergeable, accuracy improves with width."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import cms


def _true_counts(keys, weights, domain):
    out = np.zeros(domain)
    np.add.at(out, keys, weights)
    return out


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 200))
def test_never_underestimates(seed, n_keys):
    """The defining CMS guarantee: estimate ≥ true count."""
    rng = np.random.default_rng(seed)
    cfg = cms.CMSConfig(rows=4, cols=64, seed=1)
    keys = rng.integers(0, 50, size=n_keys).astype(np.int32)
    w = rng.uniform(0, 10, size=n_keys).astype(np.float32)
    sketch = cms.update(cms.init_sketch(cfg), jnp.asarray(keys), jnp.asarray(w), cfg)
    est = np.asarray(cms.query(sketch, jnp.arange(50, dtype=jnp.int32), cfg))
    true = _true_counts(keys, w, 50)
    assert (est >= true - 1e-3).all()


def test_exact_when_wide():
    """With cols ≫ #distinct keys, all rows collide with high probability on
    nothing and the estimate is exact."""
    rng = np.random.default_rng(0)
    cfg = cms.CMSConfig(rows=4, cols=8192, seed=3)
    keys = rng.integers(0, 32, size=500).astype(np.int32)
    w = np.ones(500, np.float32)
    sketch = cms.update(cms.init_sketch(cfg), jnp.asarray(keys), jnp.asarray(w), cfg)
    est = np.asarray(cms.query(sketch, jnp.arange(32, dtype=jnp.int32), cfg))
    true = _true_counts(keys, w, 32)
    np.testing.assert_allclose(est, true, rtol=1e-6)


def test_merge_is_linear():
    """Sharded updates + all-reduce == single-stream update (DESIGN.md §2)."""
    rng = np.random.default_rng(1)
    cfg = cms.CMSConfig(rows=4, cols=128, seed=5)
    keys = rng.integers(0, 64, size=400).astype(np.int32)
    w = rng.uniform(0, 5, size=400).astype(np.float32)
    s_all = cms.update(cms.init_sketch(cfg), jnp.asarray(keys), jnp.asarray(w), cfg)
    s1 = cms.update(cms.init_sketch(cfg), jnp.asarray(keys[:200]), jnp.asarray(w[:200]), cfg)
    s2 = cms.update(cms.init_sketch(cfg), jnp.asarray(keys[200:]), jnp.asarray(w[200:]), cfg)
    np.testing.assert_allclose(np.asarray(cms.merge(s1, s2)), np.asarray(s_all), rtol=1e-6)


def test_padding_masked():
    cfg = cms.CMSConfig(rows=2, cols=64, seed=2)
    keys = jnp.asarray([3, -1, 3, -1], jnp.int32)  # -1 = padding
    w = jnp.asarray([1.0, 100.0, 2.0, 100.0], jnp.float32)
    sketch = cms.update(cms.init_sketch(cfg), keys, w, cfg)
    est = float(cms.query(sketch, jnp.asarray([3], jnp.int32), cfg)[0])
    assert abs(est - 3.0) < 1e-5


def test_more_width_more_accurate():
    """Paper §5.3.4 / Fig 7: wider sketch ⇒ less overestimation."""
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 2000, size=20000).astype(np.int32)
    w = np.ones(20000, np.float32)
    true = _true_counts(keys, w, 2000)
    errs = []
    for cols in (128, 512, 4096):
        cfg = cms.CMSConfig(rows=4, cols=cols, seed=11)
        sketch = cms.update(cms.init_sketch(cfg), jnp.asarray(keys), jnp.asarray(w), cfg)
        est = np.asarray(cms.query(sketch, jnp.arange(2000, dtype=jnp.int32), cfg))
        errs.append(float(np.mean(est - true)))
    assert errs[2] < errs[1] < errs[0]


def test_more_rows_tighter_tail():
    """Paper §4.2.1: more hash functions ⇒ smaller chance of big errors."""
    rng = np.random.default_rng(9)
    keys = rng.integers(0, 1000, size=8000).astype(np.int32)
    w = np.ones(8000, np.float32)
    true = _true_counts(keys, w, 1000)
    tails = []
    for rows in (1, 4):
        cfg = cms.CMSConfig(rows=rows, cols=256, seed=13)
        sketch = cms.update(cms.init_sketch(cfg), jnp.asarray(keys), jnp.asarray(w), cfg)
        est = np.asarray(cms.query(sketch, jnp.arange(1000, dtype=jnp.int32), cfg))
        tails.append(float(np.percentile(est - true, 99)))
    assert tails[1] <= tails[0]
