"""Kernel micro-benchmarks: Pallas (interpret, correctness-path) working-set
accounting + CPU timing of the jnp production paths across the shape sweep.

On CPU the timings compare the scatter-oracle vs chunked paths; the Pallas
VMEM working set per grid step is computed analytically from the
BlockSpecs — the number that must stay under ~16 MiB VMEM on TPU.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core import cms as cms_lib
from repro.kernels.cms import ops as cms_ops
from repro.kernels.repulsion import ops as rep_ops
from repro.kernels.segment import ops as seg_ops


def _vmem_repulsion(ti: int, tj: int) -> int:
    # pos/mass/radii tiles + 4 pair blocks (dx, dy, d2, mag) in f32
    return 4 * (ti * 2 + ti * 2 + tj * 2 + tj * 2) + 4 * 4 * ti * tj


def _vmem_cms(rows: int, cols: int, blk: int) -> int:
    return 4 * (rows * blk + blk + rows * cols + blk * cols)


def _vmem_seg(tn: int, blk: int, d: int) -> int:
    return 4 * (blk + blk * d + tn * blk + tn * d)


def run(quick: bool = False) -> list[str]:
    out = []
    rng = np.random.default_rng(0)

    # repulsion: production path timing + kernel VMEM accounting
    for n in (1024, 4096) if quick else (1024, 4096, 16384):
        pos = jnp.asarray(rng.uniform(-100, 100, (n, 2)).astype(np.float32))
        mass = jnp.asarray(rng.uniform(0.5, 3.0, n).astype(np.float32))
        t = time_call(lambda: rep_ops.repulsion(pos, mass, 80.0, backend="chunked").block_until_ready())
        out.append(row(f"kernels/repulsion/chunked/n{n}", t,
                       f"pairs_per_s={n*n/t:.2e}"))
    for ti in (256, 512):
        out.append(row(f"kernels/repulsion/vmem/t{ti}", 0,
                       f"vmem_bytes={_vmem_repulsion(ti, ti)}"))

    # cms update
    cfg = cms_lib.CMSConfig(rows=4, cols=4096, seed=1)
    for n in (65536,) if quick else (65536, 1048576):
        keys = jnp.asarray(rng.integers(0, 100000, n).astype(np.int32))
        w = jnp.ones(n, jnp.float32)
        s0 = cms_lib.init_sketch(cfg)
        t = time_call(lambda: cms_ops.update(s0, keys, w, cfg, backend="ref").block_until_ready())
        out.append(row(f"kernels/cms/ref/n{n}", t, f"keys_per_s={n/t:.2e}"))
    out.append(row("kernels/cms/vmem/blk1024", 0,
                   f"vmem_bytes={_vmem_cms(4, 4096, 1024)}"))

    # segment sum
    for e, d in ((65536, 64),) if quick else ((65536, 64), (262144, 128)):
        data = jnp.asarray(rng.standard_normal((e, d)).astype(np.float32))
        seg = jnp.asarray(rng.integers(0, e // 16, e).astype(np.int32))
        t = time_call(lambda: seg_ops.segment_sum(data, seg, e // 16, backend="ref").block_until_ready())
        out.append(row(f"kernels/segment/ref/e{e}d{d}", t,
                       f"edges_per_s={e/t:.2e}"))
    out.append(row("kernels/segment/vmem/tn256blk512d128", 0,
                   f"vmem_bytes={_vmem_seg(256, 512, 128)}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
