"""Shared benchmark plumbing: CPU-scale graph suite mirroring the paper's
structural regimes + timing helpers. Results print as CSV
(name,us_per_call,derived) per the harness contract."""
from __future__ import annotations

import time

from repro.graph import planted_partition, powerlaw_graph

# CPU-scale stand-ins for the paper's SNAP suite (DESIGN.md §8): same
# regimes (community-rich, heavy-tailed), sizes runnable on one core.
SUITE = {
    # name: (builder, n_nodes)
    "ppart-8k": (lambda: planted_partition(8000, 64, 0.12, 2e-4, seed=5)[0], 8000),
    "ppart-32k": (lambda: planted_partition(32768, 160, 0.05, 4e-5, seed=6)[0], 32768),
    "powerlaw-16k": (lambda: powerlaw_graph(16384, m=6, seed=7), 16384),
}


def time_call(fn, *args, repeat: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn(*args)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"
