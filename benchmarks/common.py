"""Shared benchmark plumbing: CPU-scale graph suite mirroring the paper's
structural regimes + timing helpers. Results print as CSV
(name,us_per_call,derived) per the harness contract; ``make_record`` /
``record_from_csv`` / ``write_bench_json`` define the unified structured
record schema every bench's JSON artifact (and ``benchmarks.run``'s
repo-root ``BENCH_<name>.json`` files) shares."""
from __future__ import annotations

import json
import time

from repro.graph import planted_partition, powerlaw_graph

# CPU-scale stand-ins for the paper's SNAP suite (DESIGN.md §8): same
# regimes (community-rich, heavy-tailed), sizes runnable on one core.
SUITE = {
    # name: (builder, n_nodes)
    "ppart-8k": (lambda: planted_partition(8000, 64, 0.12, 2e-4, seed=5)[0], 8000),
    "ppart-32k": (lambda: planted_partition(32768, 160, 0.05, 4e-5, seed=6)[0], 32768),
    "powerlaw-16k": (lambda: powerlaw_graph(16384, m=6, seed=7), 16384),
}


def time_call(fn, *args, repeat: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn(*args)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.0f},{derived}"


# ---------------------------------------------------------------------------
# Unified structured records — one schema for every bench JSON artifact:
#   {"name": <row name>, "config": {...knobs...}, "metrics": {...numbers...}}
# wrapped by write_bench_json as
#   {"bench": ..., "timestamp": ..., "records": [...]}.
# The timestamp is passed in by the runner (benchmarks/run.py) so record
# construction stays deterministic and testable.


def make_record(name: str, config: dict | None = None,
                metrics: dict | None = None) -> dict:
    """One benchmark measurement in the unified schema: ``name`` identifies
    the measured row (the CSV row name), ``config`` holds the knobs that
    produced it, ``metrics`` the measured numbers (``us_per_call`` plus any
    derived values)."""
    return {
        "name": str(name),
        "config": dict(config or {}),
        "metrics": dict(metrics or {}),
    }


def _coerce(v: str):
    """CSV derived values are strings; store numbers as numbers."""
    try:
        f = float(v)
    except ValueError:
        return v
    return int(f) if f.is_integer() and "." not in v and "e" not in v.lower() else f


def record_from_csv(line: str) -> dict | None:
    """Parse one harness CSV row (``name,us_per_call,derived`` with derived
    as ``k=v;k=v``) into a unified record; None for non-row lines (headers,
    check summaries)."""
    parts = line.split(",", 2)
    if len(parts) < 2:
        return None
    name, us = parts[0], parts[1]
    try:
        us_val = float(us)
    except ValueError:
        return None  # header or prose line
    metrics = {"us_per_call": us_val}
    if len(parts) == 3 and parts[2]:
        for kv in parts[2].split(";"):
            if "=" in kv:
                k, v = kv.split("=", 1)
                metrics[k.strip()] = _coerce(v.strip())
    return make_record(name, metrics=metrics)


def write_bench_json(path: str, bench: str, records: list,
                     timestamp: float | None = None, **extra) -> str:
    """Write a bench's records in the unified wrapper schema. ``timestamp``
    comes from the runner (unix seconds); ``extra`` keys land in the
    wrapper (e.g. sweep-wide config)."""
    payload = {"bench": bench, "timestamp": timestamp,
               "records": records, **extra}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path
