"""Device-count weak-scaling sweep for the sharded detect+layout pipeline:
each point re-runs the full streamed pipeline in a subprocess forced to D
CPU devices (``--xla_force_host_platform_device_count``), and the parent
asserts the D-device labels / supergraph / layout are bit-for-bit identical
to the 1-device run while per-device peak bytes shrink ~1/D.

    PYTHONPATH=src python -m benchmarks.shard_bench --quick
    PYTHONPATH=src python -m benchmarks.shard_bench --devices 1,8 --check \
        --json shard.json
    PYTHONPATH=src python -m benchmarks.run --only shard

CSV rows (name,us_per_call,derived) per the harness contract. The worker
(``--worker``) prints one JSON blob and nothing else; it is always spawned
with its own ``XLA_FLAGS``/``JAX_PLATFORMS=cpu`` so the sweep is
independent of the parent's device count. Hashes cover every pipeline
output (labels, supergraph edges/weights/sizes, layout positions), so a
single reordered float add anywhere in the sharded path fails the sweep.
``peak_local_bytes`` is the engine's per-device analytic (replicated state
+ chunk/D — core/stream.py); the worker also measures the real placement
of one sharded chunk via ``addressable_shards`` as a cross-check.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys

from benchmarks.common import row

# Sweep shapes: the chunk buffers must dominate replicated per-pass state
# for the 1/D memory assertion to have teeth (state is replicated on every
# device; only chunk buffers shard). block 4096 divides the chunk and any
# power-of-two device count, so no divisibility fallback triggers.
# ``max_super`` caps the aggregation state (the default min(4|E|, 262144)
# is 3 MB of replicated pa/pb/pw — it would swamp the sharded chunks); the
# planted graphs here have < 2k distinct community pairs, far below it.
FULL = dict(nodes=6144, communities=48, p_in=0.5, p_out=0.012,
            chunk=131072, block=4096, rounds=2, iterations=10,
            max_super=16384)
QUICK = dict(nodes=2048, communities=32, p_in=0.5, p_out=0.03,
             chunk=32768, block=2048, rounds=2, iterations=5,
             max_super=8192)
SEED = 7
DEVICES_FULL = (1, 2, 4, 8)
DEVICES_QUICK = (1, 2)
# Memory bar: local_D <= total_1 * (1/D + EPS). EPS absorbs the replicated
# state share of the footprint; the shapes above keep it chunk-dominated.
MEM_EPS = 0.25


def _hash(a) -> str:
    import numpy as np

    return hashlib.sha256(np.ascontiguousarray(np.asarray(a)).tobytes()).hexdigest()


def _worker(args) -> None:
    """Run the sharded streamed pipeline on every local device; print JSON."""
    import time
    from dataclasses import replace

    import jax
    import numpy as np

    from repro.core.pipeline import default_config
    from repro.core.stream import StreamConfig
    from repro.graph import mode_degree, planted_partition
    from repro.launch.mesh import make_stream_mesh
    from repro.launch.stream_runner import StreamRunner, StreamRunnerConfig

    p = QUICK if args.quick else FULL
    n = p["nodes"]
    edges, _ = planted_partition(n, p["communities"], p["p_in"], p["p_out"],
                                 seed=SEED)
    delta = mode_degree(edges, n)
    cfg = default_config(n, len(edges), delta, rounds=p["rounds"],
                         iterations=p["iterations"])
    cfg = replace(cfg, scoda=replace(cfg.scoda, block_size=p["block"]),
                  max_super_edges=p["max_super"])
    mesh = make_stream_mesh()
    runner = StreamRunner(cfg, StreamRunnerConfig(
        stream=StreamConfig(chunk_size=p["chunk"], prefetch=1,
                            shard_detect=True, shard_layout=True),
        shard_chunks=True,
    ), mesh=mesh)

    t0 = time.perf_counter()
    res = runner.run(edges, n)
    wall_s = time.perf_counter() - t0

    # Real placement cross-check: one row-sharded chunk's largest per-device
    # shard (the analytic peak assumes exactly chunk/D bytes per device).
    arr = runner.put(np.ascontiguousarray(edges[: p["chunk"]]))
    shard_b = max(s.data.nbytes for s in arr.addressable_shards)

    s = res.stream
    print(json.dumps({
        "devices": jax.device_count(),
        "stats_devices": s.devices,
        "n_edges": int(len(edges)),
        "wall_s": wall_s,
        "edges_per_s": s.edges_per_s,
        "passes": s.passes,
        "chunks": s.chunks,
        "peak_device_bytes": s.peak_device_bytes,
        "peak_local_bytes": s.peak_local_bytes,
        "chunk_shard_bytes": shard_b,
        "chunk_full_bytes": int(p["chunk"] * 8),
        "n_supernodes": res.n_supernodes,
        "n_superedges": res.n_superedges,
        "modularity": res.modularity,
        "hash_labels": _hash(res.labels),
        "hash_sg_edges": _hash(res.supergraph.edges),
        "hash_sg_weights": _hash(res.supergraph.weights),
        "hash_sizes": _hash(res.sizes),
        "hash_positions": _hash(res.positions),
    }))


def _spawn(devices: int, quick: bool) -> dict:
    """One sweep point: this module as a worker under a forced device count."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    # Drop any inherited device-count forcing so ours is the only one.
    kept = [tok for tok in env.get("XLA_FLAGS", "").split()
            if not tok.startswith("--xla_force_host_platform_device_count")]
    kept.append(f"--xla_force_host_platform_device_count={devices}")
    env["XLA_FLAGS"] = " ".join(kept)
    cmd = [sys.executable, "-m", "benchmarks.shard_bench", "--worker"]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if out.returncode != 0:
        raise RuntimeError(
            f"shard worker (D={devices}) failed:\n{out.stdout}\n{out.stderr}"
        )
    return json.loads(out.stdout.strip().splitlines()[-1])


HASH_KEYS = ("hash_labels", "hash_sg_edges", "hash_sg_weights", "hash_sizes",
             "hash_positions", "n_supernodes", "n_superedges", "modularity")


def run(quick: bool = False, devices: tuple | None = None,
        records: list | None = None):
    """Yield CSV rows; append one structured record per device count."""
    devs = devices or (DEVICES_QUICK if quick else DEVICES_FULL)
    base = None
    for d in devs:
        r = _spawn(d, quick)
        r["match_base"] = (
            base is None or all(r[k] == base[k] for k in HASH_KEYS)
        )
        if base is None:
            base = r
        ratio = r["peak_local_bytes"] / base["peak_device_bytes"]
        r["local_over_base"] = ratio
        yield row(
            f"shard/pipeline/D{d}", r["wall_s"],
            f"devices={r['stats_devices']};match={int(r['match_base'])};"
            f"edges_per_s={r['edges_per_s']:.3e};"
            f"peak_local={r['peak_local_bytes']};local_over_1dev={ratio:.3f}",
        )
        if records is not None:
            records.append(r)


def _check(records: list) -> list[str]:
    """Acceptance bars: every D bit-identical to D=1; sharding engaged (no
    silent divisibility fallback); per-device peak <= (1/D + eps) of the
    1-device peak; real chunk shards exactly chunk/D bytes. Returns the
    result lines (printed and fed to ``run.step_summary``)."""
    base = records[0]
    assert base["devices"] == 1, f"first sweep point has D={base['devices']}"
    for r in records:
        d = r["devices"]
        assert r["match_base"], (
            f"D={d} diverged from D=1: "
            + str({k: (r[k], base[k]) for k in HASH_KEYS if r[k] != base[k]})
        )
        assert r["stats_devices"] == d, (
            f"D={d} run fell back to {r['stats_devices']} device(s) — "
            "a divisibility gate silently disabled sharding"
        )
        assert r["chunk_shard_bytes"] * d == r["chunk_full_bytes"], (
            f"D={d}: chunk shard {r['chunk_shard_bytes']}B x {d} != "
            f"{r['chunk_full_bytes']}B — chunk not evenly row-sharded"
        )
        bound = (1.0 / d + MEM_EPS) * base["peak_device_bytes"]
        assert r["peak_local_bytes"] <= bound, (
            f"D={d}: per-device peak {r['peak_local_bytes']:,}B > "
            f"(1/{d} + {MEM_EPS}) x 1-device peak "
            f"{base['peak_device_bytes']:,}B"
        )
    dmax = records[-1]
    return [
        f"check: {len(records)} device counts "
        f"({', '.join(str(r['devices']) for r in records)}) all bit-identical "
        "to 1 device (labels, supergraph, layout)",
        f"check: per-device peak at D={dmax['devices']} is "
        f"{dmax['local_over_base']:.2f}x the 1-device peak "
        f"(bound 1/D + {MEM_EPS})",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller graph, device counts 1,2")
    ap.add_argument("--devices", default="",
                    help="comma-separated device counts (default 1,2,4,8; "
                         "quick 1,2)")
    ap.add_argument("--json", default="",
                    help="also write structured records to this path")
    ap.add_argument("--check", action="store_true",
                    help="assert bit-identity across device counts and the "
                         "1/D per-device memory bar")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.worker:
        _worker(args)
        return

    devices = None
    if args.devices:
        # dict.fromkeys: dedupe while keeping order (e.g. "1,2,2" → 1,2)
        devices = tuple(dict.fromkeys(int(d) for d in args.devices.split(",")))
        assert devices[0] == 1, "sweep must start at 1 device (the reference)"
    records: list = []
    print("name,us_per_call,derived")
    for line in run(quick=args.quick, devices=devices, records=records):
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "bench": "shard_bench",
                "params": QUICK if args.quick else FULL,
                "mem_eps": MEM_EPS,
                "records": records,
            }, f, indent=2)
        print(f"wrote {args.json} ({len(records)} records)")
    if args.check:
        from benchmarks.run import step_summary

        lines = _check(records)
        print("\n".join(lines))
        step_summary("shard_bench", lines)


if __name__ == "__main__":
    main()
