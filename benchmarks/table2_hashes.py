"""Paper Table 2: effect of the number of CMS hash functions (1–4) on
running time, #supernodes, #superedges — plus size-estimate accuracy
(the paper's qualitative Fig. 4 claim, quantified)."""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from benchmarks.common import SUITE, row
from repro.core import biggraphvis, default_config
from repro.graph import mode_degree


def run(quick: bool = False) -> list[str]:
    rows = []
    name, (build, n) = list(SUITE.items())[0]
    edges_np = build()
    dt = mode_degree(edges_np, n)
    base = default_config(n, len(edges_np), dt, rounds=4, iterations=10,
                          s_cap=min(n, 16384))
    hash_counts = (1, 4) if quick else (1, 2, 3, 4)
    for rows_n in hash_counts:
        cfg = replace(base, cms=replace(base.cms, rows=rows_n))
        t0 = time.perf_counter()
        res = biggraphvis(edges_np, n, cfg)
        dt_s = time.perf_counter() - t0
        # accuracy: CMS sizes vs exact community degree-sums
        exact = np.zeros(cfg.s_cap)
        deg = np.zeros(n)
        np.add.at(deg, edges_np[:, 0], 1)
        np.add.at(deg, edges_np[:, 1], 1)
        np.add.at(exact, res.labels, deg)
        live = np.arange(cfg.s_cap) < res.n_supernodes
        err = np.mean(np.abs(res.sizes[live] - exact[live]) / np.maximum(exact[live], 1))
        rows.append(row(
            f"table2/{name}/hash{rows_n}", dt_s,
            f"SN={res.n_supernodes};SE={res.n_superedges};size_relerr={err:.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
