"""Roofline analysis (§Roofline of EXPERIMENTS.md).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
derives, per (arch × shape × mesh):

    compute term    = FLOPs/device   / 197e12      [bf16 peak per v5e chip]
    memory term     = HBM bytes/dev  / 819e9
    collective term = link bytes/dev / 50e9        [ICI per link]

FLOPs/device = max(cost_analysis flops, loop-adjusted HLO dot flops).
HBM bytes    = max(cost_analysis 'bytes accessed', loop-adjusted dot
               operand+output traffic) — both lower-bound true traffic;
               the max is the tighter bound.
Collectives  = ring-factor-adjusted payloads from the partitioned HLO.

Also reported: MODEL_FLOPS (analytic 6·N·D / 2·N·D), the useful-compute
ratio MODEL_FLOPS / (chips · FLOPs/dev), the dominant term, and the
roofline fraction = t_ideal_compute / max(t_c, t_m, t_coll) where
t_ideal = MODEL_FLOPS / (chips · peak).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip (v5e)
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    cost = rec.get("cost", {})
    flops_dev = max(float(cost.get("flops", 0.0)), float(rec.get("hlo_dot_flops", 0.0)))
    bytes_dev = max(
        float(cost.get("bytes accessed", 0.0)),
        float(rec.get("hlo_dot_traffic", rec.get("dot_traffic_bytes", 0.0)) or 0.0),
    )
    coll_dev = float(rec.get("collective_bytes", 0.0))
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / LINK_BW
    model_flops = float(rec.get("meta", {}).get("model_flops", 0.0))
    t_ideal = model_flops / (chips * PEAK_FLOPS)
    t_step = max(t_c, t_m, t_x)
    dominant = {t_c: "compute", t_m: "memory", t_x: "collective"}[t_step]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / max(chips * flops_dev, 1.0),
        "roofline_fraction": (t_ideal / t_step) if t_step > 0 else 0.0,
        "hbm_gib_per_dev": rec.get("bytes_per_device", 0) / 2**30,
        "compile_s": rec.get("compile_s", 0.0),
    }


def load_all(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        r = analyze_record(rec)
        if r:
            rows.append(r)
    return rows


def fmt_table(rows: list[dict], mesh: str = "single") -> str:
    hdr = ("| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | dominant | "
           "useful | roofline | GiB/dev |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['hbm_gib_per_dev']:.1f} |"
        )
    return "\n".join(out)


def run(quick: bool = False) -> list[str]:
    rows = load_all()
    csv = []
    for r in rows:
        csv.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s']) * 1e6:.0f},"
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.2f};gib={r['hbm_gib_per_dev']:.1f}"
        )
    return csv


if __name__ == "__main__":
    rows = load_all()
    print(fmt_table(rows, "single"))
    print()
    print(fmt_table(rows, "multi"))
