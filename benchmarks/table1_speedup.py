"""Paper Table 1: BigGraphVis (supergraph) vs full-graph ForceAtlas2 —
running time, speedup, supergraph size, SG (detection) time, modularity.

The paper reports 70–95× speedups on an Nvidia K20c; here the same
pipeline runs at CPU scale on the synthetic suite and reports the same
columns. The speedup mechanism is identical (layout cost ∝ n² drops to
S² with S ≪ n); absolute scale is projected via §Roofline.
"""
from __future__ import annotations

import time

import jax
from benchmarks.common import SUITE, row
from repro.core import biggraphvis, default_config
from repro.core import forceatlas2 as fa2
from repro.graph import mode_degree, pad_edges
from repro.graph.utils import degrees

import jax.numpy as jnp

FULL_ITERS = 100  # paper: 500 for full graphs; scaled 5× down like the rest
SG_ITERS = 20  # paper: 100


def run(quick: bool = False) -> list[str]:
    rows = []
    suite = dict(list(SUITE.items())[:1]) if quick else SUITE
    for name, (build, n) in suite.items():
        edges_np = build()
        dt = mode_degree(edges_np, n)
        cfg = default_config(n, len(edges_np), dt, rounds=4, iterations=SG_ITERS,
                             s_cap=min(n, 16384))

        # --- BigGraphVis (supergraph pipeline); warm timing — the first
        # call pays one-time jit compilation, the second is steady state
        # (the paper's GPU numbers likewise exclude CUDA compilation)
        biggraphvis(edges_np, n, cfg)
        t0 = time.perf_counter()
        res = biggraphvis(edges_np, n, cfg)
        bgv_s = time.perf_counter() - t0
        sg_s = res.timings["scoda_s"] + res.timings["supergraph_s"]

        # --- full-graph FA2 baseline (grid repulsion — the BH analogue)
        edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
        deg = degrees(edges, n)
        mass = deg.astype(jnp.float32) + 1.0
        w = jnp.ones(edges.shape[0], jnp.float32)
        lcfg = fa2.FA2Config(iterations=FULL_ITERS, repulsion="grid",
                             grid_size=64, use_radii=False)
        pos, _, _ = fa2.layout(edges, w, mass, n, lcfg)  # compile warmup
        jax.block_until_ready(pos)
        t0 = time.perf_counter()
        pos, _, _ = fa2.layout(edges, w, mass, n, lcfg)
        jax.block_until_ready(pos)
        fa2_s = time.perf_counter() - t0

        speedup = fa2_s / bgv_s
        rows.append(row(
            f"table1/{name}/fa2_full", fa2_s,
            f"n={n};e={len(edges_np)}"))
        rows.append(row(
            f"table1/{name}/biggraphvis", bgv_s,
            f"SN={res.n_supernodes};SE={res.n_superedges};"
            f"SGtime_ms={sg_s*1e3:.0f};speedup={speedup:.1f}x;M={res.modularity:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
