"""Paper Figs. 5 & 7: degree-threshold sensitivity (δ, 3δ, 5δ) and CMS
width sensitivity (5k vs 15k columns equivalents, scaled)."""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from benchmarks.common import SUITE, row
from repro.core import biggraphvis, default_config
from repro.graph import mode_degree


def run(quick: bool = False) -> list[str]:
    rows = []
    name, (build, n) = list(SUITE.items())[0]
    edges_np = build()
    dt = max(2, mode_degree(edges_np, n))
    base = default_config(n, len(edges_np), dt, rounds=4, iterations=10,
                          s_cap=min(n, 16384))

    # Fig 5: threshold δ, 3δ, 5δ
    for mult in (1, 3, 5):
        cfg = replace(base, scoda=replace(base.scoda, degree_threshold=dt * mult))
        t0 = time.perf_counter()
        res = biggraphvis(edges_np, n, cfg)
        rows.append(row(
            f"fig5/{name}/thr{mult}x", time.perf_counter() - t0,
            f"SN={res.n_supernodes};M={res.modularity:.3f}"))
        if quick:
            break

    # Fig 7: sketch width (cols) small vs large
    for cols in (max(64, base.cms.cols // 3), base.cms.cols * 3):
        cfg = replace(base, cms=replace(base.cms, cols=cols))
        t0 = time.perf_counter()
        res = biggraphvis(edges_np, n, cfg)
        exact = np.zeros(cfg.s_cap)
        deg = np.zeros(n)
        np.add.at(deg, edges_np[:, 0], 1)
        np.add.at(deg, edges_np[:, 1], 1)
        np.add.at(exact, res.labels, deg)
        live = np.arange(cfg.s_cap) < res.n_supernodes
        err = np.mean(np.abs(res.sizes[live] - exact[live]) / np.maximum(exact[live], 1))
        rows.append(row(
            f"fig7/{name}/cols{cols}", time.perf_counter() - t0,
            f"size_relerr={err:.4f}"))
        if quick:
            break
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
