"""Raster-stage benchmark: node-disk and streamed edge-splat throughput
(Mpixels/s, edges/s) per raster backend and resolution (repro/render).

The edge pass is the renderer's scaling stage: chunks stream through
``EdgeChunkStream`` and splat through ``kernels/raster``, so its device
residency (accumulation buffers + chunk buffers) must be independent of
|E| — the residency sweep renders the same scene at |E| and 4·|E| and
records both peaks.

    PYTHONPATH=src python -m benchmarks.render_bench
    PYTHONPATH=src python -m benchmarks.render_bench --quick --json r.json
    PYTHONPATH=src python -m benchmarks.render_bench --check
    PYTHONPATH=src python -m benchmarks.run --only render

CSV rows (name,us_per_call,derived) per the harness contract; ``--json``
writes the structured records (the CI ``render-smoke`` artifact).
``--check`` asserts the acceptance bar: the streamed edge-splat stage
sustains ≥ 1M edges/s at the check point (512², 4 samples/edge), and
peak render device bytes are bit-equal across the |E| vs 4·|E| runs.
"""
from __future__ import annotations

import argparse
import json
from dataclasses import replace

import jax
import numpy as np

from benchmarks.common import row
from repro.render import RenderConfig, render_arrays

N_NODES = 5000
EDGES_FULL = 1 << 20
EDGES_QUICK = 1 << 19
RES_FULL = (512, 1024)
RES_QUICK = (512,)
CHECK_EDGES_PER_S = 1e6
CHECK_CFG = dict(width=512, height=512, edge_samples=4, chunk_size=1 << 16)


def _backends() -> tuple:
    return ("ref", "pallas") if jax.default_backend() == "tpu" else ("ref",)


def _scene(n_edges: int, seed: int = 7):
    """Synthetic layout + edges: raster cost is shape/occupancy-driven,
    not layout-quality-driven, so random positions keep the bench
    independent of SCoDA/FA2."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(0.0, 100.0, (N_NODES, 2)).astype(np.float32)
    radii = rng.uniform(0.5, 4.0, N_NODES).astype(np.float32)
    groups = rng.integers(0, 11, N_NODES).astype(np.int32)
    edges = rng.integers(0, N_NODES, (n_edges, 2)).astype(np.int32)
    return pos, radii, groups, edges


def _best_stats(fn, repeat: int = 3):
    """Warm (compile) once, then keep the fastest run's stats."""
    fn()
    best = None
    for _ in range(repeat):
        _, st = fn()
        if best is None or st.seconds < best.seconds:
            best = st
    return best


def run(quick: bool = False, records: list | None = None,
        edges_np: np.ndarray | None = None):
    pos, radii, groups, edges = _scene(EDGES_QUICK if quick else EDGES_FULL)
    if edges_np is not None:
        edges = edges_np
    resolutions = RES_QUICK if quick else RES_FULL
    for backend in _backends():
        for res in resolutions:
            base = RenderConfig(width=res, height=res, backend=backend,
                                time_raster=True)
            # Node-disk pass (dense per-pixel coverage).
            st = _best_stats(lambda: render_arrays(
                pos, radii, groups, None, cfg=base))
            mpix = res * res / max(st.node_raster_s, 1e-9) / 1e6
            yield row(
                f"render/nodes/{backend}/r{res}", st.node_raster_s,
                f"mpix_s={mpix:.1f};nodes={st.nodes_drawn}",
            )
            if records is not None:
                records.append({
                    "kind": "nodes", "backend": backend, "res": res,
                    "nodes": st.nodes_drawn,
                    "node_raster_s": st.node_raster_s, "mpix_s": mpix,
                })
            # Streamed edge-splat pass.
            for samples in (4, 8):
                cfg = replace(base, draw_nodes=False, edge_samples=samples)
                st = _best_stats(lambda c=cfg: render_arrays(
                    pos, radii, groups, edges, cfg=c))
                eps = st.edges_per_s
                yield row(
                    f"render/edges/{backend}/r{res}/s{samples}",
                    st.edge_raster_s,
                    f"edges_s={eps / 1e6:.2f}M;chunks={st.chunks}",
                )
                if records is not None:
                    records.append({
                        "kind": "edges", "backend": backend, "res": res,
                        "samples": samples, "n_edges": len(edges),
                        "chunks": st.chunks,
                        "edge_raster_s": st.edge_raster_s,
                        "edges_per_s": eps,
                        "raster_update_s": st.stream.raster_update_s,
                        "peak_device_bytes": st.peak_device_bytes,
                    })

    # Residency sweep: same scene at |E| and 4·|E| — the renderer's peak
    # device bytes must not move (chunked accumulation, fixed buffers).
    cfg = RenderConfig(**CHECK_CFG, draw_nodes=False)
    for scale_tag, e in (("E", edges), ("4E", np.tile(edges, (4, 1)))):
        _, st = render_arrays(pos, radii, groups, e, cfg=cfg)
        yield row(
            f"render/residency/{scale_tag}", st.edge_raster_s,
            f"peak_device_bytes={st.peak_device_bytes};n_edges={len(e)}",
        )
        if records is not None:
            records.append({
                "kind": "residency", "scale": scale_tag, "n_edges": len(e),
                "peak_device_bytes": st.peak_device_bytes,
                "edges_per_s": st.edges_per_s,
            })


def _check(records: list) -> list[str]:
    """Acceptance bar: ≥ 1M edges/s at the check point; peak device bytes
    bit-equal across the |E| / 4·|E| residency runs. Returns the result
    lines (printed and fed to ``run.step_summary``)."""
    pts = [r for r in records if r["kind"] == "edges"
           and r["res"] == CHECK_CFG["width"]
           and r["samples"] == CHECK_CFG["edge_samples"]]
    assert pts, "no check-point records (res=512, samples=4)"
    best = max(p["edges_per_s"] for p in pts)
    assert best >= CHECK_EDGES_PER_S, (
        f"edge splat too slow: {best / 1e6:.2f}M edges/s "
        f"< {CHECK_EDGES_PER_S / 1e6:.0f}M"
    )
    peaks = {r["scale"]: r["peak_device_bytes"] for r in records
             if r["kind"] == "residency"}
    assert peaks["E"] == peaks["4E"], (
        f"render residency grew with |E|: {peaks['E']:,} → {peaks['4E']:,}"
    )
    return [
        f"check: edge splat {best / 1e6:.2f}M edges/s ≥ 1M",
        f"check: peak device bytes |E|-independent ({peaks['E']:,})",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="smaller sweep")
    ap.add_argument("--json", default="",
                    help="also write structured records to this path")
    ap.add_argument("--edges", default="",
                    help="bench a converted .npy edge file instead of the "
                         "synthetic scene (node ids are remapped mod N)")
    ap.add_argument("--check", action="store_true",
                    help="assert ≥1M edges/s and |E|-independent residency")
    args = ap.parse_args()

    edges_np = None
    if args.edges:
        from repro.data.edge_store import NpyEdgeStore

        store = NpyEdgeStore(args.edges)
        edges_np = store.read(0, store.n_edges) % N_NODES
    records: list = []
    print("name,us_per_call,derived")
    for line in run(quick=args.quick, records=records, edges_np=edges_np):
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "bench": "render_bench",
                "n_nodes": N_NODES,
                "backends": list(_backends()),
                "check_cfg": CHECK_CFG,
                "records": records,
            }, f, indent=2)
        print(f"wrote {args.json} ({len(records)} records)")
    if args.check:
        from benchmarks.run import step_summary

        lines = _check(records)
        print("\n".join(lines))
        step_summary("render_bench", lines)


if __name__ == "__main__":
    main()
