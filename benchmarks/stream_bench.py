"""Streaming engine vs one-shot: pass count, throughput, copy/compute
overlap, and peak device/host bytes — in-memory vs memory-mapped sources,
prefetch on/off.

    PYTHONPATH=src python -m benchmarks.stream_bench
    PYTHONPATH=src python -m benchmarks.stream_bench --source mmap --quick \
        --json stream.json
    PYTHONPATH=src python -m benchmarks.stream_bench --source mmap \
        --edges edges.npy --nodes 8000
    PYTHONPATH=src python -m benchmarks.run --only stream

CSV rows (name,us_per_call,derived) per the harness contract; ``--json``
additionally writes the structured records (the CI ``stream-smoke``
artifact). The streamed chunk size is FIXED (not scaled to |E|), so for
the mmap source peak host bytes is the staging ring alone — independent
of |E| — while the in-memory source's host residency is the edge list
itself. Every streamed run is asserted bit-for-bit identical to the
one-shot result, and ``copy_stall_s``/``host_fill_s`` quantify how much
of the run the double-buffered staging pipeline failed to hide.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from dataclasses import replace

import numpy as np

from benchmarks.common import SUITE, row, time_call
from repro.core import StreamConfig, biggraphvis, default_config
from repro.data.edge_store import NpyEdgeStore, write_npy
from repro.graph import mode_degree

# block_size must divide the chunk for the chunked block partition to match
# one-shot (bit-exact results); the chunk is fixed so streamed residency —
# device chunk buffers and, for disk sources, host staging — is a constant,
# not a function of |E|.
BLOCK = 2048
CHUNK = 16384


def _bench_config(n: int, e: int, edges: np.ndarray, rounds: int):
    cfg = default_config(n, e, mode_degree(edges, n), rounds=rounds, iterations=10)
    return replace(cfg, scoda=replace(cfg.scoda, block_size=BLOCK))


def _check_match(name: str, res_one, res_str) -> None:
    assert np.array_equal(res_one.labels, res_str.labels), name
    assert np.array_equal(
        np.asarray(res_one.supergraph.edges), np.asarray(res_str.supergraph.edges)
    ), name
    assert res_one.modularity == res_str.modularity, name


def bench_graph(
    name: str,
    edges: np.ndarray,
    n: int,
    rounds: int = 4,
    sources: tuple = ("memory", "mmap"),
    prefetches: tuple = (0, 1),
    records: list | None = None,
    mmap_path: str | None = None,
):
    """Yield CSV rows (and append structured records) for one suite graph.

    ``mmap_path`` reuses an existing on-disk ``.npy`` for the mmap source
    instead of writing a temp copy. The in-memory ``edges`` array is still
    required: the one-shot reference run is what every streamed result is
    compared against, so this driver is bounded by host memory by design.
    """
    e = len(edges)
    if e <= CHUNK:
        raise SystemExit(
            f"{name}: {e} edges fit in one {CHUNK}-row chunk — nothing to "
            "stream; use a larger graph"
        )
    cfg = _bench_config(n, e, edges, rounds)
    scfg = StreamConfig(chunk_size=CHUNK)

    res_one = biggraphvis(edges, n, cfg)
    s_one = res_one.stream
    t_one = time_call(lambda: biggraphvis(edges, n, cfg))
    yield row(
        f"bgv_oneshot/{name}", t_one,
        f"passes={s_one.passes};chunks={s_one.chunks};"
        f"chunk_size={s_one.chunk_size};peak_bytes={s_one.peak_device_bytes}",
    )
    if records is not None:
        records.append({
            "graph": name, "source": "oneshot", "prefetch": 0,
            "n_nodes": n, "n_edges": e, "us_per_call": t_one * 1e6,
            "passes": s_one.passes, "chunk_size": s_one.chunk_size,
            "peak_device_bytes": s_one.peak_device_bytes,
            "peak_host_bytes": s_one.peak_host_bytes,
        })

    with tempfile.TemporaryDirectory() as tmp:
        for source in sources:
            if source == "mmap" and mmap_path is None:
                mmap_path = write_npy(os.path.join(tmp, f"{name}.npy"), edges)
            for prefetch in prefetches:
                pcfg = replace(scfg, prefetch=prefetch)
                src = edges if source == "memory" else NpyEdgeStore(mmap_path)
                res = biggraphvis(src, n, cfg, stream=pcfg)
                _check_match(f"{name}/{source}", res_one, res)
                s = res.stream
                assert s.peak_device_bytes < s_one.peak_device_bytes, (
                    name, s.peak_device_bytes, s_one.peak_device_bytes)
                if source == "mmap":
                    # out-of-core: host residency is the staging ring alone
                    assert s.peak_host_bytes <= (prefetch + 2) * s.chunk_size * 8, (
                        name, s.peak_host_bytes)
                t = time_call(lambda: biggraphvis(src, n, cfg, stream=pcfg))
                derived = (
                    f"passes={s.passes};chunks={s.chunks};"
                    f"chunk_size={s.chunk_size};"
                    f"edges_per_s={s.edges_per_s:.3e};"
                    f"stall_s={s.copy_stall_s:.4f};fill_s={s.host_fill_s:.4f};"
                    f"peak_bytes={s.peak_device_bytes};"
                    f"peak_host_bytes={s.peak_host_bytes}"
                )
                yield row(f"bgv_stream/{name}/{source}/pf{prefetch}", t, derived)
                if records is not None:
                    records.append({
                        "graph": name, "source": source, "prefetch": prefetch,
                        "n_nodes": n, "n_edges": e, "us_per_call": t * 1e6,
                        "passes": s.passes, "chunks": s.chunks,
                        "chunk_size": s.chunk_size,
                        "edges_per_s": s.edges_per_s,
                        "copy_stall_s": s.copy_stall_s,
                        "host_fill_s": s.host_fill_s,
                        "peak_device_bytes": s.peak_device_bytes,
                        "peak_host_bytes": s.peak_host_bytes,
                    })


def run(quick: bool = False, sources: tuple = ("memory", "mmap"),
        records: list | None = None):
    names = list(SUITE)[:1] if quick else list(SUITE)
    for name in names:
        builder, n = SUITE[name]
        yield from bench_graph(
            name, builder(), n, rounds=2 if quick else 4,
            sources=sources, prefetches=(0, 1), records=records,
        )


def _check_host_bytes_flat(records: list) -> list[str]:
    """mmap host residency must not grow with |E| across suite graphs.
    Returns the result lines (printed and fed to ``run.step_summary``)."""
    by_pf = {}
    for r in records:
        if r["source"] == "mmap":
            by_pf.setdefault(r["prefetch"], set()).add(r["peak_host_bytes"])
    for pf, vals in by_pf.items():
        assert len(vals) == 1, f"mmap peak_host_bytes varies with |E|: {vals}"
    return [
        f"check: mmap peak_host_bytes |E|-independent at prefetch={pf} "
        f"({next(iter(vals)):,} bytes)"
        for pf, vals in sorted(by_pf.items())
    ]


def _check(records: list, sources: tuple) -> list[str]:
    """Streamed-run acceptance summary. The hard bit-identity and residency
    assertions already ran inline in ``bench_graph`` (every streamed run is
    compared to its one-shot reference as it happens); this recaps them for
    the CI step summary and re-asserts the cross-graph mmap invariant."""
    streamed = [r for r in records if r["source"] in ("memory", "mmap")]
    assert streamed, "no streamed records in the sweep"
    lines = [
        f"check: all {len(streamed)} streamed runs bit-identical to "
        "one-shot, peak device bytes below one-shot residency"
    ]
    mmap_graphs = {r["graph"] for r in streamed if r["source"] == "mmap"}
    if "mmap" in sources and len(mmap_graphs) > 1:
        lines += _check_host_bytes_flat(records)
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="1 graph / fewer rounds")
    ap.add_argument("--source", choices=("memory", "mmap", "both"),
                    default="both")
    ap.add_argument("--json", default="",
                    help="also write structured records to this path")
    ap.add_argument("--edges", default="",
                    help="bench a converted edge file instead of the suite")
    ap.add_argument("--nodes", type=int, default=0,
                    help="node count of --edges (required with it)")
    ap.add_argument("--check", action="store_true",
                    help="summarize the inline streamed==one-shot and "
                         "residency assertions (and re-assert mmap host "
                         "bytes flat across graphs when applicable)")
    args = ap.parse_args()

    sources = ("memory", "mmap") if args.source == "both" else (args.source,)
    records: list = []
    print("name,us_per_call,derived")
    if args.edges:
        if not args.nodes:
            raise SystemExit("--edges requires --nodes")
        store = NpyEdgeStore(args.edges)
        edges = store.read(0, store.n_edges)  # one-shot reference input
        name = os.path.basename(args.edges)
        for line in bench_graph(
            name, edges, args.nodes, rounds=2 if args.quick else 4,
            sources=sources, prefetches=(0, 1), records=records,
            mmap_path=args.edges,
        ):
            print(line)
    else:
        for line in run(quick=args.quick, sources=sources, records=records):
            print(line)
        if not args.quick and "mmap" in sources:
            _check_host_bytes_flat(records)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "bench": "stream_bench",
                "chunk_rows": CHUNK,
                "sources": list(sources),
                "records": records,
            }, f, indent=2)
        print(f"wrote {args.json} ({len(records)} records)")
    if args.check:
        from benchmarks.run import step_summary

        lines = _check(records, sources)
        print("\n".join(lines))
        step_summary("stream_bench", lines)


if __name__ == "__main__":
    main()
