"""Streaming engine vs one-shot: pass count, chunk throughput, peak device
bytes.

    PYTHONPATH=src python -m benchmarks.stream_bench
    PYTHONPATH=src python -m benchmarks.run --only stream

CSV rows (name,us_per_call,derived) per the harness contract. For each
suite graph the one-shot path (whole edge list as a single chunk) is
compared against the streamed path (chunk size = |E|/8): the streamed run
must report lower peak device bytes — its residency swaps the full edge
materialization for chunk buffers — while producing identical labels and
supergraph.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.common import SUITE, row, time_call
from repro.core import StreamConfig, biggraphvis, default_config
from repro.graph import mode_degree


def bench_graph(name: str, edges: np.ndarray, n: int, rounds: int = 4):
    e = len(edges)
    # block_size must divide the chunk for the chunked block partition to
    # match one-shot (bit-exact results); chunk ≈ |E|/8 → a real multi-chunk
    # stream on every suite graph.
    block = 2048
    chunk = max(block, (e // 8 // block) * block)
    cfg = default_config(n, e, mode_degree(edges, n), rounds=rounds, iterations=10)
    cfg = replace(cfg, scoda=replace(cfg.scoda, block_size=block))
    scfg = StreamConfig(chunk_size=chunk)

    res_one = biggraphvis(edges, n, cfg)
    res_str = biggraphvis(edges, n, cfg, stream=scfg)
    assert np.array_equal(res_one.labels, res_str.labels), name
    assert np.array_equal(
        np.asarray(res_one.supergraph.edges), np.asarray(res_str.supergraph.edges)
    ), name
    s_one, s_str = res_one.stream, res_str.stream
    assert s_str.peak_device_bytes < s_one.peak_device_bytes, (
        name, s_str.peak_device_bytes, s_one.peak_device_bytes)

    t_one = time_call(lambda: biggraphvis(edges, n, cfg))
    t_str = time_call(lambda: biggraphvis(edges, n, cfg, stream=scfg))
    yield row(
        f"bgv_oneshot/{name}", t_one,
        f"passes={s_one.passes};chunks={s_one.chunks};"
        f"chunk_size={s_one.chunk_size};peak_bytes={s_one.peak_device_bytes}",
    )
    yield row(
        f"bgv_stream/{name}", t_str,
        f"passes={s_str.passes};chunks={s_str.chunks};"
        f"chunk_size={s_str.chunk_size};"
        f"edges_per_s={s_str.edges_per_s:.3e};"
        f"peak_bytes={s_str.peak_device_bytes}",
    )


def run(quick: bool = False):
    names = list(SUITE)[:1] if quick else list(SUITE)
    for name in names:
        builder, n = SUITE[name]
        yield from bench_graph(name, builder(), n, rounds=2 if quick else 4)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line)
