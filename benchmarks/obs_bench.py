"""Observability overhead: the streamed BigGraphVis workload timed with
tracing off vs on — the ``repro.obs`` instrumentation must stay off the
hot path (``--check`` gates traced-on ≤ 3% slower, best-of-N both sides),
and the traced run's Chrome-trace export must carry the full span tree
(detect/supergraph/layout with per-chunk children).

    PYTHONPATH=src python -m benchmarks.obs_bench [--quick] [--check] \
        [--json obs.json] [--trace-out obs.trace.json]
    PYTHONPATH=src python -m benchmarks.run --only obs

CSV rows (name,us_per_call,derived) per the harness contract.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from dataclasses import replace

from benchmarks.common import SUITE, make_record, row, time_call, write_bench_json
from repro.core import StreamConfig, biggraphvis, default_config
from repro.graph import mode_degree
from repro.obs.trace import NULL_TRACER, Tracer

# Mirror stream_bench's fixed streaming shape (chunked run, several chunks
# per pass) so the overhead gate measures the instrumented path the other
# benches time.
BLOCK = 2048
CHUNK = 16384

OVERHEAD_GATE = 1.03  # traced-on / traced-off wall ratio ceiling

# Span names the traced workload must produce, each with at least one
# per-chunk (or per-call) child underneath.
REQUIRED_SPANS = ("biggraphvis", "detect", "detect.chunk", "supergraph",
                  "supergraph.chunk", "layout")


def _setup(graph: str, rounds: int):
    builder, n = SUITE[graph]
    edges = builder()
    cfg = default_config(n, len(edges), mode_degree(edges, n),
                         rounds=rounds, iterations=10)
    cfg = replace(cfg, scoda=replace(cfg.scoda, block_size=BLOCK))
    scfg = StreamConfig(chunk_size=CHUNK)
    return edges, n, cfg, scfg


def measure(graph: str = "ppart-8k", rounds: int = 2, repeat: int = 3):
    """(t_off, t_on, tracer) — best-of-``repeat`` streamed pipeline wall
    with tracing disabled (explicit null tracer) vs enabled (a private
    enabled tracer threaded via ``BGVConfig.obs``; the process-global
    tracer is never touched). The returned tracer holds the spans of the
    traced runs for export/validation."""
    edges, n, cfg, scfg = _setup(graph, rounds)

    cfg_off = replace(cfg, obs=NULL_TRACER)
    t_off = time_call(lambda: biggraphvis(edges, n, cfg_off, stream=scfg),
                      repeat=repeat)

    tracer = Tracer(enabled=True)
    cfg_on = replace(cfg, obs=tracer)

    def traced():
        tracer.clear()  # bound span memory: keep only the last run's tree
        biggraphvis(edges, n, cfg_on, stream=scfg)

    t_on = time_call(traced, repeat=repeat)
    return t_off, t_on, tracer


def validate_chrome_trace(path: str) -> dict:
    """Load a Chrome trace-event file and assert the BigGraphVis span tree
    is present: valid JSON, ``traceEvents`` complete-span records, every
    ``REQUIRED_SPANS`` name at least once, and the per-chunk child spans
    under both stream stages. Returns {span name: count}."""
    with open(path) as f:
        doc = json.load(f)
    events = [e for e in doc.get("traceEvents", []) if e.get("ph") == "X"]
    assert events, f"{path}: no complete ('X') trace events"
    for e in events:
        assert {"name", "ts", "dur", "pid", "tid"} <= set(e), e
    counts: dict = {}
    for e in events:
        counts[e["name"]] = counts.get(e["name"], 0) + 1
    missing = [s for s in REQUIRED_SPANS if s not in counts]
    assert not missing, f"{path}: missing spans {missing} (have {sorted(counts)})"
    assert counts["detect.chunk"] >= counts["detect"], counts
    assert counts["supergraph.chunk"] >= counts["supergraph"], counts
    return counts


def run(quick: bool = False, records: list | None = None,
        trace_out: str | None = None):
    repeat = 2 if quick else 3
    rounds = 2
    t_off, t_on, tracer = measure(rounds=rounds, repeat=repeat)
    ratio = t_on / t_off if t_off else float("inf")
    n_spans = len(tracer.spans())
    derived = (f"ratio={ratio:.4f};spans={n_spans};"
               f"traced_off_us={t_off * 1e6:.0f}")
    yield row("obs_overhead/ppart-8k/off", t_off, "spans=0")
    yield row("obs_overhead/ppart-8k/on", t_on, derived)
    if records is not None:
        records.append(make_record(
            "obs_overhead/ppart-8k",
            config={"graph": "ppart-8k", "rounds": rounds,
                    "chunk_size": CHUNK, "repeat": repeat,
                    "gate": OVERHEAD_GATE},
            metrics={"us_per_call": t_on * 1e6,
                     "traced_off_us": t_off * 1e6,
                     "overhead_ratio": ratio, "spans": n_spans},
        ))
    if trace_out:
        tracer.to_chrome(trace_out)


def check(records: list, trace_out: str) -> list[str]:
    """The CI gates: tracing-on within ``OVERHEAD_GATE`` of tracing-off,
    and the exported Chrome trace structurally complete."""
    assert records, "no records measured"
    r = records[-1]["metrics"]
    ratio = r["overhead_ratio"]
    assert ratio <= OVERHEAD_GATE, (
        f"tracing overhead {ratio:.4f} exceeds gate {OVERHEAD_GATE}: "
        f"off={r['traced_off_us']:.0f}us on={r['us_per_call']:.0f}us"
    )
    counts = validate_chrome_trace(trace_out)
    return [
        f"check: tracing-on/off ratio {ratio:.4f} <= {OVERHEAD_GATE} "
        f"(off {r['traced_off_us'] / 1e3:.1f}ms, "
        f"on {r['us_per_call'] / 1e3:.1f}ms)",
        f"check: Chrome trace valid — {sum(counts.values())} spans, "
        f"all of {', '.join(REQUIRED_SPANS)} present with per-chunk "
        "children",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="fewer repeats")
    ap.add_argument("--json", default="",
                    help="write unified structured records to this path")
    ap.add_argument("--trace-out", default="",
                    help="keep the traced run's Chrome trace at this path")
    ap.add_argument("--check", action="store_true",
                    help="gate overhead <= 3% and validate the trace export")
    args = ap.parse_args()

    records: list = []
    tmp = None
    trace_out = args.trace_out
    if not trace_out:
        tmp = tempfile.NamedTemporaryFile(
            suffix=".trace.json", delete=False)
        trace_out = tmp.name
        tmp.close()
    try:
        print("name,us_per_call,derived")
        for line in run(quick=args.quick, records=records,
                        trace_out=trace_out):
            print(line)
        if args.json:
            import time as _time

            write_bench_json(args.json, "obs_bench", records,
                             timestamp=_time.time())
            print(f"wrote {args.json} ({len(records)} records)")
        if args.check:
            from benchmarks.run import step_summary

            lines = check(records, trace_out)
            print("\n".join(lines))
            step_summary("obs_bench", lines)
    finally:
        if tmp is not None:
            os.unlink(trace_out)


if __name__ == "__main__":
    main()
