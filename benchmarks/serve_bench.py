"""Tile-serving benchmark: zipfian pan/zoom traffic over the tile-pyramid
service (repro/serve/tiles.py) — tiles/s, cache hit rate, and miss-latency
percentiles, plus the service's two correctness bars: steady-state ticks
must trigger **zero recompilation** (fixed tile shapes), and every served
tile must be **bit-identical** to a direct one-shot ``render_arrays`` of
the same viewport.

    PYTHONPATH=src python -m benchmarks.serve_bench
    PYTHONPATH=src python -m benchmarks.serve_bench --quick --json s.json
    PYTHONPATH=src python -m benchmarks.serve_bench --check
    PYTHONPATH=src python -m benchmarks.run --only serve

Phases: a warm-up renders every pyramid tile plus the drill pool (the
compile phase — its cost is what ``launch/serve.py``'s persistent
compilation cache amortizes across restarts), then the measured phase
replays a ``synthetic_trace`` against a deliberately undersized LRU cache
so steady-state misses exist and their re-render latency is measurable.

CSV rows (name,us_per_call,derived) per the harness contract; ``--json``
writes the structured records (the CI ``serve-smoke`` artifact).
``--check`` asserts the acceptance bar: warm-cache hit rate ≥ 80%, zero
steady-state recompiles, p99 miss latency under the tail bar, and served
== direct bit-identity on sampled tiles.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import row
from repro.core import biggraphvis, default_config
from repro.graph import mode_degree, planted_partition
from repro.render import render_arrays
from repro.serve.tiles import (
    TileConfig,
    TileEngine,
    TilePyramid,
    TileRequest,
    TileSpec,
    jit_compile_count,
    synthetic_trace,
)

N_NODES = 3000
N_COMMUNITIES = 30
DRILL_POOL = 8
# Measured-phase cache capacity as a fraction of the full working set
# (pyramid + drill pool): small enough that eviction misses exist in
# steady state, big enough that the zipf-hot set stays resident.
CACHE_FRAC = 0.6
CHECK_HIT_RATE = 0.80
CHECK_P99_MISS_S = 2.0  # generous CI bar; ~0.2s measured on a laptop core
IDENTITY_SAMPLES = 6


def _setup(quick: bool):
    edges, _ = planted_partition(N_NODES, N_COMMUNITIES, 0.15, 0.001, seed=42)
    cfg = default_config(
        N_NODES, len(edges), mode_degree(edges, N_NODES),
        iterations=40 if quick else 60, s_cap=1024,
    )
    result = biggraphvis(edges, N_NODES, cfg)
    tile_cfg = TileConfig(
        tile_size=128 if quick else 256,
        depth=3 if quick else 4,
        drill_iterations=30 if quick else 60,
    )
    pyramid = TilePyramid(result, tile_cfg, source=edges, bgv_cfg=cfg)
    return pyramid


def _percentile(values, q: float) -> float:
    return float(np.percentile(np.asarray(values), q)) if len(values) else 0.0


def run(quick: bool = False, records: list | None = None):
    pyramid = _setup(quick)
    n_pyramid = sum(pyramid.n_tiles(z) ** 2 for z in range(pyramid.cfg.depth))
    drills = pyramid.drillable_communities()[:DRILL_POOL]
    tile_bytes = pyramid.cfg.tile_size ** 2 * 3
    working_set = (n_pyramid + len(drills)) * tile_bytes
    engine = TileEngine(
        pyramid, cache_bytes=int(CACHE_FRAC * working_set), slots=8
    )

    # Phase 1 — warm-up: compiles every render entry the traffic can hit.
    c0 = jit_compile_count()
    t0 = time.perf_counter()
    warmed = engine.warmup(drills=drills)
    warm_s = time.perf_counter() - t0
    warm_compiles = jit_compile_count() - c0
    yield row(
        "serve/warmup", warm_s,
        f"tiles={warmed};compiles={warm_compiles}",
    )

    # Phase 2 — measured traffic against the undersized cache.
    n_requests = 600 if quick else 2000
    trace = synthetic_trace(
        pyramid, n_requests, drill_pool=DRILL_POOL, seed=0
    )
    c1 = jit_compile_count()
    hits0, misses0 = engine.cache.hits, engine.cache.misses
    miss_lat: list[float] = []
    t0 = time.perf_counter()
    for spec in trace:
        req = TileRequest(spec)
        engine.submit(req)
        while not req.done:
            engine.tick()
        if not req.hit:
            miss_lat.append(req.latency_s)
    dt = time.perf_counter() - t0
    steady_compiles = jit_compile_count() - c1
    hits = engine.cache.hits - hits0
    hit_rate = hits / max(engine.cache.hits + engine.cache.misses
                          - hits0 - misses0, 1)
    p50, p99 = _percentile(miss_lat, 50), _percentile(miss_lat, 99)
    yield row(
        "serve/traffic", dt,
        f"tiles_s={len(trace) / dt:.1f};hit_rate={hit_rate:.3f};"
        f"misses={len(miss_lat)};p50_ms={p50 * 1e3:.0f};"
        f"p99_ms={p99 * 1e3:.0f};recompiles={steady_compiles}",
    )

    # Phase 3 — served == direct bit-identity on sampled pyramid tiles:
    # whatever the cache did, a served tile must equal a fresh one-shot
    # render_arrays of the same viewport.
    pyramid_specs = [s for s in trace if isinstance(s, TileSpec)]
    rng = np.random.default_rng(7)
    sample_idx = rng.choice(
        len(pyramid_specs), size=min(IDENTITY_SAMPLES, len(pyramid_specs)),
        replace=False,
    )
    identical = 0
    samples = [pyramid_specs[int(i)] for i in sample_idx]
    for spec in samples:
        served = engine.request(spec)
        direct, _ = render_arrays(
            pyramid.result.positions,
            np.sqrt(np.maximum(np.asarray(pyramid.result.sizes), 0.0)),
            pyramid.result.groups,
            np.asarray(pyramid.result.supergraph.edges),
            edge_weights=np.asarray(pyramid.result.supergraph.weights),
            cfg=pyramid.render_config(spec),
        )
        identical += int(np.array_equal(served, direct))
    yield row(
        "serve/identity", 0.0,
        f"identical={identical}/{len(samples)}",
    )

    if records is not None:
        records.append({
            "kind": "serve",
            "tile_size": pyramid.cfg.tile_size,
            "depth": pyramid.cfg.depth,
            "pyramid_tiles": n_pyramid,
            "drill_pool": int(len(drills)),
            "cache_bytes": engine.cache.capacity_bytes,
            "warmup_s": warm_s,
            "warmup_compiles": warm_compiles,
            "requests": len(trace),
            "seconds": dt,
            "tiles_per_s": len(trace) / dt,
            "hit_rate": hit_rate,
            "misses": len(miss_lat),
            "p50_miss_s": p50,
            "p99_miss_s": p99,
            "steady_compiles": steady_compiles,
            "evictions": engine.cache.evictions,
            "identity_ok": identical,
            "identity_total": len(samples),
        })


def _check(records: list) -> list[str]:
    """Acceptance bar (ISSUE 7): warm-cache hit rate ≥ 80%, zero
    steady-state recompiles, tail latency under the bar, and bit-identity
    of served vs direct tiles. Returns the result lines."""
    (r,) = [r for r in records if r["kind"] == "serve"]
    assert r["hit_rate"] >= CHECK_HIT_RATE, (
        f"warm-cache hit rate {r['hit_rate']:.3f} < {CHECK_HIT_RATE}"
    )
    assert r["steady_compiles"] == 0, (
        f"steady-state ticks recompiled {r['steady_compiles']} times "
        "(tile shapes should be fixed after warm-up)"
    )
    assert r["misses"] > 0, (
        "no steady-state misses — cache sizing broke; miss latency unmeasured"
    )
    assert r["p99_miss_s"] <= CHECK_P99_MISS_S, (
        f"p99 miss latency {r['p99_miss_s']:.2f}s > {CHECK_P99_MISS_S}s"
    )
    assert r["identity_ok"] == r["identity_total"], (
        f"served tiles diverged from direct render_arrays: "
        f"{r['identity_ok']}/{r['identity_total']} identical"
    )
    return [
        f"check: warm-cache hit rate {r['hit_rate']:.1%} ≥ {CHECK_HIT_RATE:.0%}",
        f"check: steady-state recompiles {r['steady_compiles']} == 0",
        f"check: p99 miss latency {r['p99_miss_s'] * 1e3:.0f}ms ≤ "
        f"{CHECK_P99_MISS_S * 1e3:.0f}ms ({r['misses']} misses, "
        f"p50 {r['p50_miss_s'] * 1e3:.0f}ms)",
        f"check: served == direct render_arrays on "
        f"{r['identity_ok']}/{r['identity_total']} sampled tiles",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="smaller sweep")
    ap.add_argument("--json", default="",
                    help="also write structured records to this path")
    ap.add_argument("--check", action="store_true",
                    help="assert hit-rate/recompile/latency/identity bars")
    args = ap.parse_args()

    records: list = []
    print("name,us_per_call,derived")
    for line in run(quick=args.quick, records=records):
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "bench": "serve_bench",
                "n_nodes": N_NODES,
                "records": records,
            }, f, indent=2)
        print(f"wrote {args.json} ({len(records)} records)")
    if args.check:
        from benchmarks.run import step_summary

        lines = _check(records)
        print("\n".join(lines))
        step_summary("serve_bench", lines)


if __name__ == "__main__":
    main()
