"""Adaptive-stop FA2 quality vs the fixed-iteration baseline, gated.

The convergence claim (ROADMAP "Convergence engineering") as an enforced
acceptance bar instead of a trace plot: for each benchmark graph, a fixed
500-iteration random-init layout (the legacy schedule) is scored against
an adaptive run (``init="bfs"``, ``stop_tolerance``/``min_iterations``)
with the sampled metrics from repro/quality — pivot stress, k-ring
neighborhood preservation, edge-length CV and a crossing proxy — under
one metric seed, so the two arms see identical sampling.

    PYTHONPATH=src python -m benchmarks.quality_bench
    PYTHONPATH=src python -m benchmarks.quality_bench --quick --json q.json --check
    PYTHONPATH=src python -m benchmarks.run --only quality

``--check`` asserts the acceptance bars: the adaptive arm stops within
half the iteration cap while reaching >= 98% of the fixed baseline's
quality on BOTH gated metrics (neighborhood preservation, and 1 − stress
so "98% of quality" stays a greater-is-better ratio); repeated ``layout``
calls at fixed shapes trigger zero recompiles (the adaptive carry and
``lax.cond`` body are shape-stable); and with >= 2 devices the sharded
adaptive layout — positions, trace, and ``iterations_run`` — is
bit-identical to the single-device run (the converged flag is computed
from replicated gathered forces, so every device freezes together; the
CI ``quality-smoke`` job forces 2 host devices to keep this leg live).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import forceatlas2 as fa2
from repro.graph import pad_edges, planted_partition
from repro.graph.utils import degrees
from repro.quality import layout_quality
from repro.serve.tiles import jit_compile_count

ITER_CAP = 500  # the paper's full-graph schedule; the fixed arm runs it all
STOP_TOL = 0.05  # g_swing <= tol * g_traction freezes the scan ...
MIN_ITERS = 200  # ... but never before the floor (the bfs init starts calm)
ITER_BUDGET = ITER_CAP // 2  # --check: adaptive must stop within half the cap
QUALITY_MIN = 0.98  # --check: >= 98% of fixed-arm quality on both metrics
GRAPH_SEED = 5
METRIC_SEED = 0

# name, n, communities, p_in, p_out, repulsion backend, backend kwargs.
GRAPHS_FULL = (
    ("ppart-1k", 1000, 12, 0.2, 5e-4, "exact", {}),
    ("ppart-4k", 4000, 40, 0.15, 2e-4, "grid",
     {"grid_size": 32, "grid_window": 16}),
)
GRAPHS_QUICK = GRAPHS_FULL[:1]


def _cfg(repulsion: str, extra: dict, adaptive: bool) -> fa2.FA2Config:
    knobs = (
        {"stop_tolerance": STOP_TOL, "min_iterations": MIN_ITERS,
         "init": "bfs"}
        if adaptive
        else {}
    )
    return fa2.FA2Config(iterations=ITER_CAP, repulsion=repulsion,
                         use_radii=False, **extra, **knobs)


def _layout(edges, w, mass, n, cfg):
    t0 = time.perf_counter()
    pos, trace, iters = fa2.layout(edges, w, mass, n, cfg)
    jax.block_until_ready(pos)
    return np.asarray(pos), int(iters), time.perf_counter() - t0


def bench_graph(name, n, k, p_in, p_out, repulsion, extra, records):
    edges_np, _ = planted_partition(n, k, p_in, p_out, seed=GRAPH_SEED)
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    mass = degrees(edges, n).astype(jnp.float32) + 1.0
    w = jnp.ones(edges.shape[0], jnp.float32)

    arms = {}
    for arm in ("fixed", "adaptive"):
        cfg = _cfg(repulsion, extra, arm == "adaptive")
        pos, iters, sec = _layout(edges, w, mass, n, cfg)
        rec = {
            "graph": name, "n": n, "arm": arm, "repulsion": repulsion,
            "seconds": sec, "iterations_run": iters,
            "iterations_cap": ITER_CAP,
            **layout_quality(pos, edges_np, n, seed=METRIC_SEED),
        }
        arms[arm] = rec
        if records is not None:
            records.append(rec)
        yield row(
            f"quality/{name}/{arm}", sec,
            f"iters={iters};stress={rec['stress']:.4f};"
            f"np={rec['neighborhood']:.4f};edge_cv={rec['edge_cv']:.3f};"
            f"crossing={rec['crossing']:.4f}",
        )

    # Recompile guard: two more adaptive calls at the same shapes must hit
    # the jit cache (a flat jax.monitoring compile-count delta).
    acfg = _cfg(repulsion, extra, True)
    base = jit_compile_count()
    for _ in range(2):
        _layout(edges, w, mass, n, acfg)
    delta = jit_compile_count() - base
    if records is not None:
        records.append({"graph": name, "arm": "recompile",
                        "repeat_calls": 2, "compile_delta": delta})
    yield row(f"quality/{name}/recompile", 0.0, f"compile_delta={delta}")

    # Sharded adaptive bit-identity (lives only with a real multi-device
    # mesh; the CI job forces 2 host devices so this leg always runs there).
    d = jax.device_count()
    if d > 1 and n % d == 0:
        from repro.launch.mesh import make_stream_mesh

        mesh = make_stream_mesh()
        pos1, trace1, it1 = fa2.layout(edges, w, mass, n, acfg)
        posd, traced, itd = fa2.layout_sharded(edges, w, mass, n, acfg, mesh)
        bit = (
            np.array_equal(np.asarray(pos1), np.asarray(posd))
            and np.array_equal(np.asarray(trace1), np.asarray(traced))
            and int(it1) == int(itd)
        )
        if records is not None:
            records.append({"graph": name, "arm": "sharded", "devices": d,
                            "bit_identical": bool(bit),
                            "iterations_run": int(itd)})
        yield row(f"quality/{name}/sharded", 0.0,
                  f"devices={d};bit_identical={bit}")


def run(quick: bool = False, records: list | None = None):
    """Yield CSV rows (and append structured records) per graph."""
    graphs = GRAPHS_QUICK if quick else GRAPHS_FULL
    for name, n, k, p_in, p_out, repulsion, extra in graphs:
        yield from bench_graph(name, n, k, p_in, p_out, repulsion, extra,
                               records)


def _check(records: list) -> list[str]:
    """Acceptance bars (see module docstring). Returns the result lines
    (printed and fed to ``run.step_summary``)."""
    by_graph: dict[str, dict] = {}
    for r in records:
        if r.get("arm") in ("fixed", "adaptive"):
            by_graph.setdefault(r["graph"], {})[r["arm"]] = r
    assert by_graph, "no layout records"
    lines = []
    for g, arms in by_graph.items():
        f, a = arms["fixed"], arms["adaptive"]
        assert a["iterations_run"] <= ITER_BUDGET, (
            f"{g}: adaptive ran {a['iterations_run']} iterations "
            f"(budget: {ITER_BUDGET} = half the {ITER_CAP} cap)"
        )
        np_ratio = a["neighborhood"] / max(f["neighborhood"], 1e-12)
        stress_q = (1.0 - a["stress"]) / max(1.0 - f["stress"], 1e-12)
        assert np_ratio >= QUALITY_MIN, (
            f"{g}: neighborhood preservation {a['neighborhood']:.4f} is "
            f"{np_ratio:.3f}x the fixed baseline {f['neighborhood']:.4f} "
            f"(bar: {QUALITY_MIN})"
        )
        assert stress_q >= QUALITY_MIN, (
            f"{g}: stress quality (1-stress) {1 - a['stress']:.4f} is "
            f"{stress_q:.3f}x the fixed baseline {1 - f['stress']:.4f} "
            f"(bar: {QUALITY_MIN})"
        )
        lines.append(
            f"check: {g} adaptive stopped at {a['iterations_run']}/"
            f"{ITER_CAP} with np {np_ratio:.2f}x, 1-stress "
            f"{stress_q:.2f}x the fixed baseline (bars: <= {ITER_BUDGET}, "
            f">= {QUALITY_MIN}x)"
        )
    recompiles = [r for r in records if r.get("arm") == "recompile"]
    assert recompiles, "no recompile records"
    for r in recompiles:
        assert r["compile_delta"] == 0, (
            f"{r['graph']}: {r['compile_delta']} recompiles across "
            f"{r['repeat_calls']} repeated fixed-shape layout calls"
        )
    lines.append(
        f"check: zero recompiles across repeated layout calls "
        f"({len(recompiles)} graphs)"
    )
    sharded = [r for r in records if r.get("arm") == "sharded"]
    for r in sharded:
        assert r["bit_identical"], (
            f"{r['graph']}: sharded adaptive layout diverged from the "
            f"single-device run on {r['devices']} devices"
        )
    if sharded:
        lines.append(
            f"check: sharded adaptive layout bit-identical on "
            f"{sharded[0]['devices']} devices ({len(sharded)} graphs)"
        )
    else:
        lines.append("check: sharded identity skipped (single device)")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="first graph only")
    ap.add_argument("--json", default="",
                    help="also write structured records to this path")
    ap.add_argument("--check", action="store_true",
                    help="assert the iteration-budget / quality-ratio / "
                         "recompile / sharded-identity acceptance bars")
    args = ap.parse_args()

    records: list = []
    print("name,us_per_call,derived")
    for line in run(quick=args.quick, records=records):
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "bench": "quality_bench",
                "backend": jax.default_backend(),
                "devices": jax.device_count(),
                "iterations_cap": ITER_CAP,
                "stop_tolerance": STOP_TOL,
                "min_iterations": MIN_ITERS,
                "records": records,
            }, f, indent=2)
        print(f"wrote {args.json} ({len(records)} records)")
    if args.check:
        from benchmarks.run import step_summary

        lines = _check(records)
        print("\n".join(lines))
        step_summary("quality_bench", lines)


if __name__ == "__main__":
    main()
