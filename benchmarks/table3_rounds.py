"""Paper Table 3: effect of community-detection rounds (2/3/4) on running
time, #supernodes, #superedges, and modularity (paper §5.3.5: communities
merge and intra-community mass grows with rounds)."""
from __future__ import annotations

import time
from dataclasses import replace

from benchmarks.common import SUITE, row
from repro.core import biggraphvis, default_config
from repro.graph import mode_degree


def run(quick: bool = False) -> list[str]:
    rows = []
    name, (build, n) = list(SUITE.items())[0]
    edges_np = build()
    dt = mode_degree(edges_np, n)
    base = default_config(n, len(edges_np), dt, rounds=4, iterations=10,
                          s_cap=min(n, 16384))
    round_counts = (1, 4) if quick else (1, 2, 3, 4)
    for r in round_counts:
        cfg = replace(base, scoda=replace(base.scoda, rounds=r))
        t0 = time.perf_counter()
        res = biggraphvis(edges_np, n, cfg)
        dt_s = time.perf_counter() - t0
        rows.append(row(
            f"table3/{name}/rounds{r}", dt_s,
            f"SN={res.n_supernodes};SE={res.n_superedges};M={res.modularity:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
