"""Full-graph ForceAtlas2 throughput: tiled grid repulsion vs dense baseline.

Sweeps n × repulsion backend × grid size and times the FA2 ``layout``
iteration loop per backend (compile excluded), reporting iterations/s and
node-iterations/s, plus the *compiled* temp footprint of each backend's
repulsion stage from XLA's memory analysis. The dense ``grid_dense``
baseline materializes an [n, G², 2] far-field tensor every iteration; the
tiled backends (kernels/grid) stream cache/VMEM-sized chunks, so their
far-field footprint is O(n + G²) — independent of the n·G² product.

    PYTHONPATH=src python -m benchmarks.fa2_bench
    PYTHONPATH=src python -m benchmarks.fa2_bench --quick --json fa2.json --check
    PYTHONPATH=src python -m benchmarks.run --only fa2

CSV rows (name,us_per_call,derived) per the harness contract; ``--json``
additionally writes the structured records (the CI ``fa2-smoke``
artifact), including a ``speedup`` record per (n, G) point. ``--check``
asserts the acceptance bars: the tiled "grid" backend reaches ≥ 1.5× the
dense baseline's iterations/s at every swept n ≥ 50 000, and the tiled
far field compiles with an O(nb·G² + n) temp footprint — a bound every
[n, G²] intermediate exceeds at every swept point.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.core import forceatlas2 as fa2
from repro.graph import pad_edges
from repro.graph.utils import degrees
from repro.kernels.grid import ops as grid_ops

ITERS = 5  # timed layout iterations per call
WINDOW = 32
FAR_CHUNK = 1024  # node-chunk size of the tiled XLA far field (kernels/grid)
NS_FULL = (8192, 50_000)
GS_FULL = (32, 64)
NS_QUICK = (8192, 50_000)
GS_QUICK = (32,)
SPEEDUP_N = 50_000  # --check bar applies from this size up
SPEEDUP_MIN = 1.5


def backends() -> tuple[str, ...]:
    """Dense baseline + tiled XLA everywhere; the Pallas backend only where
    it compiles (interpret mode would benchmark the interpreter)."""
    base = ["grid_dense", "grid"]
    if jax.default_backend() == "tpu":
        base.append("grid_pallas")
    return tuple(base)


def synth_graph(n: int, avg_deg: int = 4, seed: int = 0) -> np.ndarray:
    """Random [E,2] edge list (repulsion dominates FA2; structure is
    irrelevant to its cost, which is shape-driven)."""
    rng = np.random.default_rng(seed)
    e = avg_deg * n // 2
    edges = rng.integers(0, n, (e, 2), dtype=np.int64).astype(np.int32)
    return edges[edges[:, 0] != edges[:, 1]]


def _cfg(backend: str, g: int) -> fa2.FA2Config:
    return fa2.FA2Config(iterations=ITERS, repulsion=backend, grid_size=g,
                         grid_window=WINDOW, use_radii=False)


def repulsion_temp_bytes(n: int, g: int, backend: str) -> dict:
    """Compiled temp bytes of the repulsion stage (and, for the tiled
    backends, of the far field alone) via XLA memory analysis."""
    pos = jax.ShapeDtypeStruct((n, 2), jnp.float32)
    mass = jax.ShapeDtypeStruct((n,), jnp.float32)

    def temp(fn, *args):
        return int(
            jax.jit(fn).lower(*args).compile().memory_analysis()
            .temp_size_in_bytes
        )

    cfg = _cfg(backend, g)
    out = {"repulsion_temp_bytes": temp(
        lambda p, m: fa2._repulsion_forces(p, m, None, cfg), pos, mass)}
    if backend != "grid_dense":
        # Measure the same kernel the timed path runs: "grid" auto-resolves
        # to Pallas on TPU and the XLA ref elsewhere.
        if backend == "grid":
            kb = "pallas" if jax.default_backend() == "tpu" else "ref"
        else:
            kb = "pallas"
        cell = jax.ShapeDtypeStruct((n,), jnp.int32)
        cent = jax.ShapeDtypeStruct((g * g, 2), jnp.float32)
        cmass = jax.ShapeDtypeStruct((g * g,), jnp.float32)
        out["far_temp_bytes"] = temp(
            lambda p, m, c, cc, cm: grid_ops.far_field(
                p, m, c, cc, cm, 80.0, backend=kb),
            pos, mass, cell, cent, cmass)
    return out


def bench_point(n: int, g: int, backend: str, edges_np: np.ndarray):
    edges = jnp.asarray(pad_edges(edges_np, len(edges_np), n))
    mass = degrees(edges, n).astype(jnp.float32) + 1.0
    w = jnp.ones(edges.shape[0], jnp.float32)
    cfg = _cfg(backend, g)

    def run():
        pos, _, _ = fa2.layout(edges, w, mass, n, cfg)
        jax.block_until_ready(pos)

    t = time_call(run, repeat=2)  # per call = ITERS iterations, warm
    rec = {
        "n": n, "g": g, "backend": backend, "n_edges": len(edges_np),
        "iterations": ITERS, "pass_s": t,
        "iters_per_s": ITERS / t,
        "node_iters_per_s": n * ITERS / t,
    }
    rec.update(repulsion_temp_bytes(n, g, backend))
    return rec


def run(quick: bool = False, records: list | None = None):
    """Yield CSV rows (and append structured records) for the sweep."""
    ns = NS_QUICK if quick else NS_FULL
    gs = GS_QUICK if quick else GS_FULL
    for n in ns:
        edges_np = synth_graph(n)
        for g in gs:
            per_backend = {}
            for backend in backends():
                rec = bench_point(n, g, backend, edges_np)
                per_backend[backend] = rec
                if records is not None:
                    records.append(rec)
                derived = (
                    f"iters_per_s={rec['iters_per_s']:.2f};"
                    f"node_iters_per_s={rec['node_iters_per_s']:.0f};"
                    f"repulsion_temp_bytes={rec['repulsion_temp_bytes']}"
                )
                if "far_temp_bytes" in rec:
                    derived += f";far_temp_bytes={rec['far_temp_bytes']}"
                yield row(f"fa2/n{n}/g{g}/{backend}", rec["pass_s"], derived)
            speedup = (per_backend["grid"]["iters_per_s"]
                       / per_backend["grid_dense"]["iters_per_s"])
            yield row(
                f"fa2/n{n}/g{g}/speedup",
                per_backend["grid"]["pass_s"],
                f"tiled_over_dense={speedup:.2f}",
            )
            if records is not None:
                records.append({
                    "n": n, "g": g, "backend": "speedup",
                    "tiled_over_dense": speedup,
                })


def _check(records: list) -> list[str]:
    """Acceptance bars (see module docstring). Returns the result lines
    (printed and fed to ``run.step_summary``)."""
    checked_speed = checked_mem = 0
    for r in records:
        if r["backend"] == "speedup" and r["n"] >= SPEEDUP_N:
            checked_speed += 1
            assert r["tiled_over_dense"] >= SPEEDUP_MIN, (
                f"tiled grid only {r['tiled_over_dense']:.2f}x dense at "
                f"n={r['n']} G={r['g']} (bar: {SPEEDUP_MIN}x)"
            )
        if "far_temp_bytes" in r:
            checked_mem += 1
            # O(nb·G² + n): a handful of [nb, G²] f32 chunk blocks plus a
            # few vectors of n — NOT the [n, G², 2] dense tensor (which is
            # 8·n·G² bytes and exceeds this bound for every swept n).
            bound = 8 * FAR_CHUNK * r["g"] * r["g"] * 4 + 16 * r["n"]
            assert r["far_temp_bytes"] < bound, (
                f"{r['backend']} far field temp {r['far_temp_bytes']} ≥ "
                f"{bound} at n={r['n']} G={r['g']}: an [n, G²] intermediate "
                "is back"
            )
    assert checked_speed, f"no n ≥ {SPEEDUP_N} points in the sweep"
    assert checked_mem, "no tiled far-field records in the sweep"
    return [
        f"check: tiled ≥ {SPEEDUP_MIN}x dense at all {checked_speed} "
        f"n≥{SPEEDUP_N} points",
        f"check: far field O(n + G²) at all {checked_mem} tiled points",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="smaller sweep")
    ap.add_argument("--json", default="",
                    help="also write structured records to this path")
    ap.add_argument("--check", action="store_true",
                    help="assert the tiled-vs-dense speedup and far-field "
                         "memory acceptance bars")
    args = ap.parse_args()

    records: list = []
    print("name,us_per_call,derived")
    for line in run(quick=args.quick, records=records):
        print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "bench": "fa2_bench",
                "backend": jax.default_backend(),
                "iterations": ITERS,
                "window": WINDOW,
                "records": records,
            }, f, indent=2)
        print(f"wrote {args.json} ({len(records)} records)")
    if args.check:
        from benchmarks.run import step_summary

        lines = _check(records)
        print("\n".join(lines))
        step_summary("fa2_bench", lines)


if __name__ == "__main__":
    main()
