"""Resilience gates: kill-and-resume must be sha256-bit-identical with
bounded re-work, injected store faults must quarantine visibly while the
run completes, and NaN-poisoned layouts must recover under the FA2
divergence sentinel (``--check`` enforces all three).

    PYTHONPATH=src python -m benchmarks.resilience_bench [--quick] \
        [--check] [--json resilience.json]
    PYTHONPATH=src python -m benchmarks.run --only resilience

CSV rows (name,us_per_call,derived) per the harness contract.
"""
from __future__ import annotations

import argparse
import hashlib
import tempfile
import time
from dataclasses import replace

import numpy as np

from benchmarks.common import SUITE, make_record, row, write_bench_json
from repro.core import StreamConfig, biggraphvis, default_config
from repro.core import forceatlas2 as fa2
from repro.graph import mode_degree
from repro.obs.metrics import REGISTRY
from repro.resilience import (
    ChaosConfig,
    ChaosEdgeStore,
    KillSwitch,
    SimulatedPreemption,
    StreamCheckpointer,
    ValidationPolicy,
    poison_weights,
)

# Mirror stream/obs_bench's fixed streaming shape: several chunks per pass
# so there are many distinct chunk boundaries to kill at.
BLOCK = 2048
CHUNK = 16384

REDO_GATE = 0.10  # resumed re-work: extra chunks / uninterrupted chunks


def _setup(graph: str, rounds: int):
    builder, n = SUITE[graph]
    edges = builder()
    cfg = default_config(n, len(edges), mode_degree(edges, n),
                         rounds=rounds, iterations=10)
    cfg = replace(cfg, scoda=replace(cfg.scoda, block_size=BLOCK))
    return edges, n, cfg


def _digest(res) -> str:
    h = hashlib.sha256()
    sg = res.supergraph
    for a in (res.labels, sg.edges, sg.weights, sg.sizes, sg.labels,
              res.positions):
        h.update(np.asarray(a).tobytes())
    h.update(np.float64(res.modularity).tobytes())
    return h.hexdigest()


def measure_kill_resume(graph: str = "ppart-8k", rounds: int = 2):
    """Baseline, killed, and resumed runs of the same streamed workload.

    Returns a metrics dict: ``identical`` (resumed digest == baseline),
    ``extra_chunk_frac`` (chunks processed beyond the uninterrupted run's,
    deterministic given the kill boundary and checkpoint cadence), and the
    three wall times."""
    edges, n, cfg = _setup(graph, rounds)
    scfg = StreamConfig(chunk_size=CHUNK)

    t0 = time.perf_counter()
    base = biggraphvis(edges, n, cfg, stream=scfg)
    t_base = time.perf_counter() - t0
    total_chunks = base.stream.chunks
    # kill mid-way through the detect passes (chunk boundaries are the
    # only preemption points, so this is exactly reproducible)
    kill_at = total_chunks // 2

    with tempfile.TemporaryDirectory() as d:
        ck = StreamCheckpointer(d, every_chunks=1,
                                on_boundary=KillSwitch(kill_at))
        t0 = time.perf_counter()
        try:
            biggraphvis(edges, n, cfg, stream=scfg, checkpoint=ck)
            raise AssertionError("kill switch never fired")
        except SimulatedPreemption:
            pass
        t_killed = time.perf_counter() - t0

        t0 = time.perf_counter()
        res = biggraphvis(
            edges, n, cfg, stream=scfg,
            checkpoint=StreamCheckpointer(d, every_chunks=1), resume=True,
        )
        t_resume = time.perf_counter() - t0

    assert res.stream.resumed_at, "resume did not restore a checkpoint"
    # the killed run completed kill_at+1 chunk updates and checkpointed
    # every boundary, so re-work is whatever the resumed run re-streams
    # beyond the remainder
    extra = (kill_at + 1 + res.stream.chunks) - total_chunks
    return {
        "identical": float(_digest(res) == _digest(base)),
        "total_chunks": total_chunks,
        "kill_at": kill_at,
        "extra_chunks": extra,
        "extra_chunk_frac": extra / total_chunks,
        "resumed_at": res.stream.resumed_at,
        "t_base_s": t_base,
        "t_killed_s": t_killed,
        "t_resume_s": t_resume,
    }


def measure_quarantine(graph: str = "ppart-8k", rounds: int = 2):
    """A permanently unreadable chunk must quarantine (visibly) while the
    run completes with valid shapes. ``StreamStats`` reports *distinct*
    quarantined chunks; the ``errors.quarantined_chunks`` obs counter is
    per-occurrence (the bad chunk is hit once per pass) — the delta here
    is ``quarantine_events``."""
    edges, n, cfg = _setup(graph, rounds)
    store = ChaosEdgeStore(edges, ChaosConfig(io_error_offsets=(CHUNK,)))
    scfg = StreamConfig(
        chunk_size=CHUNK,
        validation=ValidationPolicy(max_retries=1, retry_backoff_s=0.001),
    )
    before = REGISTRY.counter("errors.quarantined_chunks").value
    t0 = time.perf_counter()
    res = biggraphvis(store, n, cfg, stream=scfg)
    t = time.perf_counter() - t0
    labels = np.asarray(res.labels)
    return {
        "quarantined_chunks": res.stream.quarantined_chunks,
        "quarantine_events": (
            REGISTRY.counter("errors.quarantined_chunks").value - before
        ),
        "quarantined_ids": list(res.stream.quarantined_chunk_ids),
        "retries": res.stream.retries,
        "passes": res.stream.passes,
        "completed": float(labels.shape == (n,) and bool((labels >= 0).all())
                           and bool(np.isfinite(res.modularity))),
        "t_s": t,
    }


def measure_nan_guard(graph: str = "ppart-8k", rounds: int = 2):
    """NaN-poisoned layout weights: the guarded layout must stay finite
    and report its recoveries; the unguarded one demonstrably diverges."""
    edges, n, cfg = _setup(graph, rounds)
    res = biggraphvis(edges, n, cfg, stream=StreamConfig(chunk_size=CHUNK))
    sg = res.supergraph
    e = np.asarray(sg.edges)
    # poison *live* superedges only — the capacity padding is masked out
    # of the attraction pass and would never propagate the NaNs
    w = np.asarray(sg.weights, np.float32).copy()
    live = max(1, int(res.n_superedges))
    w[:live] = poison_weights(w[:live], k=8, seed=2)
    mass = np.maximum(np.asarray(sg.sizes, np.float32), 0.0)
    m = mass.shape[0]
    p_off, _, _ = fa2.layout(e, w, mass, m, fa2.FA2Config(iterations=20))
    t0 = time.perf_counter()
    p_on, tr, _ = fa2.layout(
        e, w, mass, m, fa2.FA2Config(iterations=20, nan_guard=True))
    t = time.perf_counter() - t0
    return {
        "unguarded_finite": float(np.isfinite(np.asarray(p_off)).all()),
        "guarded_finite": float(np.isfinite(np.asarray(p_on)).all()),
        "recoveries": fa2.recovery_count(tr),
        "t_s": t,
    }


def run(quick: bool = False, records: list | None = None):
    rounds = 2
    kr = measure_kill_resume(rounds=rounds)
    yield row(
        "resilience/kill_resume/ppart-8k", kr["t_resume_s"],
        f"identical={int(kr['identical'])};extra_chunks={kr['extra_chunks']};"
        f"kill_at={kr['kill_at']};total_chunks={kr['total_chunks']}",
    )
    q = measure_quarantine(rounds=rounds)
    yield row(
        "resilience/quarantine/ppart-8k", q["t_s"],
        f"quarantined={q['quarantined_chunks']};"
        f"events={q['quarantine_events']};retries={q['retries']};"
        f"completed={int(q['completed'])}",
    )
    ng = measure_nan_guard(rounds=rounds)
    yield row(
        "resilience/nan_guard/ppart-8k", ng["t_s"],
        f"recoveries={ng['recoveries']};finite={int(ng['guarded_finite'])}",
    )
    if records is not None:
        records.append(make_record(
            "resilience/kill_resume/ppart-8k",
            config={"graph": "ppart-8k", "rounds": rounds,
                    "chunk_size": CHUNK, "every_chunks": 1,
                    "gate": REDO_GATE},
            metrics={"us_per_call": kr["t_resume_s"] * 1e6, **{
                k: v for k, v in kr.items() if k != "resumed_at"}},
        ))
        records.append(make_record(
            "resilience/quarantine/ppart-8k",
            config={"graph": "ppart-8k", "rounds": rounds,
                    "chunk_size": CHUNK},
            metrics={"us_per_call": q["t_s"] * 1e6,
                     "quarantined_chunks": q["quarantined_chunks"],
                     "quarantine_events": q["quarantine_events"],
                     "retries": q["retries"], "passes": q["passes"],
                     "completed": q["completed"]},
        ))
        records.append(make_record(
            "resilience/nan_guard/ppart-8k",
            config={"graph": "ppart-8k", "iterations": 20, "poisoned": 8},
            metrics={"us_per_call": ng["t_s"] * 1e6,
                     "recoveries": ng["recoveries"],
                     "guarded_finite": ng["guarded_finite"],
                     "unguarded_finite": ng["unguarded_finite"]},
        ))


def check(records: list) -> list[str]:
    """The CI gates: resumed run bit-identical with re-work <= REDO_GATE,
    injected faults quarantined visibly on a completing run, NaN-poisoned
    layout recovered finite by the sentinel."""
    by_name = {r["name"]: r["metrics"] for r in records}
    kr = by_name["resilience/kill_resume/ppart-8k"]
    assert kr["identical"] == 1.0, (
        "resumed run is NOT bit-identical to the uninterrupted run"
    )
    assert kr["extra_chunk_frac"] <= REDO_GATE, (
        f"resume re-work {kr['extra_chunk_frac']:.3f} exceeds gate "
        f"{REDO_GATE} ({kr['extra_chunks']} of {kr['total_chunks']} chunks)"
    )
    q = by_name["resilience/quarantine/ppart-8k"]
    assert q["quarantined_chunks"] >= 1, "no chunk was quarantined"
    assert q["quarantine_events"] >= q["passes"], (
        f"expected the poisoned chunk quarantined every pass, got "
        f"{q['quarantine_events']} events over {q['passes']} passes"
    )
    assert q["completed"] == 1.0, "quarantined run did not complete cleanly"
    ng = by_name["resilience/nan_guard/ppart-8k"]
    assert ng["guarded_finite"] == 1.0, "nan_guard layout went non-finite"
    assert ng["recoveries"] > 0, "nan_guard never fired on poisoned input"
    return [
        f"check: kill@{int(kr['kill_at'])} resume bit-identical, "
        f"{int(kr['extra_chunks'])}/{int(kr['total_chunks'])} chunks redone "
        f"(gate {REDO_GATE:.0%})",
        f"check: injected fault quarantined {int(q['quarantine_events'])}x "
        f"across {int(q['passes'])} passes "
        f"({int(q['quarantined_chunks'])} distinct chunk(s)); run completed",
        f"check: nan_guard recovered {int(ng['recoveries'])} poisoned "
        "iterations, layout finite (unguarded diverges: "
        f"finite={int(ng['unguarded_finite'])})",
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="fewer repeats")
    ap.add_argument("--json", default="",
                    help="write unified structured records to this path")
    ap.add_argument("--check", action="store_true",
                    help="gate bit-identity, re-work bound, quarantine "
                         "visibility, and NaN recovery")
    args = ap.parse_args()

    records: list = []
    print("name,us_per_call,derived")
    for line in run(quick=args.quick, records=records):
        print(line)
    if args.json:
        write_bench_json(args.json, "resilience_bench", records,
                         timestamp=time.time())
        print(f"wrote {args.json} ({len(records)} records)")
    if args.check:
        from benchmarks.run import step_summary

        lines = check(records)
        print("\n".join(lines))
        step_summary("resilience_bench", lines)


if __name__ == "__main__":
    main()
