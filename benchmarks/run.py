"""Benchmark harness entry point — one module per paper table/figure plus
the engine benches and the roofline summary. Prints
``name,us_per_call,derived`` CSV and writes each module's rows as unified
structured records (benchmarks/common.py schema) to repo-root
``BENCH_<name>.json``; ``--list`` prints the registry with each bench's
one-line description.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--list] [--only fa2,agg]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def step_summary(bench: str, lines: list[str]) -> None:
    """Append a bench's ``--check`` result lines to the GitHub Actions job
    summary ($GITHUB_STEP_SUMMARY, a markdown file the runner renders under
    the job). No-op outside CI (env var unset) or with nothing to report —
    benches call this unconditionally after their checks pass.
    """
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path or not lines:
        return
    with open(path, "a") as f:
        f.write(f"### {bench}\n\n")
        for line in lines:
            f.write(f"- {line}\n")
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="1 graph / fewer sweeps")
    ap.add_argument("--only", default="", help="comma-separated module subset")
    ap.add_argument("--list", action="store_true",
                    help="print the bench registry (name + one-line "
                         "description) and exit")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing repo-root BENCH_<name>.json records")
    args = ap.parse_args()

    from benchmarks import (agg_bench, fa2_bench, fig_params, kernels_bench,
                            obs_bench, quality_bench, render_bench,
                            resilience_bench, roofline, serve_bench,
                            shard_bench, stream_bench, table1_speedup,
                            table2_hashes, table3_rounds)
    from benchmarks.common import record_from_csv, write_bench_json

    modules = {
        "table1": table1_speedup,
        "table2": table2_hashes,
        "table3": table3_rounds,
        "figs": fig_params,
        "kernels": kernels_bench,
        "stream": stream_bench,
        "agg": agg_bench,
        "render": render_bench,
        "serve": serve_bench,
        "fa2": fa2_bench,
        "quality": quality_bench,
        "shard": shard_bench,
        "obs": obs_bench,
        "resilience": resilience_bench,
        "roofline": roofline,
    }
    if args.list:
        width = max(map(len, modules))
        for name, mod in modules.items():
            desc = (mod.__doc__ or "").strip().splitlines()[0]
            print(f"{name:<{width}}  {desc}")
        return
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules.items():
        try:
            lines = []
            for line in mod.run(quick=args.quick):
                print(line)
                lines.append(line)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
            continue
        if not args.no_json:
            records = [r for r in map(record_from_csv, lines) if r]
            if records:
                path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
                write_bench_json(path, name, records, timestamp=time.time(),
                                 quick=args.quick)
                print(f"wrote {path} ({len(records)} records)",
                      file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
