"""Benchmark harness entry point — one module per paper table/figure plus
the engine benches and the roofline summary. Prints
``name,us_per_call,derived`` CSV; ``--list`` prints the registry with each
bench's one-line description.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--list] [--only fa2,agg]
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback


def step_summary(bench: str, lines: list[str]) -> None:
    """Append a bench's ``--check`` result lines to the GitHub Actions job
    summary ($GITHUB_STEP_SUMMARY, a markdown file the runner renders under
    the job). No-op outside CI (env var unset) or with nothing to report —
    benches call this unconditionally after their checks pass.
    """
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path or not lines:
        return
    with open(path, "a") as f:
        f.write(f"### {bench}\n\n")
        for line in lines:
            f.write(f"- {line}\n")
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="1 graph / fewer sweeps")
    ap.add_argument("--only", default="", help="comma-separated module subset")
    ap.add_argument("--list", action="store_true",
                    help="print the bench registry (name + one-line "
                         "description) and exit")
    args = ap.parse_args()

    from benchmarks import (agg_bench, fa2_bench, fig_params, kernels_bench,
                            quality_bench, render_bench, roofline,
                            serve_bench, shard_bench, stream_bench,
                            table1_speedup, table2_hashes, table3_rounds)

    modules = {
        "table1": table1_speedup,
        "table2": table2_hashes,
        "table3": table3_rounds,
        "figs": fig_params,
        "kernels": kernels_bench,
        "stream": stream_bench,
        "agg": agg_bench,
        "render": render_bench,
        "serve": serve_bench,
        "fa2": fa2_bench,
        "quality": quality_bench,
        "shard": shard_bench,
        "roofline": roofline,
    }
    if args.list:
        width = max(map(len, modules))
        for name, mod in modules.items():
            desc = (mod.__doc__ or "").strip().splitlines()[0]
            print(f"{name:<{width}}  {desc}")
        return
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules.items():
        try:
            for line in mod.run(quick=args.quick):
                print(line)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
