"""Per-chunk superedge aggregation: two-level sorted-merge vs lexsort.

Sweeps chunk size × ``max_super_edges`` over a suite graph and times one
full aggregation pass per ``agg_backend`` (state donated, chunks staged on
device up front, so the numbers isolate the combine step itself). The
merge backend replaces the baseline's O((cap+C)·log(cap+C)) re-sort of
state + chunk with one O(C log C) local dedupe plus an O(cap + C)
sorted-merge (kernels/merge), so its advantage grows with cap/C.

    PYTHONPATH=src python -m benchmarks.agg_bench
    PYTHONPATH=src python -m benchmarks.agg_bench --quick --json agg.json
    PYTHONPATH=src python -m benchmarks.agg_bench --edges edges.npy \\
        --nodes 8000 --json agg.json --check
    PYTHONPATH=src python -m benchmarks.run --only agg

CSV rows (name,us_per_call,derived) per the harness contract; ``--json``
additionally writes the structured records (the CI ``agg-smoke``
artifact), including a ``speedup`` comparison record per (chunk, cap)
point. ``--check`` asserts the acceptance bar: merge beats the lexsort
baseline wherever cap ≥ 8 × chunk. Every merge run's final state is
asserted bit-for-bit equal to the lexsort run's.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SUITE, row, time_call
from repro.core.stream import EdgeChunkStream
from repro.core.supergraph import agg_init, agg_update
from repro.data.edge_store import NpyEdgeStore

BACKENDS = ("lexsort", "merge")
S_CAP = 2048
CHUNKS_FULL = (2048, 8192)
CAPS_FULL = (8192, 32768, 131072)
CHUNKS_QUICK = (4096,)
CAPS_QUICK = (8192, 65536)


def _aggregate_pass(chunks, labels_ext, s_cap, cap, backend):
    state = agg_init(s_cap, cap)
    for ch in chunks:
        state = agg_update(state, ch, labels_ext, s_cap, cap, backend)
    jax.block_until_ready(state)
    return state


def bench_graph(
    name: str,
    edges: np.ndarray,
    n: int,
    chunk_sizes: tuple,
    caps: tuple,
    records: list | None = None,
):
    """Yield CSV rows (and append structured records) for one graph."""
    rng = np.random.default_rng(0)
    # Aggregation cost is shape-driven (static shapes), not data-driven;
    # random community labels keep the bench independent of SCoDA.
    labels_ext = jnp.asarray(
        np.concatenate([rng.integers(0, S_CAP, n), [S_CAP]]).astype(np.int32)
    )
    for chunk_size in chunk_sizes:
        stream = EdgeChunkStream(edges, n, chunk_size)
        chunks = [jnp.asarray(np.array(c)) for c in stream]
        jax.block_until_ready(chunks)
        for cap in caps:
            times = {}
            states = {}
            for backend in BACKENDS:
                states[backend] = _aggregate_pass(
                    chunks, labels_ext, S_CAP, cap, backend
                )
                t = time_call(
                    lambda b=backend: _aggregate_pass(
                        chunks, labels_ext, S_CAP, cap, b
                    )
                )
                times[backend] = t
                us_per_chunk = t / len(chunks) * 1e6
                yield row(
                    f"agg/{name}/{backend}/C{stream.chunk_size}/cap{cap}",
                    t,
                    f"us_per_chunk={us_per_chunk:.1f};chunks={len(chunks)}",
                )
                if records is not None:
                    records.append({
                        "graph": name, "backend": backend,
                        "chunk_size": stream.chunk_size, "cap": cap,
                        "n_edges": len(edges), "n_chunks": len(chunks),
                        "pass_us": t * 1e6, "us_per_chunk": us_per_chunk,
                    })
            for k in range(4):
                a, b = states["lexsort"][k], states["merge"][k]
                assert np.array_equal(np.asarray(a), np.asarray(b)), (
                    name, stream.chunk_size, cap, k)
            speedup = times["lexsort"] / times["merge"]
            yield row(
                f"agg/{name}/speedup/C{stream.chunk_size}/cap{cap}",
                times["merge"],
                f"speedup={speedup:.2f};cap_over_chunk="
                f"{cap / stream.chunk_size:.1f}",
            )
            if records is not None:
                records.append({
                    "graph": name, "backend": "speedup",
                    "chunk_size": stream.chunk_size, "cap": cap,
                    "speedup": speedup,
                    "cap_over_chunk": cap / stream.chunk_size,
                })


def run(quick: bool = False, records: list | None = None):
    name = next(iter(SUITE))
    builder, n = SUITE[name]
    yield from bench_graph(
        name, builder(), n,
        CHUNKS_QUICK if quick else CHUNKS_FULL,
        CAPS_QUICK if quick else CAPS_FULL,
        records=records,
    )


def _check_merge_wins(records: list) -> list[str]:
    """Acceptance bar: merge beats lexsort wherever cap ≥ 8 × chunk.
    Returns the result lines (printed and fed to ``run.step_summary``)."""
    checked = 0
    for r in records:
        if r["backend"] != "speedup" or r["cap_over_chunk"] < 8:
            continue
        checked += 1
        assert r["speedup"] > 1.0, (
            f"merge slower than lexsort at chunk={r['chunk_size']} "
            f"cap={r['cap']}: speedup {r['speedup']:.2f}"
        )
    assert checked, "no cap ≥ 8×chunk points in the sweep"
    return [f"check: merge beats lexsort at all {checked} cap≥8×chunk points"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="smaller sweep")
    ap.add_argument("--json", default="",
                    help="also write structured records to this path")
    ap.add_argument("--edges", default="",
                    help="bench a converted .npy edge file instead of the suite")
    ap.add_argument("--nodes", type=int, default=0,
                    help="node count of --edges (required with it)")
    ap.add_argument("--check", action="store_true",
                    help="assert merge beats lexsort wherever cap ≥ 8×chunk")
    args = ap.parse_args()

    records: list = []
    print("name,us_per_call,derived")
    if args.edges:
        if not args.nodes:
            raise SystemExit("--edges requires --nodes")
        store = NpyEdgeStore(args.edges)
        edges = store.read(0, store.n_edges)
        for line in bench_graph(
            args.edges.rsplit("/", 1)[-1], edges, args.nodes,
            CHUNKS_QUICK if args.quick else CHUNKS_FULL,
            CAPS_QUICK if args.quick else CAPS_FULL,
            records=records,
        ):
            print(line)
    else:
        for line in run(quick=args.quick, records=records):
            print(line)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "bench": "agg_bench",
                "s_cap": S_CAP,
                "backends": list(BACKENDS),
                "records": records,
            }, f, indent=2)
        print(f"wrote {args.json} ({len(records)} records)")
    if args.check:
        from benchmarks.run import step_summary

        lines = _check_merge_wins(records)
        print("\n".join(lines))
        step_summary("agg_bench", lines)


if __name__ == "__main__":
    main()
