"""Quickstart: BigGraphVis end to end on a synthetic community graph.

    PYTHONPATH=src python examples/quickstart.py

Generates a planted-partition graph, runs the full paper pipeline
(streaming SCoDA → count-min-sketch sizing → supergraph → ForceAtlas2),
prints the Table-1-style summary, and writes supergraph.svg +
full_colored.svg next to this script.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    biggraphvis,
    default_config,
    full_layout_colored,
    write_svg,
)
from repro.graph import mode_degree, planted_partition


def main() -> None:
    n = 3000
    edges, _ = planted_partition(n, 30, 0.15, 0.001, seed=42)
    delta = mode_degree(edges, n)
    print(f"graph: {n} nodes, {len(edges)} edges, mode degree δ={delta}")

    cfg = default_config(n, len(edges), delta, rounds=4, iterations=60, s_cap=4096)
    # Superedge aggregation runs the two-level sorted-merge backend by
    # default (StreamConfig.agg_backend="merge"; "lexsort" = old baseline).
    res = biggraphvis(edges, n, cfg)
    print(
        f"BigGraphVis: {res.n_supernodes} supernodes, {res.n_superedges} superedges, "
        f"modularity={res.modularity:.3f}"
    )
    print("timings:", {k: f"{v:.2f}s" for k, v in res.timings.items()})

    out = os.path.dirname(os.path.abspath(__file__))
    live = res.sizes > 0
    write_svg(
        os.path.join(out, "supergraph.svg"),
        res.positions[live],
        np.sqrt(np.maximum(res.sizes[live], 1.0)),
        res.groups[live],
    )
    print("wrote", os.path.join(out, "supergraph.svg"))

    pos, groups = full_layout_colored(edges, n, cfg, iterations=60)
    write_svg(
        os.path.join(out, "full_colored.svg"), pos, np.full(n, 2.0), groups,
        edges=edges[:4000],
    )
    print("wrote", os.path.join(out, "full_colored.svg"))


if __name__ == "__main__":
    main()
