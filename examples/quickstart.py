"""Quickstart: BigGraphVis end to end on a synthetic community graph.

    PYTHONPATH=src python examples/quickstart.py

Generates a planted-partition graph, runs the full paper pipeline
(streaming SCoDA → count-min-sketch sizing → supergraph → ForceAtlas2 →
streamed rasterization), prints the Table-1-style summary, and writes
supergraph.png / supergraph.svg + full_colored.png / full_colored.svg
next to this script.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

# Everything the quickstart needs is on the stable top-level API surface
# (repro/__init__.py); write_svg/write_png are drawing extras.
from repro import (
    RenderConfig,
    biggraphvis,
    default_config,
    full_layout_colored,
    render_arrays,
)
from repro.core import write_svg
from repro.graph import mode_degree, planted_partition
from repro.render import write_png


def main() -> None:
    n = 3000
    edges, _ = planted_partition(n, 30, 0.15, 0.001, seed=42)
    delta = mode_degree(edges, n)
    print(f"graph: {n} nodes, {len(edges)} edges, mode degree δ={delta}")

    out = os.path.dirname(os.path.abspath(__file__))
    cfg = default_config(n, len(edges), delta, rounds=4, iterations=60, s_cap=4096)
    # Superedge aggregation runs the two-level sorted-merge backend by
    # default (StreamConfig.agg_backend="merge"; "lexsort" = old baseline).
    res = biggraphvis(edges, n, cfg)
    # .render() streams the supergraph drawing through the rasterizer
    # (repro/render): superedge splats + supernode disks → PNG.
    res.render(os.path.join(out, "supergraph.png"))
    print(
        f"BigGraphVis: {res.n_supernodes} supernodes, {res.n_superedges} superedges, "
        f"modularity={res.modularity:.3f}"
    )
    print("timings:", {k: f"{v:.2f}s" for k, v in res.timings.items()})
    print("wrote", os.path.join(out, "supergraph.png"))

    live = res.sizes > 0
    drawn = write_svg(
        os.path.join(out, "supergraph.svg"),
        res.positions[live],
        np.sqrt(np.maximum(res.sizes[live], 1.0)),
        res.groups[live],
    )
    print("wrote", drawn)

    pos, groups = full_layout_colored(edges, n, cfg, iterations=60)
    drawn = write_svg(
        os.path.join(out, "full_colored.svg"), pos, np.full(n, 2.0), groups,
        edges=edges[:4000],
    )
    print("wrote", drawn)

    # Full-graph raster render: every edge streamed through the raster
    # chunk path (residency independent of |E|), nodes as 2px dots.
    img, rstats = render_arrays(
        pos, np.full(n, 2.0), groups, edges,
        cfg=RenderConfig(width=768, height=768, supersample=2),
    )
    write_png(os.path.join(out, "full_colored.png"), img)
    print(
        f"wrote {os.path.join(out, 'full_colored.png')} "
        f"({rstats.edges_streamed} edge rows in {rstats.chunks} chunks, "
        f"{rstats.edges_per_s / 1e6:.2f}M edges/s)"
    )


if __name__ == "__main__":
    main()
