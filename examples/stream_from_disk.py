"""Streaming from disk: BigGraphVis over an edge list that never has to fit
in host memory.

    PYTHONPATH=src python examples/stream_from_disk.py

Writes a graph to an on-disk edge store, then drives the full pipeline from
the memory-mapped file: the only |E|-sized host buffer in play is the
double-buffered staging ring (two chunk-sized arrays), and results are
bit-for-bit identical to the in-memory run.

The same stores are produced/inspected from the shell via the converter CLI:

    PYTHONPATH=src python -m repro.data.edge_store info edges.npy
    PYTHONPATH=src python -m repro.data.edge_store convert edges.bin edges.npy
    PYTHONPATH=src python -m repro.data.edge_store convert edges.npy shards/ \\
        --format shards --shard-edges 1000000

and any of those forms (.npy, raw .bin, shard directory) can be passed
straight to ``biggraphvis()`` as the edge source.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import StreamConfig, biggraphvis, default_config
from repro.data.edge_store import write_npy
from repro.graph import mode_degree, planted_partition


def main() -> None:
    n = 3000
    edges, _ = planted_partition(n, 30, 0.15, 0.001, seed=42)
    cfg = default_config(
        n, len(edges), mode_degree(edges, n), rounds=4, iterations=30, s_cap=4096
    )

    with tempfile.TemporaryDirectory() as tmp:
        path = write_npy(os.path.join(tmp, "edges.npy"), edges)
        print(f"graph: {n} nodes, {len(edges)} edges -> {path}")

        # agg_backend="merge" (the default) aggregates superedges with the
        # two-level sorted-merge (kernels/merge) instead of re-lexsorting
        # state + chunk every chunk; "lexsort" restores the baseline.
        stream = StreamConfig(chunk_size=8192, prefetch=1, agg_backend="merge")
        res_disk = biggraphvis(path, n, cfg, stream=stream)
        res_mem = biggraphvis(edges, n, cfg, stream=stream)

    identical = np.array_equal(res_mem.labels, res_disk.labels) and np.array_equal(
        np.asarray(res_mem.supergraph.edges), np.asarray(res_disk.supergraph.edges)
    )
    s = res_disk.stream
    print(f"disk-streamed == in-memory: {identical}")
    print(
        f"supernodes={res_disk.n_supernodes} superedges={res_disk.n_superedges} "
        f"modularity={res_disk.modularity:.3f}"
    )
    print(
        f"passes={s.passes} chunks={s.chunks} "
        f"throughput={s.edges_per_s / 1e6:.2f}M edges/s"
    )
    print(
        f"host bytes while streaming: {s.peak_host_bytes:,} "
        f"(staging ring only; edge list itself is {edges.nbytes:,})"
    )
    print(
        f"overlap: host_fill={s.host_fill_s * 1e3:.1f}ms "
        f"copy_stall={s.copy_stall_s * 1e3:.1f}ms"
    )


if __name__ == "__main__":
    main()
