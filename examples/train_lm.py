"""End-to-end training driver: a ~25M-param GQA transformer (reduced
yi-family config) trained for a few hundred steps on the synthetic Zipf
token stream, with periodic checkpointing and a mid-run simulated
preemption + restore.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 256]

(~100M-param preset: --d-model 512 --layers 12 --vocab 32768 — same code,
longer wall-clock; the default fits a CPU-only CI budget.)
"""
import argparse
import functools
import os
import shutil
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import LMStream
from repro.models import transformer as tfm
from repro.models.param import init_params, param_count
from repro.train.fault_tolerance import CheckpointManager
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import StepWatchdog, TrainConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/train_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = tfm.LMConfig(
        name="train-lm-example", n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv_heads=4, head_dim=args.d_model // 8,
        d_ff=args.d_model * 4, vocab=args.vocab, vocab_padded=args.vocab,
        act_dtype=jnp.float32, q_chunk=0,
    )
    specs = tfm.param_specs(cfg)
    print(f"model: {param_count(specs)/1e6:.1f}M params")

    if not args.resume and os.path.isdir(args.ckpt):
        shutil.rmtree(args.ckpt)

    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3))
    loss_fn = functools.partial(tfm.lm_loss, cfg, tfm.Constraints())
    step_fn = jax.jit(make_train_step(loss_fn, tcfg), donate_argnums=(0, 1))

    params = init_params(jax.random.PRNGKey(0), specs)
    state = init_opt_state(params, tcfg.adamw)
    stream = LMStream(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq)

    mgr = CheckpointManager(args.ckpt, every_steps=100)
    mgr.install_preemption_handler()
    start, restored, meta = mgr.restore_latest((params, state))
    if start is not None:
        params, state = restored
        print(f"resumed from step {start}")
        start += 1
    else:
        start = 0

    wd = StepWatchdog()
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        wd.start()
        params, state, m = step_fn(params, state, stream.batch_at(step))
        if wd.stop():
            print(f"step {step}: straggler detected — checkpointing")
            mgr.save(step, (params, state))
        if mgr.should_save(step):
            mgr.save(step, (params, state), extra={"loss": float(m["loss"])})
        if step % 25 == 0:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"({(time.perf_counter()-t0)/(step-start+1):.2f}s/step)")
        if step == args.steps // 2 and not args.resume:
            # simulate a preemption: checkpoint, drop state, restore
            mgr.save(step, (params, state), extra={"reason": "simulated preemption"})
            s, (params, state), _ = mgr.restore_latest((params, state))
            print(f"step {step}: simulated preemption → restored step {s}")
    final = float(m["loss"])
    print(f"done: final loss {final:.4f} in {time.perf_counter()-t0:.0f}s")
    assert np.isfinite(final)


if __name__ == "__main__":
    main()
