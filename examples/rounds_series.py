"""Paper Figs. 8/9 analogue: the effect of hierarchical detection rounds
on the supergraph layout — writes rounds_<r>.svg for r in {1,2,3,4} so
the merging of communities is visible exactly as in the paper's series.

    PYTHONPATH=src python examples/rounds_series.py
"""
import os
import sys
from dataclasses import replace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import biggraphvis, default_config
from repro.core import write_svg
from repro.graph import mode_degree, planted_partition


def main() -> None:
    n = 2500
    edges, _ = planted_partition(n, 25, 0.2, 0.001, seed=7)
    delta = mode_degree(edges, n)
    out = os.path.dirname(os.path.abspath(__file__))
    base = default_config(n, len(edges), delta, rounds=4, iterations=50, s_cap=4096)
    for r in (1, 2, 3, 4):
        cfg = replace(base, scoda=replace(base.scoda, rounds=r))
        res = biggraphvis(edges, n, cfg)
        live = res.sizes > 0
        path = write_svg(os.path.join(out, f"rounds_{r}.svg"),
                         res.positions[live],
                         np.sqrt(np.maximum(res.sizes[live], 1.0)),
                         res.groups[live])
        print(f"rounds={r}: SN={res.n_supernodes} SE={res.n_superedges} "
              f"M={res.modularity:.3f} -> {path}")


if __name__ == "__main__":
    main()
