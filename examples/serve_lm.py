"""Batched serving example: continuous-batching greedy decode over a
shared KV cache (repro.serve.engine.LMEngine) with a small random-weight
model — requests of different lengths join and leave the slot pool
between ticks.

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.param import init_params
from repro.serve.engine import LMEngine, Request


def main() -> None:
    cfg = tfm.LMConfig(
        name="serve-example", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=101, vocab_padded=112,
        act_dtype=jnp.float32, q_chunk=0,
    )
    params = init_params(jax.random.PRNGKey(1), tfm.param_specs(cfg))
    engine = LMEngine(cfg, params, n_slots=4, max_len=64)

    rng = np.random.default_rng(0)
    backlog = [
        Request(prompt=rng.integers(1, cfg.vocab, size=int(p)), max_new=int(n))
        for p, n in [(5, 8), (3, 12), (9, 6), (2, 10), (4, 7), (6, 9)]
    ]
    done = []
    tick = 0
    while backlog or engine.n_live:
        while backlog and engine.submit(backlog[0]):
            backlog.pop(0)
        done += engine.tick()
        tick += 1
        print(f"tick {tick:3d}: live={engine.n_live} queued={len(backlog)} done={len(done)}")
    for i, req in enumerate(done):
        assert len(req.out) == req.max_new
        print(f"req{i}: prompt[{len(req.prompt)}] -> {req.out}")
    print(f"served {len(done)} requests in {tick} ticks (continuous batching)")


if __name__ == "__main__":
    main()
