"""GNN example: full-batch GAT node classification on a planted-partition
graph (cora-regime), plus a sampled-minibatch GIN run through the real
CSR fanout sampler — the two GNN training modes of the assignment.

    PYTHONPATH=src python examples/gnn_fullbatch.py
"""
import functools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gnn_archs import smoke_gnn
from repro.graph import NeighborSampler, planted_partition
from repro.graph.utils import to_csr
from repro.models import gnn as gnn_lib
from repro.models.param import init_params
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import TrainConfig, make_train_step
from dataclasses import replace


def full_batch_gat() -> None:
    n, k = 600, 6
    edges, labels = planted_partition(n, k, 0.3, 0.01, seed=1)
    cfg = replace(smoke_gnn("gat"), d_feat=32, n_out=k, n_layers=2, d_hidden=32)
    params = init_params(jax.random.PRNGKey(0), gnn_lib.param_specs(cfg))
    rng = np.random.default_rng(0)
    # features: noisy one-hot-ish community signal
    feats = rng.standard_normal((n, 32)).astype(np.float32)
    feats[np.arange(n), labels % 32] += 2.0
    train_mask = (rng.random(n) < 0.5).astype(np.float32)
    batch = {
        "feats": jnp.asarray(feats),
        "edges": jnp.asarray(edges),
        "labels": jnp.asarray(labels),
        "mask": jnp.asarray(train_mask),
    }
    tcfg = TrainConfig(adamw=AdamWConfig(lr=3e-3))
    step = jax.jit(make_train_step(functools.partial(gnn_lib.gnn_loss, cfg), tcfg))
    state = init_opt_state(params, tcfg.adamw)
    for i in range(60):
        params, state, m = step(params, state, batch)
        if i % 20 == 0:
            print(f"[gat full-batch] step {i} loss {float(m['loss']):.3f}")
    out = gnn_lib.forward(cfg, params, batch)
    pred = np.asarray(jnp.argmax(out, -1))
    test = train_mask == 0
    acc = (pred[test] == labels[test]).mean()
    print(f"[gat full-batch] held-out accuracy {acc:.2%}")
    assert acc > 0.5


def sampled_gin() -> None:
    n = 2000
    edges, labels = planted_partition(n, 10, 0.2, 0.005, seed=2)
    indptr, indices = to_csr(edges, n)
    sampler = NeighborSampler(indptr, indices, fanouts=(10, 5))
    cfg = replace(smoke_gnn("gin"), d_feat=16, n_out=10)
    params = init_params(jax.random.PRNGKey(1), gnn_lib.param_specs(cfg))
    rng = np.random.default_rng(3)
    feats_all = rng.standard_normal((n, 16)).astype(np.float32)
    feats_all[np.arange(n), labels % 16] += 2.0

    tcfg = TrainConfig(adamw=AdamWConfig(lr=3e-3))
    step = jax.jit(make_train_step(functools.partial(gnn_lib.gnn_loss, cfg), tcfg))
    state = init_opt_state(params, tcfg.adamw)
    for i in range(30):
        seeds = rng.integers(0, n, size=64)
        sub = sampler.sample(seeds, rng)
        cap = sub.nodes.shape[0]
        feats = np.zeros((cap, 16), np.float32)
        valid = sub.nodes >= 0
        feats[valid] = feats_all[sub.nodes[valid]]
        lab = np.zeros(cap, np.int32)
        lab[valid] = labels[sub.nodes[valid]]
        batch = {
            "feats": jnp.asarray(feats),
            "edges": jnp.asarray(sub.edges),
            "labels": jnp.asarray(lab),
            "mask": jnp.asarray(sub.seed_mask.astype(np.float32)),
        }
        params, state, m = step(params, state, batch)
        if i % 10 == 0:
            print(f"[gin sampled] step {i} loss {float(m['loss']):.3f} "
                  f"(subgraph {sub.n_nodes} nodes / {sub.n_edges} edges)")
    print("[gin sampled] done")


if __name__ == "__main__":
    full_batch_gat()
    sampled_gin()
