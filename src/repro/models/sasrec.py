"""SASRec (Kang & McAuley 2018): self-attentive sequential recommendation.

Huge sparse item-embedding table → causal 1-head self-attention over the
user's last-S interactions → next-item scoring against the (shared) table.
JAX has no nn.EmbeddingBag: the lookup is ``jnp.take`` and bulk scoring is
a [B, D]·[D, V] matmul against the vocab-sharded table (assignment §RecSys).

Steps lowered per shape cell:
  train_batch     → train_step (BCE, 1 positive + 1 sampled negative/pos)
  serve_p99/bulk  → serve_step (score all V items for the last position)
  retrieval_cand  → retrieval_step (1 user × 1M candidate dot scores)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.param import ParamSpec


@dataclass(frozen=True)
class SASRecConfig:
    name: str
    n_items: int
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    act_dtype: Any = jnp.float32


def param_specs(cfg: SASRecConfig) -> dict:
    nl, d = cfg.n_blocks, cfg.embed_dim
    dt = jnp.float32
    return {
        # item 0 is the padding item (classic SASRec convention)
        "item_embed": ParamSpec((cfg.n_items, d), ("vocab", "embed"), "normal", dt),
        "pos_embed": ParamSpec((cfg.seq_len, d), (None, "embed"), "normal", dt),
        "final_norm": ParamSpec((d,), ("embed",), "zeros", dt),
        "layers": {
            "attn_norm": ParamSpec((nl, d), ("layer", "embed"), "zeros", dt),
            "wq": ParamSpec((nl, d, cfg.n_heads, d // cfg.n_heads), ("layer", "embed", "heads", "head_dim"), "scaled", dt),
            "wk": ParamSpec((nl, d, cfg.n_heads, d // cfg.n_heads), ("layer", "embed", "heads", "head_dim"), "scaled", dt),
            "wv": ParamSpec((nl, d, cfg.n_heads, d // cfg.n_heads), ("layer", "embed", "heads", "head_dim"), "scaled", dt),
            "wo": ParamSpec((nl, cfg.n_heads, d // cfg.n_heads, d), ("layer", "heads", "head_dim", "embed"), "scaled", dt),
            "ffn_norm": ParamSpec((nl, d), ("layer", "embed"), "zeros", dt),
            "w1": ParamSpec((nl, d, d), ("layer", "embed", "mlp"), "scaled", dt),
            "b1": ParamSpec((nl, d), ("layer", "mlp"), "zeros", dt),
            "w2": ParamSpec((nl, d, d), ("layer", "mlp", "embed"), "scaled", dt),
            "b2": ParamSpec((nl, d), ("layer", "embed"), "zeros", dt),
        },
    }


def encode(cfg: SASRecConfig, params, seq, constraint=None):
    """seq [B, S] item ids (0 = pad) → user states [B, S, D]."""
    b, s = seq.shape
    cstr = (lambda x: jax.lax.with_sharding_constraint(x, constraint)) if constraint is not None else (lambda x: x)
    x = params["item_embed"].astype(cfg.act_dtype)[seq] * (cfg.embed_dim ** 0.5)
    x = x + params["pos_embed"].astype(cfg.act_dtype)[None, :s]
    x = jnp.where((seq > 0)[..., None], x, 0.0)
    x = cstr(x)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, lp):
        hN = L.rms_norm(x, lp["attn_norm"])
        q = jnp.einsum("bsd,dhk->bshk", hN, lp["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", hN, lp["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", hN, lp["wv"].astype(x.dtype))
        att = L.gqa_attention(q, k, v, positions, positions, causal=True)
        x = x + jnp.einsum("bshk,hkd->bsd", att, lp["wo"].astype(x.dtype))
        hN = L.rms_norm(x, lp["ffn_norm"])
        y = jax.nn.relu(jnp.einsum("bsd,df->bsf", hN, lp["w1"].astype(x.dtype)) + lp["b1"].astype(x.dtype))
        y = jnp.einsum("bsf,fd->bsd", y, lp["w2"].astype(x.dtype)) + lp["b2"].astype(x.dtype)
        return cstr(x + y), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rms_norm(x, params["final_norm"])


def sasrec_loss(cfg: SASRecConfig, params, batch, constraint=None):
    """Paper objective: BCE on (h_t · e_pos) vs (h_t · e_neg) per position."""
    seq, pos, neg = batch["seq"], batch["pos"], batch["neg"]  # [B, S] each
    h = encode(cfg, params, seq, constraint)
    te = params["item_embed"].astype(h.dtype)
    pe, ne = te[pos], te[neg]
    sp = jnp.sum(h * pe, -1).astype(jnp.float32)
    sn = jnp.sum(h * ne, -1).astype(jnp.float32)
    mask = (pos > 0).astype(jnp.float32)
    loss = -(jax.nn.log_sigmoid(sp) + jax.nn.log_sigmoid(-sn)) * mask
    return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)


def make_serve_step(cfg: SASRecConfig, constraint=None, logits_constraint=None):
    """seq [B, S] → scores [B, n_items] for the next interaction."""

    def serve_step(params, batch):
        h = encode(cfg, params, batch["seq"], constraint)[:, -1]  # [B, D]
        scores = jnp.einsum("bd,vd->bv", h, params["item_embed"].astype(h.dtype))
        if logits_constraint is not None:
            scores = jax.lax.with_sharding_constraint(scores, logits_constraint)
        return scores

    return serve_step


def make_retrieval_step(cfg: SASRecConfig, constraint=None):
    """One user sequence × [C] candidate ids → [C] scores (batched dot,
    not a loop — assignment §RecSys)."""

    def retrieval_step(params, batch):
        h = encode(cfg, params, batch["seq"], constraint)[:, -1]  # [1, D]
        cand = params["item_embed"].astype(h.dtype)[batch["candidates"]]  # [C, D]
        return jnp.einsum("bd,cd->bc", h, cand)[0]

    return retrieval_step
