"""GNN family: GIN, GAT, MeshGraphNet, GraphCast (encode-process-decode).

All four share one substrate: message passing = gather(src) → edge compute
→ ``segment_sum`` scatter(dst) — the same primitive as the paper's
supergraph aggregation (kernels/segment). JAX has no sparse-adjacency
SpMM beyond BCOO, so segment ops over an edge index ARE the system
(assignment note §GNN).

Uniform structure so every arch scans over stacked layer params:
    input_proj → L × (arch-specific block, residual) → readout
with per-shape d_feat / n_out injected by the config system. Edges are
padded with the trash id (= n_nodes), which segment_sum drops natively.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.param import ParamSpec


@dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str  # gin | gat | meshgraphnet | graphcast
    n_layers: int
    d_hidden: int
    d_feat: int
    n_out: int
    n_heads: int = 1  # gat
    task: str = "node_class"  # node_class | graph_class | node_reg
    act_dtype: Any = jnp.float32
    remat: bool = False


def param_specs(cfg: GNNConfig) -> dict:
    nl, d = cfg.n_layers, cfg.d_hidden
    dt = jnp.float32
    specs = {
        "in_w": ParamSpec((cfg.d_feat, d), ("gnn_feat", "gnn_hidden"), "scaled", dt),
        "in_b": ParamSpec((d,), ("gnn_hidden",), "zeros", dt),
        "out_w": ParamSpec((d, cfg.n_out), ("gnn_hidden", "gnn_out"), "scaled", dt),
        "out_b": ParamSpec((cfg.n_out,), ("gnn_out",), "zeros", dt),
    }
    if cfg.arch == "gin":
        specs["layers"] = {
            "eps": ParamSpec((nl,), ("layer",), "zeros", dt),
            "w1": ParamSpec((nl, d, d), ("layer", "gnn_hidden", "gnn_mlp"), "scaled", dt),
            "b1": ParamSpec((nl, d), ("layer", "gnn_mlp"), "zeros", dt),
            "w2": ParamSpec((nl, d, d), ("layer", "gnn_mlp", "gnn_hidden"), "scaled", dt),
            "b2": ParamSpec((nl, d), ("layer", "gnn_hidden"), "zeros", dt),
        }
    elif cfg.arch == "gat":
        h = cfg.n_heads
        dh = d // h
        specs["layers"] = {
            "w": ParamSpec((nl, d, h, dh), ("layer", "gnn_hidden", "heads", "gnn_mlp"), "scaled", dt),
            "a_src": ParamSpec((nl, h, dh), ("layer", "heads", "gnn_mlp"), "scaled", dt),
            "a_dst": ParamSpec((nl, h, dh), ("layer", "heads", "gnn_mlp"), "scaled", dt),
        }
    else:  # meshgraphnet / graphcast: MPNN with edge + node MLPs
        specs["edge_in_w"] = ParamSpec((2 * d, d), ("gnn_concat", "gnn_hidden"), "scaled", dt)
        specs["edge_in_b"] = ParamSpec((d,), ("gnn_hidden",), "zeros", dt)
        specs["layers"] = {
            "we1": ParamSpec((nl, 3 * d, d), ("layer", "gnn_concat", "gnn_mlp"), "scaled", dt),
            "be1": ParamSpec((nl, d), ("layer", "gnn_mlp"), "zeros", dt),
            "we2": ParamSpec((nl, d, d), ("layer", "gnn_mlp", "gnn_hidden"), "scaled", dt),
            "be2": ParamSpec((nl, d), ("layer", "gnn_hidden"), "zeros", dt),
            "wv1": ParamSpec((nl, 2 * d, d), ("layer", "gnn_concat", "gnn_mlp"), "scaled", dt),
            "bv1": ParamSpec((nl, d), ("layer", "gnn_mlp"), "zeros", dt),
            "wv2": ParamSpec((nl, d, d), ("layer", "gnn_mlp", "gnn_hidden"), "scaled", dt),
            "bv2": ParamSpec((nl, d), ("layer", "gnn_hidden"), "zeros", dt),
        }
    return specs


def _gather(h_ext, idx):
    return h_ext[idx]


def _segsum(data, seg, n):
    return jax.ops.segment_sum(data, seg, num_segments=n)


def _gin_layer(h, lp, src, dst, n):
    agg = _segsum(h[src], dst, n) + _segsum(h[dst], src, n)  # symmetrized
    z = (1.0 + lp["eps"]) * h + agg
    z = jax.nn.relu(z @ lp["w1"] + lp["b1"])
    return z @ lp["w2"] + lp["b2"]


def _gat_layer(h, lp, src, dst, n):
    d = h.shape[-1]
    nh, dh = lp["a_src"].shape
    q = (h @ lp["w"].reshape(d, nh * dh)).reshape(n, nh, dh)
    es = jnp.einsum("nhd,hd->nh", q, lp["a_src"])
    ed = jnp.einsum("nhd,hd->nh", q, lp["a_dst"])
    # Symmetrize: both directions of every undirected edge.
    s2 = jnp.concatenate([src, dst])
    d2 = jnp.concatenate([dst, src])
    logit = jax.nn.leaky_relu(es[s2] + ed[d2], 0.2)  # [2E, H]
    # Numerically stable edge softmax over incoming edges per dst.
    mx = jnp.full((n, nh), -1e30).at[d2].max(logit)
    ex = jnp.exp(logit - mx[d2])
    denom = _segsum(ex, d2, n) + 1e-9
    alpha = ex / denom[d2]
    msg = alpha[:, :, None] * q[s2]
    out = _segsum(msg.reshape(-1, nh * dh), d2, n)
    return jax.nn.elu(out)


def _mpnn_layer(h, e_feat, lp, src, dst, n):
    z = jnp.concatenate([e_feat, h[src], h[dst]], axis=-1)
    e_new = jax.nn.relu(z @ lp["we1"] + lp["be1"]) @ lp["we2"] + lp["be2"]
    e_feat = e_feat + e_new
    agg = _segsum(e_feat, dst, n) + _segsum(e_feat, src, n)
    z = jnp.concatenate([h, agg], axis=-1)
    h_new = jax.nn.relu(z @ lp["wv1"] + lp["bv1"]) @ lp["wv2"] + lp["bv2"]
    return h + h_new, e_feat


def forward(cfg: GNNConfig, params, batch, constraint=None):
    """batch: feats [N, d_feat], edges [E, 2] (trash id = N), plus
    graph_ids [N] for graph_class. Returns [N, n_out] (or [B, n_out])."""
    feats, edges = batch["feats"], batch["edges"]
    n = feats.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    cstr = (lambda x: jax.lax.with_sharding_constraint(x, constraint)) if constraint is not None else (lambda x: x)

    h = jnp.tanh(feats.astype(cfg.act_dtype) @ params["in_w"] + params["in_b"])
    h = cstr(h)

    if cfg.arch in ("meshgraphnet", "graphcast"):
        h_ext = jnp.concatenate([h, jnp.zeros((1, h.shape[1]), h.dtype)])
        src_c = jnp.minimum(src, n)
        dst_c = jnp.minimum(dst, n)
        e_feat = jnp.concatenate([h_ext[src_c], h_ext[dst_c]], axis=-1)
        e_feat = jax.nn.relu(e_feat @ params["edge_in_w"] + params["edge_in_b"])

        def body(carry, lp):
            h, e = carry
            h2, e2 = _mpnn_layer(h, e, lp, src, dst, n)
            return (cstr(h2), e2), None

        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        (h, _), _ = jax.lax.scan(body, (h, e_feat), params["layers"])
    else:
        layer = _gin_layer if cfg.arch == "gin" else _gat_layer

        def body(h, lp):
            return cstr(h + layer(h, lp, src, dst, n)), None

        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(body, h, params["layers"])

    if cfg.task == "graph_class":
        gid = batch["graph_ids"]
        n_graphs = batch["labels"].shape[0]
        pooled = _segsum(h, gid, n_graphs)
        return pooled @ params["out_w"] + params["out_b"]
    return h @ params["out_w"] + params["out_b"]


def gnn_loss(cfg: GNNConfig, params, batch, constraint=None):
    out = forward(cfg, params, batch, constraint).astype(jnp.float32)
    labels, mask = batch["labels"], batch["mask"]
    if cfg.task == "node_reg":
        err = jnp.square(out - labels) * mask[:, None]
        return jnp.sum(err) / jnp.maximum(jnp.sum(mask) * cfg.n_out, 1.0)
    logz = jax.nn.logsumexp(out, axis=-1)
    gold = jnp.take_along_axis(out, labels[:, None], axis=-1)[:, 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
