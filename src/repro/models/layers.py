"""Shared neural layers: RMSNorm, RoPE, GQA attention (full / sliding-window
/ chunked-prefill / decode), dense GLU MLP, and capacity-based MoE with
sort-dispatch (no [T,E,C] one-hot blowup).

Everything is a pure function over (params dict, inputs); activations use
``act_dtype`` (bf16 by default at scale) with f32 softmax/norm statistics.
"""
from __future__ import annotations

import inspect as _inspect

import jax
import jax.numpy as jnp

# Newer jax exposes shard_map at the top level; older versions keep it in
# jax.experimental. The replication-check kwarg was also renamed
# (check_rep → check_vma) on a different schedule, so pick it by signature.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

_SHMAP_NOCHECK = {
    ("check_vma" if "check_vma" in _inspect.signature(_shard_map).parameters
     else "check_rep"): False
}

# --------------------------------------------------------------- norms / pos

def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 10000.0):
    """x [..., S, H, hd]; positions [..., S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention

def _attend_grouped(q, k, v, mask, scale):
    """q [B,Sq,KV,G,hd], k [B,Skv,KV,hd], v same → out [B,Sq,KV,G,hd].

    mask [B or 1, Sq, Skv] bool (True = attend). Softmax stats in f32.
    Used by the DECODE path, where the KV cache must stay at KV heads.
    """
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(mask[:, None, None, :, :], logits, neg)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def _attend_flat(q, k, v, mask, scale):
    """Flat-head attention: q/k/v all [B,S,H,hd].

    Train/prefill path. The grouped [KV,G] factorization is sharding-
    hostile: 96 heads shard 16-way but neither KV=8 nor G=12 divides 16,
    so GSPMD falls back to a 4×4 split and "involuntary full
    rematerialization" — 16.9 TiB of backward all-gathers per device on
    mistral train_4k (EXPERIMENTS §Perf iteration 9). Flat heads shard
    cleanly; K/V are pre-expanded to H heads by the caller.
    """
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(mask[:, None, :, :], logits, neg)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v)


def expand_kv(k, g: int):
    """[B,S,KV,hd] → [B,S,KV·G,hd], head h ↔ group h // G (matches the
    kv-major flat head order of the fused qkv projection)."""
    return jnp.repeat(k, g, axis=2)


def gqa_attention(
    q,  # [B, Sq, H, hd]
    k,  # [B, Skv, KV, hd] (grouped) or [B, Skv, H, hd] (pre-expanded)
    v,
    q_positions,  # [B, Sq] int32 absolute positions
    kv_positions,  # [B, Skv]
    *,
    causal: bool = True,
    window: int | None = None,  # sliding-window size (None = full)
    kv_valid_len=None,  # [B] decode: number of live cache slots
    q_chunk: int = 0,  # >0: scan over q chunks (bounds score memory)
):
    """Grouped-query attention with optional banded (sliding) masking and
    chunked-prefill scanning. Returns [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    scale = hd ** -0.5
    flat = kv == h

    def mask_for(qpos):
        m = jnp.ones((b, qpos.shape[1], k.shape[1]), bool)
        if causal:
            m &= qpos[:, :, None] >= kv_positions[:, None, :]
        if window is not None:
            m &= qpos[:, :, None] - kv_positions[:, None, :] < window
        if kv_valid_len is not None:
            live = jnp.arange(k.shape[1])[None, :] < kv_valid_len[:, None]
            m &= live[:, None, :]
        return m

    if flat:
        if q_chunk and sq > q_chunk and sq % q_chunk == 0:
            nc = sq // q_chunk
            qs = q.reshape(b, nc, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)
            ps = q_positions.reshape(b, nc, q_chunk).transpose(1, 0, 2)

            def body(_, qc_pc):
                qc, pc = qc_pc
                return None, _attend_flat(qc, k, v, mask_for(pc), scale)

            _, outs = jax.lax.scan(body, None, (qs, ps))
            return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)
        return _attend_flat(q, k, v, mask_for(q_positions), scale)

    # grouped (decode): cache stays at KV heads, G queries share each head
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    out = _attend_grouped(qg, k, v, mask_for(q_positions), scale)
    return out.reshape(b, sq, h, hd)


# ----------------------------------------------------------------------- MLP

def glu_mlp(x, wi, wg, wo):
    """SwiGLU: (silu(x@wg) * (x@wi)) @ wo."""
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, wg.astype(x.dtype)))
    h = h * jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", h, wo.astype(x.dtype))


# ----------------------------------------------------------------------- MoE

def _cumcount(ids, n_buckets):
    """Rank of each element among equal values (stable, vectorized)."""
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    idx = jnp.arange(ids.shape[0], dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.array([True]), sorted_ids[1:] != sorted_ids[:-1]])
    group_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    rank_sorted = idx - group_start
    return jnp.zeros_like(ids).at[order].set(rank_sorted)


def moe_mlp(x, router_w, w_gate, w_in, w_out, *, top_k: int, capacity: int,
            shared=None, buf_constraint=None):
    """Capacity-based top-k MoE with sort-dispatch.

    x [B, S, D]; router_w [D, E]; w_* [E, D, F] / [E, F, D].
    Dispatch: flatten (token, choice) pairs, rank tokens per expert by a
    vectorized cumulative count, scatter into an [E·C, D] buffer, run the
    batched per-expert einsum, and combine with gate weights. Tokens past
    capacity are dropped (standard GShard semantics). No [T, E, C] one-hot.
    """
    b, s, d = x.shape
    e = router_w.shape[-1]
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf, router_w.astype(x.dtype)).astype(jnp.float32)
    gates, choices = jax.lax.top_k(logits, top_k)  # [t, k]
    gates = jax.nn.softmax(gates, axis=-1)

    tok_idx = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)  # [t·k]
    exp_idx = choices.reshape(-1).astype(jnp.int32)
    gate = gates.reshape(-1)

    rank = _cumcount(exp_idx, e)
    keep = rank < capacity
    slot = jnp.where(keep, exp_idx * capacity + rank, e * capacity)  # trash slot

    buf = jnp.zeros((e * capacity + 1, d), x.dtype).at[slot].set(xf[tok_idx])
    xs = buf[:-1].reshape(e, capacity, d)
    if buf_constraint is not None:  # expert dim → model axis (EP)
        xs = jax.lax.with_sharding_constraint(xs, buf_constraint)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, w_gate.astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xs, w_in.astype(x.dtype))
    ys = jnp.einsum("ecf,efd->ecd", h, w_out.astype(x.dtype))
    if buf_constraint is not None:
        ys = jax.lax.with_sharding_constraint(ys, buf_constraint)

    ys_flat = ys.reshape(e * capacity, d)
    contrib = jnp.where(keep[:, None], ys_flat[jnp.minimum(slot, e * capacity - 1)], 0.0)
    out = jnp.zeros((t, d), x.dtype).at[tok_idx].add(contrib * gate[:, None].astype(x.dtype))

    # Router z-loss + load-balance aux (returned for the training loss).
    probs = jax.nn.softmax(logits, axis=-1)
    load = jnp.mean(probs, axis=0)
    importance = jnp.zeros(e, jnp.float32).at[exp_idx].add(1.0) / (t * top_k)
    aux = e * jnp.sum(load * importance)
    if shared is not None:  # shared-expert branch (DeepSeek/Kimi style)
        sw_gate, sw_in, sw_out = shared
        hs = jax.nn.silu(jnp.einsum("td,df->tf", xf, sw_gate.astype(x.dtype)))
        hs = hs * jnp.einsum("td,df->tf", xf, sw_in.astype(x.dtype))
        out = out + jnp.einsum("tf,fd->td", hs, sw_out.astype(x.dtype))
    return out.reshape(b, s, d), aux


def moe_mlp_shmap(x, router_w, w_gate, w_in, w_out, *, top_k: int,
                  capacity_local: int, mesh, expert_axis: str,
                  token_axes) -> tuple:
    """Expert-parallel MoE under shard_map (DESIGN.md §4).

    Plain-GSPMD dispatch scatters over *global* tokens, which XLA
    replicates (measured: ~95 GiB/device on granite train_4k — see
    EXPERIMENTS.md §Perf). Here tokens never leave their data shard:
    every model shard owns an expert block [E_loc], dispatches its local
    tokens into a local [E_loc, C_loc, D] buffer, runs the batched expert
    einsum, combines with gate weights, and one psum over the expert axis
    sums the per-block partial outputs (tokens' other experts live on
    other shards). Collectives: a single all-reduce of [T_loc, D] per
    layer — no all-to-all, no replicated scatter.

    x must be sharded P(token_axes, None, None); router_w replicated;
    w_* sharded P(expert_axis, None, None).
    """
    from jax.sharding import PartitionSpec as P

    e = w_gate.shape[0]
    b, s, d = x.shape

    def local_fn(x_l, rw, wg_l, wi_l, wo_l):
        e_loc = wg_l.shape[0]
        m_idx = jax.lax.axis_index(expert_axis)
        bl, sl, dl = x_l.shape
        t = bl * sl
        xf = x_l.reshape(t, dl)
        logits = jnp.einsum("td,de->te", xf, rw.astype(x_l.dtype)).astype(jnp.float32)
        gates, choices = jax.lax.top_k(logits, top_k)
        gates = jax.nn.softmax(gates, axis=-1)

        tok_idx = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
        exp_idx = choices.reshape(-1).astype(jnp.int32)
        gate = gates.reshape(-1).astype(x_l.dtype)

        owned = (exp_idx >= m_idx * e_loc) & (exp_idx < (m_idx + 1) * e_loc)
        local_e = jnp.where(owned, exp_idx - m_idx * e_loc, e_loc)
        rank = _cumcount(jnp.where(owned, local_e, e_loc + 1), e_loc)
        keep = owned & (rank < capacity_local)
        n_slots = e_loc * capacity_local
        slot = jnp.where(keep, local_e * capacity_local + rank, n_slots)

        # Capacity-sized dispatch: materializing xf[tok_idx] is a [T·k, D]
        # gather (kimi train_4k: 7.5 GiB ×live-copies ⇒ 173 GiB/dev,
        # EXPERIMENTS §Perf). Invert the map instead — every buffer is
        # [E_loc·C, D], never token-count-sized.
        token_for_slot = jnp.full((n_slots + 1,), t, jnp.int32).at[slot].set(tok_idx)
        gate_for_slot = jnp.zeros((n_slots + 1,), x_l.dtype).at[slot].set(
            jnp.where(keep, gate, 0.0)
        )
        xf_ext = jnp.concatenate([xf, jnp.zeros((1, dl), x_l.dtype)])
        xs = xf_ext[token_for_slot[:-1]].reshape(e_loc, capacity_local, dl)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wg_l.astype(x_l.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", xs, wi_l.astype(x_l.dtype))
        ys = jnp.einsum("ecf,efd->ecd", h, wo_l.astype(x_l.dtype))

        ys_flat = ys.reshape(n_slots, dl) * gate_for_slot[:-1, None]
        out = jnp.zeros((t + 1, dl), x_l.dtype).at[token_for_slot[:-1]].add(ys_flat)
        out = jax.lax.psum(out[:t], expert_axis)

        # load-balance aux, reduced over every mesh axis so it is truly
        # replicated (out_specs P() demands it)
        red = tuple(token_axes or ()) + (expert_axis,)
        probs = jax.nn.softmax(logits, axis=-1)
        load = jax.lax.pmean(jnp.mean(probs, axis=0), red)
        imp = jnp.zeros(e, jnp.float32).at[exp_idx].add(1.0) / (t * top_k)
        imp = jax.lax.pmean(imp, red)
        aux = e * jnp.sum(load * imp)
        return out.reshape(bl, sl, dl), aux

    tok = tuple(token_axes) if token_axes else None
    out, aux = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(tok, None, None), P(), P(expert_axis, None, None),
                  P(expert_axis, None, None), P(expert_axis, None, None)),
        out_specs=(P(tok, None, None), P()),
        **_SHMAP_NOCHECK,
    )(x, router_w, w_gate, w_in, w_out)
    return out, aux
