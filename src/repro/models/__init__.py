"""Architecture zoo: LM transformers (dense / MoE / GQA / sliding-window),
GNNs (GIN, GAT, MeshGraphNet, GraphCast) and SASRec, all defined through
the logical-axis param system in param.py."""
