"""LM transformer family: dense (Yi, Mistral-Large, Gemma3) and MoE
(Kimi-K2, Granite) with GQA, RoPE, SwiGLU, optional sliding-window layers
(Gemma3 5:1 local:global), scan-over-layers, chunked prefill, and a
sequence-sharded KV cache decode path.

Design notes
  * scan-over-layers keeps the HLO (and compile time) O(1) in depth —
    layer params are stacked with a leading "layer" logical axis.
  * activation sharding constraints are injected by the launcher via
    ``Constraints`` (the model is mesh-agnostic).
  * the only static knobs are in LMConfig; every (arch × shape) cell of
    the assignment lowers through make_train_step / make_prefill /
    make_decode_step below.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.param import ParamSpec, cast_floats


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    vocab_padded: int  # padded to mesh divisibility (DESIGN.md §8)
    moe: MoESpec | None = None
    sliding_window: int | None = None  # window size for local layers
    global_every: int = 0  # gemma3: every 6th layer is global (5:1)
    rope_theta: float = 10000.0
    act_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    q_chunk: int = 1024  # chunked prefill threshold/chunk


@dataclass(frozen=True)
class Constraints:
    """Optional NamedShardings injected by the launcher."""
    activations: Any = None  # [B, S, D]
    logits: Any = None  # [B, S, V]
    kv_cache: Any = None  # [L, B, S, KV, hd]
    # Sequence parallelism discipline: q/k/v are all-gathered ONCE here
    # (seq replicated) before attention; the residual constraint above
    # reduce-scatters after. Without this the q-chunk scan re-gathers
    # seq-sharded q/k/v per chunk per layer (yi-6b prefill: 982 GiB of
    # all-gather per device — EXPERIMENTS §Perf iteration 8). Q shards its
    # heads over "model" where divisible; K/V heads (4–8 GQA groups) are
    # replicated — every query group needs all of them anyway.
    attn_q: Any = None  # [B, S, H, hd]
    attn_kv: Any = None  # [B, S, KV, hd]
    moe_buf: Any = None  # [E, C, D] expert dispatch buffer (global-path only)
    # shard_map expert parallelism (layers.moe_mlp_shmap); None = global path
    mesh: Any = None
    expert_axis: str = "model"
    token_axes: tuple = ()


def _c(x, s):
    return jax.lax.with_sharding_constraint(x, s) if s is not None else x


# ------------------------------------------------------------------- params

def param_specs(cfg: LMConfig) -> dict:
    nl, d, h, kv, hd = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    specs = {
        "embed": ParamSpec((cfg.vocab_padded, d), ("vocab", "embed"), "normal", dt),
        "final_norm": ParamSpec((d,), ("embed",), "zeros", dt),
        "unembed": ParamSpec((d, cfg.vocab_padded), ("embed", "vocab"), "scaled", dt),
        "layers": {
            "attn_norm": ParamSpec((nl, d), ("layer", "embed"), "zeros", dt),
            "mlp_norm": ParamSpec((nl, d), ("layer", "embed"), "zeros", dt),
            "wq": ParamSpec((nl, d, h, hd), ("layer", "embed", "heads", "head_dim"), "scaled", dt),
            "wk": ParamSpec((nl, d, kv, hd), ("layer", "embed", "kv_heads", "head_dim"), "scaled", dt),
            "wv": ParamSpec((nl, d, kv, hd), ("layer", "embed", "kv_heads", "head_dim"), "scaled", dt),
            "wo": ParamSpec((nl, h, hd, d), ("layer", "heads", "head_dim", "embed"), "scaled", dt),
        },
    }
    lyr = specs["layers"]
    if cfg.moe is None:
        lyr["wi"] = ParamSpec((nl, d, cfg.d_ff), ("layer", "embed", "mlp"), "scaled", dt)
        lyr["wg"] = ParamSpec((nl, d, cfg.d_ff), ("layer", "embed", "mlp"), "scaled", dt)
        lyr["wo_mlp"] = ParamSpec((nl, cfg.d_ff, d), ("layer", "mlp", "embed"), "scaled", dt)
    else:
        m = cfg.moe
        lyr["router"] = ParamSpec((nl, d, m.n_experts), ("layer", "embed", "expert"), "scaled", dt)
        lyr["we_g"] = ParamSpec((nl, m.n_experts, d, m.d_ff_expert), ("layer", "expert", "embed", "mlp"), "scaled", dt)
        lyr["we_i"] = ParamSpec((nl, m.n_experts, d, m.d_ff_expert), ("layer", "expert", "embed", "mlp"), "scaled", dt)
        lyr["we_o"] = ParamSpec((nl, m.n_experts, m.d_ff_expert, d), ("layer", "expert", "mlp", "embed"), "scaled", dt)
        if m.n_shared:
            f_sh = m.d_ff_expert * m.n_shared
            lyr["ws_g"] = ParamSpec((nl, d, f_sh), ("layer", "embed", "mlp"), "scaled", dt)
            lyr["ws_i"] = ParamSpec((nl, d, f_sh), ("layer", "embed", "mlp"), "scaled", dt)
            lyr["ws_o"] = ParamSpec((nl, f_sh, d), ("layer", "mlp", "embed"), "scaled", dt)
    return specs


def _is_global_layer(cfg: LMConfig, idx):
    """Gemma3 pattern: layers (global_every-1, 2·global_every-1, …) are global."""
    if cfg.sliding_window is None or cfg.global_every == 0:
        return jnp.ones_like(idx, dtype=bool)
    return (idx + 1) % cfg.global_every == 0


# ------------------------------------------------------------------ forward

def _layer(cfg: LMConfig, cons: Constraints, x, lp, layer_idx, positions,
           kv_positions=None, kv_cache=None, cur_len=None, capacity=None):
    """One transformer block. If kv_cache is given (decode), returns the
    updated (k, v) slices; else runs self-attention over x."""
    b, s, d = x.shape
    rms = L.rms_norm(x, lp["attn_norm"])
    q = jnp.einsum("bsd,dhk->bshk", rms, lp["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", rms, lp["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", rms, lp["wv"].astype(x.dtype))
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    q = _c(q, cons.attn_q)
    k = _c(k, cons.attn_kv)
    v = _c(v, cons.attn_kv)

    is_global = _is_global_layer(cfg, layer_idx)
    window = cfg.sliding_window
    new_kv = None
    if kv_cache is not None:
        ck, cv = kv_cache  # [B, S_max, KV, hd] — stays at KV heads
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cur_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cur_len, 0, 0))
        new_kv = (ck, cv)
        k_att, v_att = ck, cv
        kv_pos = kv_positions  # [B, S_max]
        valid = jnp.full((b,), cur_len + s, jnp.int32)
    else:
        # train/prefill: expand K/V to flat heads (sharding-clean path,
        # see layers._attend_flat) and re-constrain like q
        g = cfg.n_heads // cfg.n_kv_heads
        k_att = _c(L.expand_kv(k, g), cons.attn_q)
        v_att = _c(L.expand_kv(v, g), cons.attn_q)
        kv_pos = positions
        valid = None

    if window is not None:
        # Banded mask on local layers, full on global layers: widen the
        # window to "infinity" when the layer is global (traced select).
        eff_window = jnp.where(is_global, jnp.int32(2**30), jnp.int32(window))
    else:
        eff_window = None

    out = L.gqa_attention(
        q, k_att.astype(x.dtype), v_att.astype(x.dtype), positions, kv_pos,
        causal=True, window=eff_window, kv_valid_len=valid,
        q_chunk=cfg.q_chunk,
    )
    x = x + jnp.einsum("bshk,hkd->bsd", out, lp["wo"].astype(x.dtype))
    x = _c(x, cons.activations)

    rms = L.rms_norm(x, lp["mlp_norm"])
    aux = 0.0
    if cfg.moe is None:
        y = L.glu_mlp(rms, lp["wi"], lp["wg"], lp["wo_mlp"])
    else:
        m = cfg.moe
        shared = (lp["ws_g"], lp["ws_i"], lp["ws_o"]) if m.n_shared else None
        if cons.mesh is not None:
            # Expert-parallel shard_map path (production): tokens stay on
            # their data shard; one psum per layer. DESIGN.md §4.
            y, aux = L.moe_mlp_shmap(
                rms, lp["router"], lp["we_g"], lp["we_i"], lp["we_o"],
                top_k=m.top_k, capacity_local=capacity, mesh=cons.mesh,
                expert_axis=cons.expert_axis, token_axes=cons.token_axes,
            )
            if shared is not None:  # dense shared expert via plain GSPMD
                y = y + L.glu_mlp(rms, shared[1], shared[0], shared[2])
        else:
            y, aux = L.moe_mlp(
                rms, lp["router"], lp["we_g"], lp["we_i"], lp["we_o"],
                top_k=m.top_k, capacity=capacity, shared=shared,
                buf_constraint=cons.moe_buf,
            )
    x = _c(x + y, cons.activations)
    return x, new_kv, aux


def _moe_capacity(cfg: LMConfig, cons: Constraints, tokens_global: int) -> int | None:
    """Per-expert capacity. With the shard_map path this is the *local*
    capacity (tokens on one data shard, experts on one model shard)."""
    if cfg.moe is None:
        return None
    m = cfg.moe
    tokens = tokens_global
    if cons.mesh is not None:
        ext = 1
        for a in cons.token_axes:
            if a in cons.mesh.shape:
                ext *= cons.mesh.shape[a]
        tokens = max(1, tokens_global // ext)
    cap = int(m.top_k * tokens / m.n_experts * m.capacity_factor)
    return max(8, (cap + 7) // 8 * 8)


def forward(cfg: LMConfig, cons: Constraints, params, tokens, positions):
    """tokens [B, S] → logits [B, S, vocab_padded]. Used by train + prefill."""
    x = params["embed"].astype(cfg.act_dtype)[tokens]
    x = _c(x, cons.activations)
    capacity = _moe_capacity(cfg, cons, tokens.shape[0] * tokens.shape[1])
    # Cast the stacked layer params ONCE, before the scan: the ZeRO-3
    # weight all-gather inside the layer loop then moves bf16, not f32 —
    # halving the dominant collective of the f32-param train cells
    # (EXPERIMENTS §Perf iteration 9).
    params = dict(params, layers=cast_floats(params["layers"], cfg.act_dtype))

    def body(carry, scan_in):
        x, aux_acc = carry
        lp, idx = scan_in
        x, _, aux = _layer(cfg, cons, x, lp, idx, positions, capacity=capacity)
        return (x, aux_acc + aux), None

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), (params["layers"], idxs))
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    return _c(logits, cons.logits), aux


def lm_loss(cfg: LMConfig, cons: Constraints, params, batch):
    """Causal next-token cross-entropy with vocab padding masked out."""
    tokens, loss_mask = batch["tokens"], batch["loss_mask"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    logits, aux = forward(cfg, cons, params, tokens, positions)
    logits = logits.astype(jnp.float32)
    # Mask padded vocab slots out of the partition function.
    vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab
    logits = jnp.where(vmask[None, None, :], logits, jnp.finfo(jnp.float32).min)
    targets = jnp.roll(tokens, -1, axis=1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # Vocab-parallel gold extraction: take_along_axis over a vocab-sharded
    # logits tensor makes XLA all-gather the full [B,S,V] per device
    # (measured: gemma3 train_4k 137 GiB/dev — EXPERIMENTS §Perf). The
    # iota-match form stays elementwise in the sharded vocab dim and
    # reduces locally + one small all-reduce.
    viota = jnp.arange(cfg.vocab_padded, dtype=jnp.int32)  # 1-D: fusable
    gold = jnp.sum(
        jnp.where(viota[None, None, :] == targets[..., None], logits, 0.0), axis=-1
    )
    nll = (logz - gold) * loss_mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(loss_mask), 1.0)
    return loss + 0.01 * aux


def make_prefill(cfg: LMConfig, cons: Constraints = Constraints()):
    """tokens [B, S] → logits (inference prefill, no loss)."""

    def prefill(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        params_c = cast_floats(params, cfg.act_dtype)
        logits, _ = forward(cfg, cons, params_c, tokens, positions)
        return logits

    return prefill


def make_decode_step(cfg: LMConfig, cons: Constraints = Constraints()):
    """serve_step: one new token against an [L, B, S_max, KV, hd] KV cache."""

    def decode_step(params, cache, batch):
        tokens, cur_len = batch["tokens"], batch["cur_len"]  # [B,1], scalar int32
        b, s = tokens.shape
        s_max = cache["k"].shape[2]
        positions = jnp.broadcast_to(cur_len[None, None], (b, s)).astype(jnp.int32)
        kv_positions = jnp.broadcast_to(
            jnp.arange(s_max, dtype=jnp.int32)[None], (b, s_max)
        )
        params_c = cast_floats(params, cfg.act_dtype)
        x = params_c["embed"].astype(cfg.act_dtype)[tokens]
        capacity = _moe_capacity(cfg, cons, b * s)

        def body(carry, scan_in):
            x = carry
            lp, idx, ck, cv = scan_in
            x, new_kv, _ = _layer(
                cfg, cons, x, lp, idx, positions,
                kv_positions=kv_positions, kv_cache=(ck, cv), cur_len=cur_len,
                capacity=capacity,
            )
            return x, new_kv

        idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        x, (nk, nv) = jax.lax.scan(
            body, x, (params_c["layers"], idxs, cache["k"], cache["v"])
        )
        x = L.rms_norm(x, params_c["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x, params_c["unembed"].astype(x.dtype))
        nk = _c(nk, cons.kv_cache)
        nv = _c(nv, cons.kv_cache)
        return logits, {"k": nk, "v": nv}

    return decode_step


def abstract_kv_cache(cfg: LMConfig, batch: int, s_max: int):
    shape = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, cfg.act_dtype),
        "v": jax.ShapeDtypeStruct(shape, cfg.act_dtype),
    }
