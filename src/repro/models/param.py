"""Minimal parameter system with logical sharding axes (t5x/MaxText style).

A model is a function ``config → {name: ParamSpec}`` (nested dicts allowed).
Each ParamSpec carries *logical* axis names ("embed", "heads", "expert",
"vocab", ...). sharding/rules.py maps logical axes → mesh axes per arch, so
the same model code runs on any mesh.

No flax dependency: params are plain pytrees of jnp arrays; the spec tree
is the single source of truth for shapes, init and sharding.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in)
    dtype: Any = jnp.float32
    init_scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)


def _init_one(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "scaled":  # fan-in scaled normal
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape) * scale).astype(spec.dtype)
    return (jax.random.normal(key, spec.shape) * spec.init_scale).astype(spec.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(key: jax.Array, specs) -> Any:
    """Materialize a spec tree into a param pytree (host-sequential PRNG split)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(specs) -> Any:
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def logical_axes(specs) -> Any:
    return jax.tree_util.tree_map(lambda s: s.logical_axes, specs, is_leaf=is_spec)


def cast_floats(tree, dtype):
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            if isinstance(x, jax.ShapeDtypeStruct):
                return jax.ShapeDtypeStruct(x.shape, dtype, sharding=x.sharding)
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(cast, tree)


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)
