"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeSpec, input_specs
from repro.configs.biggraphvis import biggraphvis
from repro.configs.gnn_archs import gat_cora, gin_tu, graphcast, meshgraphnet
from repro.configs.lm_archs import (
    gemma3_4b,
    granite_moe_1b_a400m,
    kimi_k2_1t_a32b,
    mistral_large_123b,
    yi_6b,
)
from repro.configs.sasrec import sasrec

REGISTRY = {
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "yi-6b": yi_6b,
    "gemma3-4b": gemma3_4b,
    "mistral-large-123b": mistral_large_123b,
    "gin-tu": gin_tu,
    "meshgraphnet": meshgraphnet,
    "graphcast": graphcast,
    "gat-cora": gat_cora,
    "sasrec": sasrec,
    "biggraphvis": biggraphvis,
}

ASSIGNED = [k for k in REGISTRY if k != "biggraphvis"]


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]()


def all_cells(include_bgv: bool = True):
    """Every (arch, shape) dry-run cell, skipped cells included (flagged)."""
    for name, builder in REGISTRY.items():
        if name == "biggraphvis" and not include_bgv:
            continue
        arch = builder()
        for shape in arch.shapes.values():
            yield arch, shape
