"""Config system: ArchConfig (family, model hyperparams, shape cells,
sharding profile) + ShapeSpec (one dry-run cell). ``input_specs`` builds
the ShapeDtypeStruct stand-ins for every cell — no allocation.

Padding policy (DESIGN.md §8): XLA requires sharded dims divisible by the
mesh-axis extent, so vocab / node / edge / candidate counts are padded up
to mesh-friendly capacities here; true sizes stay in the configs and
padding is masked in the losses.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | graph_train | serve | retrieval
    # LM
    seq_len: int = 0
    global_batch: int = 0
    # GNN (padded capacities)
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    n_out: int = 0
    task: str = ""
    n_graphs: int = 0
    # recsys
    n_candidates: int = 0
    # cell skipped (reason) — still listed, never lowered
    skip: str = ""


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # lm | gnn | recsys | bgv
    profile: str  # sharding profile name (sharding/rules.py)
    model: Any  # LMConfig | GNNConfig | SASRecConfig | BGVDryConfig
    shapes: dict[str, ShapeSpec] = field(default_factory=dict)
    # train-time knobs used by launch (per-arch)
    opt_state_bits: int = 32
    # gradient-accumulation microbatches for train cells (0 = off).
    # Trade-off measured in EXPERIMENTS §Perf: each microbatch divides the
    # activation stacks but REPLAYS the ZeRO-3 weight all-gather.
    microbatch_train: int = 0
    notes: str = ""

    def model_for(self, shape: ShapeSpec):
        """Per-shape model adjustments (GNN d_feat/n_out/task vary by cell)."""
        if self.family == "gnn":
            return replace(
                self.model,
                d_feat=shape.d_feat,
                n_out=shape.n_out,
                task=shape.task,
                remat=shape.n_nodes >= 100_000,
            )
        return self.model


# --------------------------------------------------------- LM shape builders

def lm_shapes(sub_quadratic: bool) -> dict[str, ShapeSpec]:
    shapes = {
        "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
        "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
        "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
    }
    if not sub_quadratic:
        shapes["long_500k"] = replace(
            shapes["long_500k"],
            skip="pure full-attention arch: long_500k requires sub-quadratic "
                 "attention (assignment rule; see DESIGN.md §5)",
        )
    return shapes


# -------------------------------------------------------- GNN shape builders

def gnn_shapes(arch: str) -> dict[str, ShapeSpec]:
    """The four assigned graph cells. Node/edge counts padded to 512-multiples;
    d_feat/n_out per shape from the public datasets backing each regime
    (cora / reddit / ogbn-products / molecules)."""
    reg = arch in ("meshgraphnet", "graphcast")
    n_out_sm, task_sm = (227, "node_reg") if arch == "graphcast" else ((3, "node_reg") if reg else (7, "node_class"))
    n_out_lg, task_lg = (227, "node_reg") if arch == "graphcast" else ((3, "node_reg") if reg else (41, "node_class"))
    n_out_pr, task_pr = (227, "node_reg") if arch == "graphcast" else ((3, "node_reg") if reg else (47, "node_class"))
    n_out_mol, task_mol = (2, "graph_class") if not reg else (1, "node_reg")
    return {
        "full_graph_sm": ShapeSpec(
            "full_graph_sm", "graph_train",
            n_nodes=pad_to(2708, 512), n_edges=pad_to(10556, 512),
            d_feat=1433, n_out=n_out_sm, task=task_sm,
        ),
        "minibatch_lg": ShapeSpec(
            "minibatch_lg", "graph_train",
            # sampler capacity for batch_nodes=1024, fanout (15, 10)
            n_nodes=1024 * (1 + 15 + 150), n_edges=1024 * 15 + 1024 * 15 * 10,
            d_feat=602, n_out=n_out_lg, task=task_lg,
        ),
        "ogb_products": ShapeSpec(
            "ogb_products", "graph_train",
            n_nodes=pad_to(2_449_029, 512), n_edges=pad_to(61_859_140, 512),
            d_feat=100, n_out=n_out_pr, task=task_pr,
        ),
        "molecule": ShapeSpec(
            "molecule", "graph_train",
            n_nodes=pad_to(128 * 30, 512), n_edges=pad_to(128 * 64, 512),
            d_feat=16, n_out=n_out_mol, task=task_mol, n_graphs=128,
        ),
    }


# ----------------------------------------------------- recsys shape builders

def recsys_shapes() -> dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "train", global_batch=65536),
        "serve_p99": ShapeSpec("serve_p99", "serve", global_batch=512),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", global_batch=262144),
        "retrieval_cand": ShapeSpec(
            "retrieval_cand", "retrieval", global_batch=1,
            n_candidates=pad_to(1_000_000, 512),
        ),
    }


# -------------------------------------------------------------- input specs

def input_specs(arch: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    if arch.family == "lm":
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {
                "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "loss_mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
            }
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "decode":
            return {
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "cur_len": jax.ShapeDtypeStruct((), jnp.int32),
            }
    if arch.family == "gnn":
        spec = {
            "feats": jax.ShapeDtypeStruct((shape.n_nodes, shape.d_feat), jnp.float32),
            "edges": jax.ShapeDtypeStruct((shape.n_edges, 2), jnp.int32),
        }
        if shape.task == "graph_class":
            spec["graph_ids"] = jax.ShapeDtypeStruct((shape.n_nodes,), jnp.int32)
            spec["labels"] = jax.ShapeDtypeStruct((shape.n_graphs,), jnp.int32)
            spec["mask"] = jax.ShapeDtypeStruct((shape.n_graphs,), jnp.float32)
        elif shape.task == "node_reg":
            spec["labels"] = jax.ShapeDtypeStruct((shape.n_nodes, shape.n_out), jnp.float32)
            spec["mask"] = jax.ShapeDtypeStruct((shape.n_nodes,), jnp.float32)
        else:
            spec["labels"] = jax.ShapeDtypeStruct((shape.n_nodes,), jnp.int32)
            spec["mask"] = jax.ShapeDtypeStruct((shape.n_nodes,), jnp.float32)
        return spec
    if arch.family == "recsys":
        s = arch.model.seq_len
        b = shape.global_batch
        if shape.kind == "train":
            return {
                "seq": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "pos": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "neg": jax.ShapeDtypeStruct((b, s), jnp.int32),
            }
        if shape.kind == "serve":
            return {"seq": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "retrieval":
            return {
                "seq": jax.ShapeDtypeStruct((1, s), jnp.int32),
                "candidates": jax.ShapeDtypeStruct((shape.n_candidates,), jnp.int32),
            }
    raise ValueError(f"no input spec for {arch.name}/{shape.name}")
