"""The five assigned LM-family architectures, exact configs from the
assignment table (sources noted per entry)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig, lm_shapes, pad_to
from repro.models.transformer import LMConfig, MoESpec


def kimi_k2_1t_a32b() -> ArchConfig:
    # [arXiv:2501.kimi2; unverified] 61L d=7168 64H (GQA kv=8) per-expert
    # d_ff=2048, vocab 163840, MoE 384e top-8 (+1 shared) — ~1T total.
    model = LMConfig(
        name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
        n_kv_heads=8, head_dim=128, d_ff=2048, vocab=163840,
        vocab_padded=163840,
        moe=MoESpec(n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1),
    )
    return ArchConfig(
        name="kimi-k2-1t-a32b", family="lm", profile="tp", model=model,
        shapes=lm_shapes(sub_quadratic=False), opt_state_bits=8,
        microbatch_train=4,
        notes="1T-param MoE: bf16 weights + int8 momentum + factored v + "
              "4 microbatches to approach 512×16GB (EXPERIMENTS §Perf).",
    )


def granite_moe_1b_a400m() -> ArchConfig:
    # [hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 24L d=1024 16H kv=8,
    # per-expert d_ff=512, MoE 32e top-8, vocab 49155 (padded →49280 for
    # 16-way vocab sharding).
    model = LMConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=8, head_dim=64, d_ff=512, vocab=49155,
        vocab_padded=pad_to(49155, 16 * 8),
        moe=MoESpec(n_experts=32, top_k=8, d_ff_expert=512),
    )
    return ArchConfig(
        name="granite-moe-1b-a400m", family="lm", profile="tp", model=model,
        shapes=lm_shapes(sub_quadratic=False),
    )


def yi_6b() -> ArchConfig:
    # [arXiv:2403.04652; hf] llama-arch GQA: 32L d=4096 32H kv=4 d_ff=11008
    # vocab 64000.
    model = LMConfig(
        name="yi-6b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
        head_dim=128, d_ff=11008, vocab=64000, vocab_padded=64000,
    )
    return ArchConfig(
        name="yi-6b", family="lm", profile="tp", model=model,
        shapes=lm_shapes(sub_quadratic=False),
    )


def gemma3_4b() -> ArchConfig:
    # [hf:google/gemma-3-4b-pt; unverified] 34L d=2560 8H kv=4 head_dim 256
    # d_ff=10240 vocab 262144; 5:1 local:global sliding window (1024).
    model = LMConfig(
        name="gemma3-4b", n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
        head_dim=256, d_ff=10240, vocab=262144, vocab_padded=262144,
        sliding_window=1024, global_every=6,
    )
    return ArchConfig(
        name="gemma3-4b", family="lm", profile="tp", model=model,
        # hybrid local:global ⇒ sub-quadratic: long_500k RUNS for this arch
        shapes=lm_shapes(sub_quadratic=True),
        notes="8 heads < 16-way model axis: the tp profile's heads rule "
              "degrades to replicated via the divisibility fallback; "
              "mlp/vocab/embed still shard (DESIGN.md §4). A separate fsdp "
              "profile mis-aligned unembed (vocab→data) against the logits "
              "sharding (vocab→model) and cost a 64 GiB all-gather in the "
              "unembed backward — see EXPERIMENTS §Perf iteration 4.",
    )


def mistral_large_123b() -> ArchConfig:
    # [hf:mistralai/Mistral-Large-Instruct-2407; unverified] 88L d=12288
    # 96H kv=8 d_ff=28672 vocab 32768.
    model = LMConfig(
        name="mistral-large-123b", n_layers=88, d_model=12288, n_heads=96,
        n_kv_heads=8, head_dim=128, d_ff=28672, vocab=32768, vocab_padded=32768,
    )
    return ArchConfig(
        name="mistral-large-123b", family="lm", profile="tp", model=model,
        shapes=lm_shapes(sub_quadratic=False), microbatch_train=2,
        notes="microbatch=2 is the measured sweet spot: mb=4 replayed the "
              "ZeRO-3 weight gathers once too often, mb=0 blew the "
              "activation stacks (EXPERIMENTS §Perf hillclimb B).",
    )


def smoke_lm(moe: bool = False, sliding: bool = False) -> LMConfig:
    """Reduced same-family config for CPU smoke tests."""
    return LMConfig(
        name="smoke-lm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=97, vocab_padded=112,
        moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=64, n_shared=1) if moe else None,
        sliding_window=8 if sliding else None, global_every=2 if sliding else 0,
        act_dtype=jnp.float32, q_chunk=8,
    )
