"""SASRec recsys architecture (exact config from the assignment)."""
from __future__ import annotations

from repro.configs.base import ArchConfig, recsys_shapes
from repro.models.sasrec import SASRecConfig


def sasrec() -> ArchConfig:
    # [arXiv:1808.09781; paper] embed_dim 50, 2 blocks, 1 head, seq_len 50,
    # self-attentive sequential interaction. Item table sized for the
    # retrieval_cand cell (10⁶ candidates) → 2²⁰ items (mesh-divisible).
    model = SASRecConfig(name="sasrec", n_items=1_048_576, embed_dim=50,
                         n_blocks=2, n_heads=1, seq_len=50)
    return ArchConfig(name="sasrec", family="recsys", profile="recsys",
                      model=model, shapes=recsys_shapes(),
                      notes="embed_dim=50 kept faithful (not MXU-aligned); "
                            "§Perf quantifies and pads as an optimization.")


def smoke_sasrec() -> SASRecConfig:
    return SASRecConfig(name="smoke-sasrec", n_items=500, embed_dim=16,
                        n_blocks=2, n_heads=1, seq_len=12)
