"""The four assigned GNN architectures (exact configs from the assignment)."""
from __future__ import annotations

from repro.configs.base import ArchConfig, gnn_shapes
from repro.models.gnn import GNNConfig


def gin_tu() -> ArchConfig:
    # [arXiv:1810.00826; paper] 5 layers, d_hidden 64, sum aggregator,
    # learnable eps.
    model = GNNConfig(name="gin-tu", arch="gin", n_layers=5, d_hidden=64,
                      d_feat=16, n_out=2)
    return ArchConfig(name="gin-tu", family="gnn", profile="gnn", model=model,
                      shapes=gnn_shapes("gin"))


def meshgraphnet() -> ArchConfig:
    # [arXiv:2010.03409; unverified] 15 message-passing steps, d_hidden 128,
    # 2-layer MLPs, sum aggregation.
    model = GNNConfig(name="meshgraphnet", arch="meshgraphnet", n_layers=15,
                      d_hidden=128, d_feat=16, n_out=3, task="node_reg")
    return ArchConfig(name="meshgraphnet", family="gnn", profile="gnn",
                      model=model, shapes=gnn_shapes("meshgraphnet"))


def graphcast() -> ArchConfig:
    # [arXiv:2212.12794; unverified] encoder-processor-decoder mesh GNN,
    # 16 processor layers, d_hidden 512, 227 output vars.
    model = GNNConfig(name="graphcast", arch="graphcast", n_layers=16,
                      d_hidden=512, d_feat=227, n_out=227, task="node_reg")
    return ArchConfig(name="graphcast", family="gnn", profile="gnn",
                      model=model, shapes=gnn_shapes("graphcast"))


def gat_cora() -> ArchConfig:
    # [arXiv:1710.10903; paper] 2 layers, 8 heads × 8 hidden, attention
    # aggregator.
    model = GNNConfig(name="gat-cora", arch="gat", n_layers=2, d_hidden=64,
                      n_heads=8, d_feat=1433, n_out=7)
    return ArchConfig(name="gat-cora", family="gnn", profile="gnn",
                      model=model, shapes=gnn_shapes("gat"))


def smoke_gnn(arch: str) -> GNNConfig:
    return GNNConfig(name=f"smoke-{arch}", arch=arch, n_layers=2, d_hidden=16,
                     n_heads=2 if arch == "gat" else 1, d_feat=8, n_out=3)
