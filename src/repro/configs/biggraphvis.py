"""The paper's own workload as a dry-run citizen: multi-device BigGraphVis.

Two step kinds (DESIGN.md §4):
  * detect — one SCoDA streaming round + CMS sizing over *edge shards*
             (labels merge by all-reduce-min, sketches by all-reduce-add);
  * layout — one ForceAtlas2 iteration on the supergraph (n-body DP:
             node tiles sharded, positions all-gathered). The repulsion
             backend is ``BGVDryConfig.layout_repulsion``: "exact" n²
             tiles for supergraph shapes (the default), or the tiled
             uniform-grid family ("grid"/"grid_pallas", kernels/grid)
             when a cell lays out a full graph at paper scale.

Shapes mirror the paper's biggest graphs (Table 1): soc-LiveJournal
(4.0M nodes / 34.7M edges) and web-BerkStan (0.69M / 6.6M), plus the
supergraph layout at the paper's reported supernode counts.

The streamed form of the detect pass aggregates superedges through
``StreamConfig.agg_backend`` (core/stream.py): the default ``"merge"``
two-level sorted-merge (kernels/merge — Pallas on TPU, XLA elsewhere)
or the ``"lexsort"`` full re-sort baseline; both are bit-identical
below the superedge capacity.

The drawing stage itself is on-device too: repro/render rasterizes the
laid-out (super)graph through ``kernels/raster`` (edge splats streamed
chunk-by-chunk via EdgeChunkStream, node disks, int32 density
accumulation per palette color), so the picture for these Table-1
shapes is produced without the edge list ever being device-resident.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeSpec, pad_to


@dataclass(frozen=True)
class BGVDryConfig:
    name: str = "biggraphvis"
    rounds_per_step: int = 1
    cms_rows: int = 4
    # FA2 repulsion backend for the layout cells (core/forceatlas2.py
    # backend matrix): "exact" pairwise tiles for supergraph shapes,
    # "grid"/"grid_pallas" for full-graph shapes at paper scale.
    layout_repulsion: str = "exact"
    layout_grid_size: int = 64
    layout_grid_window: int = 32


def biggraphvis() -> ArchConfig:
    shapes = {
        "detect_livejournal": ShapeSpec(
            "detect_livejournal", "bgv_detect",
            n_nodes=pad_to(3_997_962, 512), n_edges=pad_to(34_681_189, 512),
            n_out=pad_to(34_500, 512),  # CMS cols (paper Table 1)
        ),
        "detect_berkstan": ShapeSpec(
            "detect_berkstan", "bgv_detect",
            n_nodes=pad_to(685_230, 512), n_edges=pad_to(6_649_470, 512),
            n_out=pad_to(6_500, 512),
        ),
        "layout_livejournal": ShapeSpec(
            "layout_livejournal", "bgv_layout",
            # paper Table 1: 248,188 supernodes / 566,160 superedges
            n_nodes=pad_to(248_188, 512), n_edges=pad_to(566_160, 512),
        ),
        "layout_berkstan": ShapeSpec(
            "layout_berkstan", "bgv_layout",
            n_nodes=pad_to(31_213, 512), n_edges=pad_to(57_382, 512),
        ),
    }
    return ArchConfig(name="biggraphvis", family="bgv", profile="gnn",
                      model=BGVDryConfig(), shapes=shapes)
