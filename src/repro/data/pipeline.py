"""Synthetic, deterministic, host-sharded data pipelines.

Every batch is a pure function of (seed, step, shard) — the property that
makes exactly-once data consumption trivial across restarts and elastic
resizes (fault_tolerance.ElasticPlan hands each pod its shard slice).
Token streams follow a Zipf distribution so LM losses behave like text;
graph batches come from the generators in repro.graph.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.generators import batched_molecules


@dataclass(frozen=True)
class LMStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.3

    def batch_at(self, step: int, shard: tuple[int, int] | None = None) -> dict:
        """(start,size) shard of the step's global batch, or the whole batch."""
        start, size = shard or (0, self.batch)
        rng = np.random.default_rng((self.seed, step))
        toks = rng.zipf(self.zipf_a, size=(self.batch, self.seq_len))
        toks = np.clip(toks, 1, self.vocab - 1).astype(np.int32)
        mask = np.ones_like(toks, np.float32)
        mask[:, -1] = 0.0  # rolled target wraps at the last position
        return {
            "tokens": toks[start : start + size],
            "loss_mask": mask[start : start + size],
        }


@dataclass(frozen=True)
class SASRecStream:
    n_items: int
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int, shard: tuple[int, int] | None = None) -> dict:
        start, size = shard or (0, self.batch)
        rng = np.random.default_rng((self.seed, 7, step))
        seq = rng.zipf(1.2, size=(self.batch, self.seq_len + 1))
        seq = np.clip(seq, 1, self.n_items - 1).astype(np.int32)
        neg = rng.integers(1, self.n_items, size=(self.batch, self.seq_len)).astype(np.int32)
        return {
            "seq": seq[start : start + size, :-1],
            "pos": seq[start : start + size, 1:],
            "neg": neg[start : start + size],
        }


@dataclass(frozen=True)
class MoleculeStream:
    batch: int
    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int = 2
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, 11, step))
        edges, feats, gids = batched_molecules(
            self.batch, self.n_nodes, self.n_edges, self.d_feat,
            seed=int(rng.integers(0, 2**31)),
        )
        labels = rng.integers(0, self.n_classes, self.batch).astype(np.int32)
        return {
            "feats": feats,
            "edges": edges,
            "graph_ids": gids,
            "labels": labels,
            "mask": np.ones(self.batch, np.float32),
        }
