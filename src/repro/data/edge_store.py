"""Out-of-core edge sources for the streaming engine (ROADMAP: "stream from
disk").

The paper's premise is that community detection needs only "a few passes on
the edge list" — so the edge list should never have to fit in device *or*
host memory. An ``EdgeStore`` is the engine's host-side edge source: a
random-access reader of ``[E, 2] int32`` edge rows that

* validates dtype/shape once, up front (``core/stream.py`` consumes any
  store without re-checking),
* exposes ``read_into(start, out)`` so readers fill caller-owned staging
  buffers (the double-buffered host→device pipeline reuses two fixed
  buffers; disk-backed stores never materialize the full list),
* reports ``resident_bytes`` — the host bytes the store itself pins.
  Memory-mapped stores report 0: their pages live in the OS page cache
  and are evicted under pressure, so host residency of a streamed run is
  the staging buffers alone, independent of |E|.

Concrete stores: ``InMemoryEdgeStore`` (NumPy array), ``NpyEdgeStore``
(memory-mapped ``.npy``), ``BinEdgeStore`` (raw little-endian int32 pairs),
``ShardedEdgeStore`` (concatenation of sub-stores, e.g. one file per
crawl shard). ``write_npy`` / ``write_bin`` / ``write_shards`` are the
streaming writers (chunked, so store→store conversion is itself
out-of-core), and the module doubles as the converter CLI:

    PYTHONPATH=src python -m repro.data.edge_store info edges.npy
    PYTHONPATH=src python -m repro.data.edge_store convert edges.bin out.npy
    PYTHONPATH=src python -m repro.data.edge_store convert big.npy shards/ \
        --format shards --shard-edges 1000000
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

import numpy as np

EDGE_DTYPE = np.dtype(np.int32)
ROW_BYTES = 2 * EDGE_DTYPE.itemsize

MANIFEST_NAME = "manifest.json"


class EdgeStoreError(ValueError):
    """A source cannot be interpreted as an [E, 2] int32 edge list."""


class CorruptStoreError(EdgeStoreError):
    """A store's on-disk bytes contradict its declared layout — a truncated
    or trailing-garbage file, or shard sizes that don't sum to the declared
    edge count. The message names the file and byte offset of the damage."""


def _check_edge_shape(shape: tuple, what: str) -> None:
    if len(shape) != 2 or shape[1] != 2:
        raise EdgeStoreError(
            f"{what}: edge lists must have shape [E, 2], got {tuple(shape)}"
        )


class EdgeStore:
    """Random-access source of [E, 2] int32 edge rows.

    Subclasses set ``n_edges`` and implement ``read_into``. Construction
    validates dtype and shape once; every read after that is trusted.
    """

    n_edges: int = 0

    def read_into(self, start: int, out: np.ndarray) -> int:
        """Fill ``out`` (an [k, 2] int32 buffer) with rows ``start:start+k``.

        Returns the number of rows written — fewer than ``len(out)`` only
        at the tail. Rows past the end are left untouched (callers pad).
        """
        raise NotImplementedError

    def read(self, start: int, count: int) -> np.ndarray:
        """Convenience copy-out; prefer ``read_into`` on hot paths."""
        out = np.empty((count, 2), EDGE_DTYPE)
        k = self.read_into(start, out)
        return out[:k]

    @property
    def resident_bytes(self) -> int:
        """Host bytes this store pins (0 for page-cache-backed stores)."""
        return 0

    def __len__(self) -> int:
        return self.n_edges


class InMemoryEdgeStore(EdgeStore):
    """Edge list held as a host NumPy array.

    Accepts any integer dtype (converted to int32); floats and other
    non-integer dtypes are rejected here rather than producing silently
    truncated node ids deep inside a kernel.
    """

    def __init__(self, edges: np.ndarray):
        edges = np.asarray(edges)
        if not np.issubdtype(edges.dtype, np.integer):
            raise EdgeStoreError(
                f"edge arrays must have an integer dtype, got {edges.dtype} "
                "(float node ids would be silently truncated)"
            )
        if edges.size == 0:
            edges = edges.reshape(0, 2)
        _check_edge_shape(edges.shape, "in-memory edges")
        if edges.dtype.itemsize > EDGE_DTYPE.itemsize and edges.size:
            lo, hi = int(edges.min()), int(edges.max())
            if lo < np.iinfo(EDGE_DTYPE).min or hi > np.iinfo(EDGE_DTYPE).max:
                raise EdgeStoreError(
                    f"node ids span [{lo}, {hi}], outside int32 range — "
                    "converting would silently wrap them"
                )
        self.array = np.ascontiguousarray(edges, dtype=EDGE_DTYPE)
        self.n_edges = len(self.array)

    def read_into(self, start: int, out: np.ndarray) -> int:
        k = max(0, min(len(out), self.n_edges - start))
        out[:k] = self.array[start : start + k]
        return k

    @property
    def resident_bytes(self) -> int:
        return self.array.nbytes


class NpyEdgeStore(EdgeStore):
    """Memory-mapped ``.npy`` edge file; the file is the backing store."""

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        try:
            mm = np.load(self.path, mmap_mode="r")
        except ValueError as e:
            # Bad/truncated header, or data section shorter than the header
            # declares (np.memmap refuses to map past EOF in mode "r").
            raise CorruptStoreError(
                f"{self.path}: truncated or corrupt .npy "
                f"(file ends at byte {os.path.getsize(self.path)}): {e}"
            ) from e
        _check_edge_shape(mm.shape, self.path)
        if mm.dtype != EDGE_DTYPE:
            raise EdgeStoreError(
                f"{self.path}: mmap edge files must be int32, got {mm.dtype} "
                "(convert with `python -m repro.data.edge_store convert`)"
            )
        # The header declares the shape; verify the file actually holds that
        # many bytes (np.load would otherwise mmap short and fault on read).
        need = mm.offset + mm.size * mm.itemsize
        have = os.path.getsize(self.path)
        if have < need:
            raise CorruptStoreError(
                f"{self.path}: truncated — header declares {len(mm)} edges "
                f"({need} bytes) but the file ends at byte {have}"
            )
        self._mm = mm
        self.n_edges = len(mm)

    def read_into(self, start: int, out: np.ndarray) -> int:
        k = max(0, min(len(out), self.n_edges - start))
        out[:k] = self._mm[start : start + k]
        return k


class BinEdgeStore(EdgeStore):
    """Raw binary edge file: little-endian int32 (src, dst) pairs."""

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        size = os.path.getsize(self.path)
        if size % ROW_BYTES:
            raise CorruptStoreError(
                f"{self.path}: size {size} is not a multiple of {ROW_BYTES} "
                f"bytes (int32 src,dst pairs) — trailing partial record "
                f"starts at byte {size - size % ROW_BYTES}"
            )
        self.n_edges = size // ROW_BYTES
        self._mm = (
            np.memmap(self.path, dtype=EDGE_DTYPE, mode="r").reshape(-1, 2)
            if size
            else np.empty((0, 2), EDGE_DTYPE)
        )

    def read_into(self, start: int, out: np.ndarray) -> int:
        k = max(0, min(len(out), self.n_edges - start))
        out[:k] = self._mm[start : start + k]
        return k


class ShardedEdgeStore(EdgeStore):
    """Concatenation of sub-stores (one file per shard); empty shards ok."""

    def __init__(self, stores, expected_edges: int | None = None):
        self.stores = [as_edge_store(s) for s in stores]
        if not self.stores:
            raise EdgeStoreError("sharded store needs at least one shard")
        self.offsets = np.cumsum([0] + [s.n_edges for s in self.stores])
        self.n_edges = int(self.offsets[-1])
        if expected_edges is not None and self.n_edges != expected_edges:
            raise CorruptStoreError(
                f"sharded store: shard sizes sum to {self.n_edges} edges but "
                f"{expected_edges} were declared — shard rows "
                f"{[int(s.n_edges) for s in self.stores]}"
            )

    def read_into(self, start: int, out: np.ndarray) -> int:
        want = max(0, min(len(out), self.n_edges - start))
        done = 0
        # First shard containing row `start`: offsets is sorted, searchsorted
        # with side="right" lands past every shard that ends at/before start.
        i = int(np.searchsorted(self.offsets, start, side="right")) - 1
        i = max(0, i)
        while done < want and i < len(self.stores):
            local = start + done - int(self.offsets[i])
            done += self.stores[i].read_into(local, out[done:want])
            i += 1
        return done

    @property
    def resident_bytes(self) -> int:
        return sum(s.resident_bytes for s in self.stores)


def _open_manifest_shards(p: Path, manifest_path: Path) -> EdgeStore:
    """Open a shard directory against its ``manifest.json`` (written by
    ``write_shards``): every listed shard must exist and hold exactly its
    declared row count, and the totals must agree — a missing, truncated,
    or swapped shard raises ``CorruptStoreError`` naming file and offset
    instead of silently streaming a shorter edge list."""
    with open(manifest_path) as f:
        manifest = json.load(f)
    stores = []
    for entry in manifest["shards"]:
        q = p / entry["file"]
        if not q.exists():
            raise CorruptStoreError(
                f"{p}: shard {entry['file']} is listed in {MANIFEST_NAME} "
                f"({entry['edges']} edges) but missing on disk"
            )
        s = open_edge_store(q)
        if s.n_edges != entry["edges"]:
            raise CorruptStoreError(
                f"{q}: holds {s.n_edges} edges but {MANIFEST_NAME} declares "
                f"{entry['edges']} — file diverges at byte "
                f"{min(s.n_edges, entry['edges']) * ROW_BYTES} of the data"
            )
        stores.append(s)
    return ShardedEdgeStore(stores, expected_edges=manifest["total_edges"])


def open_edge_store(path: str | os.PathLike) -> EdgeStore:
    """Open a path as a store: ``.npy`` → mmap, directory → sharded (its
    ``manifest.json`` verified when present, sorted ``.npy``/``.bin`` files
    otherwise), anything else → raw int32-pair binary."""
    p = Path(path)
    if p.is_dir():
        manifest = p / MANIFEST_NAME
        if manifest.exists():
            return _open_manifest_shards(p, manifest)
        shards = sorted(q for q in p.iterdir() if q.suffix in (".npy", ".bin"))
        if not shards:
            raise EdgeStoreError(f"{p}: no .npy/.bin shard files found")
        return ShardedEdgeStore([open_edge_store(q) for q in shards])
    if not p.exists():
        raise EdgeStoreError(f"{p}: no such edge file")
    if p.suffix == ".npy":
        return NpyEdgeStore(p)
    return BinEdgeStore(p)


def as_edge_store(source) -> EdgeStore:
    """Coerce an engine edge source: EdgeStore (as-is), NumPy array
    (in-memory), str/path (``open_edge_store``), list of paths (sharded)."""
    if isinstance(source, EdgeStore):
        return source
    if isinstance(source, np.ndarray):
        return InMemoryEdgeStore(source)
    if isinstance(source, (str, os.PathLike)):
        return open_edge_store(source)
    if isinstance(source, (list, tuple)):
        return ShardedEdgeStore(source)
    raise EdgeStoreError(
        f"cannot interpret {type(source).__name__} as an edge source "
        "(expected ndarray, EdgeStore, path, or list of paths)"
    )


# ------------------------------------------------------------------ writers


DEFAULT_WRITE_CHUNK = 1 << 20  # rows per copy step: out-of-core conversion


def _chunks(store: EdgeStore, chunk_rows: int):
    buf = np.empty((max(1, chunk_rows), 2), EDGE_DTYPE)
    for start in range(0, store.n_edges, len(buf)):
        k = store.read_into(start, buf)
        yield buf[:k]


def write_npy(path, source, chunk_rows: int = DEFAULT_WRITE_CHUNK) -> str:
    """Stream ``source`` into a ``.npy`` file readable by ``NpyEdgeStore``.

    Uses a preallocated memmap target so the writer's host footprint is one
    chunk buffer regardless of |E|.
    """
    store = as_edge_store(source)
    path = os.fspath(path)
    out = np.lib.format.open_memmap(
        path, mode="w+", dtype=EDGE_DTYPE, shape=(store.n_edges, 2)
    )
    done = 0
    for chunk in _chunks(store, chunk_rows):
        out[done : done + len(chunk)] = chunk
        done += len(chunk)
    out.flush()
    del out
    return path


def write_bin(path, source, chunk_rows: int = DEFAULT_WRITE_CHUNK) -> str:
    """Stream ``source`` into a raw little-endian int32-pair file."""
    store = as_edge_store(source)
    path = os.fspath(path)
    with open(path, "wb") as f:
        for chunk in _chunks(store, chunk_rows):
            f.write(np.ascontiguousarray(chunk).tobytes())
    return path


def write_shards(
    directory,
    source,
    shard_edges: int,
    fmt: str = "npy",
    chunk_rows: int = DEFAULT_WRITE_CHUNK,
) -> list:
    """Split ``source`` into ``shard-NNNNN.{npy,bin}`` files of at most
    ``shard_edges`` rows each, plus a ``manifest.json`` declaring per-shard
    and total edge counts (verified on open — a shard lost or truncated
    after writing raises ``CorruptStoreError`` instead of streaming a
    silently shorter edge list); returns the shard paths."""
    if shard_edges < 1:
        raise EdgeStoreError(f"shard_edges must be positive, got {shard_edges}")
    store = as_edge_store(source)
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    writer = {"npy": write_npy, "bin": write_bin}[fmt]
    paths = []
    entries = []
    n_shards = max(1, -(-store.n_edges // shard_edges))
    for i in range(n_shards):
        view = _StoreSlice(store, i * shard_edges, shard_edges)
        paths.append(writer(directory / f"shard-{i:05d}.{fmt}", view, chunk_rows))
        entries.append({"file": f"shard-{i:05d}.{fmt}", "edges": view.n_edges})
    with open(directory / MANIFEST_NAME, "w") as f:
        json.dump({"total_edges": store.n_edges, "shards": entries}, f, indent=2)
    return paths


class _StoreSlice(EdgeStore):
    """Zero-copy row-range view of another store (shard writer plumbing)."""

    def __init__(self, store: EdgeStore, start: int, count: int):
        self.store = store
        self.start = start
        self.n_edges = max(0, min(count, store.n_edges - start))

    def read_into(self, start: int, out: np.ndarray) -> int:
        k = max(0, min(len(out), self.n_edges - start))
        return self.store.read_into(self.start + start, out[:k])


# ---------------------------------------------------------------- converter


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.data.edge_store",
        description="Inspect and convert on-disk edge stores.",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    info = sub.add_parser("info", help="print a store's shape and layout")
    info.add_argument("path")

    conv = sub.add_parser(
        "convert", help="convert between npy / bin / sharded edge stores"
    )
    conv.add_argument("src", help="input: .npy, raw .bin, or shard directory")
    conv.add_argument("dst", help="output file (or directory for shards)")
    conv.add_argument(
        "--format",
        choices=("npy", "bin", "shards"),
        default=None,
        help="output format (default: from dst extension)",
    )
    conv.add_argument(
        "--shard-edges",
        type=int,
        default=1 << 20,
        help="rows per shard when --format shards",
    )
    conv.add_argument(
        "--chunk-rows",
        type=int,
        default=DEFAULT_WRITE_CHUNK,
        help="copy-buffer rows (host footprint of the conversion)",
    )
    args = ap.parse_args(argv)

    if args.cmd == "info":
        store = open_edge_store(args.path)
        kind = type(store).__name__
        print(f"{args.path}: {store.n_edges} edges ({kind})")
        print(f"bytes on disk ≈ {store.n_edges * ROW_BYTES:,}")
        print(f"host resident bytes = {store.resident_bytes:,}")
        if isinstance(store, ShardedEdgeStore):
            for s, e in zip(store.stores, np.diff(store.offsets)):
                print(f"  shard {getattr(s, 'path', '?')}: {int(e)} edges")
        return

    fmt = args.format or ("npy" if args.dst.endswith(".npy") else "bin")
    store = open_edge_store(args.src)
    if fmt == "shards":
        paths = write_shards(
            args.dst, store, args.shard_edges, chunk_rows=args.chunk_rows
        )
        print(f"wrote {len(paths)} shards under {args.dst}")
    elif fmt == "npy":
        print("wrote", write_npy(args.dst, store, chunk_rows=args.chunk_rows))
    else:
        print("wrote", write_bin(args.dst, store, chunk_rows=args.chunk_rows))


if __name__ == "__main__":
    main()
