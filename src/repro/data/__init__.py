"""Data layer: synthetic batch pipelines (``pipeline``) and out-of-core
edge stores for the streaming engine (``edge_store``).

Re-exports from ``edge_store`` are lazy (PEP 562) so running the converter
CLI as ``python -m repro.data.edge_store`` does not import the module twice.
"""
import importlib

__all__ = [
    "EDGE_DTYPE",
    "BinEdgeStore",
    "EdgeStore",
    "EdgeStoreError",
    "InMemoryEdgeStore",
    "NpyEdgeStore",
    "ShardedEdgeStore",
    "as_edge_store",
    "open_edge_store",
    "write_bin",
    "write_npy",
    "write_shards",
]


def __getattr__(name):
    if name in __all__:
        return getattr(importlib.import_module("repro.data.edge_store"), name)
    raise AttributeError(f"module 'repro.data' has no attribute '{name}'")
