"""Serving subsystem: the interactive tile-pyramid layout service
(``tiles`` — pan/zoom over a computed ``BGVResult`` with an LRU tile
cache and slot-batched re-renders) and the continuous-batching LM decode
engine the tick design came from (``engine``).

Imports are lazy (PEP 562): ``repro.serve.TileEngine`` pulls only the
tile service; the LM engine's transformer stack loads only when asked
for.
"""
import importlib

_EXPORTS = {
    "DrillSpec": "repro.serve.tiles",
    "LMEngine": "repro.serve.engine",
    "Request": "repro.serve.engine",
    "TileCache": "repro.serve.tiles",
    "TileConfig": "repro.serve.tiles",
    "TileEngine": "repro.serve.tiles",
    "TilePyramid": "repro.serve.tiles",
    "TileRequest": "repro.serve.tiles",
    "TileSpec": "repro.serve.tiles",
    "community_subgraph": "repro.serve.tiles",
    "jit_compile_count": "repro.serve.tiles",
    "synthetic_trace": "repro.serve.tiles",
}
__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro.serve' has no attribute '{name}'")


def __dir__():
    return sorted(set(globals()) | set(__all__))
