"""Interactive tile-pyramid layout service over a computed ``BGVResult``.

The batch pipeline ends at one PNG; exploring a massive graph needs pan
and zoom. This module turns a finished layout into a cacheable surface
(the ROADMAP's "layout-as-a-service" item):

* ``TilePyramid`` — multi-resolution tile addressing over the supergraph
  drawing. The alive supernodes' square world bounding box is level 0
  (one tile); level ``z`` splits it into ``2^z × 2^z`` tiles, each
  rendered through the streaming rasterizer (``repro.render``) with a
  fixed ``RenderConfig.viewport``, so adjacent tiles clip splats at the
  shared pixel edge and tile the scene seamlessly. Every tile of every
  level renders with the same array shapes and jit static arguments —
  the render step compiles during warm-up and never again
  (``jit_compile_count`` is the recompile meter benchmarks gate on).
* **drill-down** (GMine's hierarchical model, PAPERS.md) — at high zoom
  a ``DrillSpec(community)`` request expands one community's *internal*
  structure: the induced subgraph of its member nodes is laid out and
  recolored by ``full_layout_colored`` (sub-communities re-detected
  inside the community) and rendered to a fixed-size tile.
* ``TileEngine`` — the serving loop, modeled on ``serve/engine.py``'s
  batched-tick design: requests are served from a byte-capped LRU
  ``TileCache`` on hit, and queued misses are rendered in slot-capped
  batches per ``tick()`` (fixed tile shapes keep every tick on already
  compiled code).
* ``synthetic_trace`` — the zipfian pan/zoom traffic model shared by
  ``benchmarks/serve_bench.py`` and ``launch/serve.py``.

Bit-exactness contract: a served pyramid tile equals a direct one-shot
``render_arrays`` of the same viewport, and a served drill tile equals a
direct ``full_layout_colored`` + fitted render of the same community
(tests/test_tiles.py; ``serve_bench --check`` re-verifies on live
traffic). Persistent compilation caching for the service start path is
``repro.kernels.compat.enable_persistent_compilation_cache``.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.pipeline import BGVConfig, BGVResult, full_layout_colored
from repro.data.edge_store import as_edge_store
from repro.obs.metrics import REGISTRY, ensure_error_counters
from repro.obs.trace import get_tracer

# The recompile meter lives in repro.obs.meters now (idempotent listener
# registration, shared jax.compiles counter); this import keeps the
# historical `from repro.serve.tiles import jit_compile_count` path — and
# the `repro.serve` lazy export resolving through it — working.
from repro.obs.meters import jit_compile_count  # noqa: F401
from repro.render import RenderConfig, render_arrays

# ---------------------------------------------------------------------------
# Tile addressing


@dataclass(frozen=True)
class TileSpec:
    """Pyramid tile address: ``level`` ∈ [0, depth), ``x``/``y`` ∈
    [0, 2^level) with ``y`` counted from the top (max world y) row."""

    level: int
    x: int
    y: int


@dataclass(frozen=True)
class DrillSpec:
    """Drill-down tile address: one community's internal layout."""

    community: int


@dataclass(frozen=True)
class TileConfig:
    """Pyramid/tile knobs. ``tile_size`` is the square output resolution
    per tile; ``depth`` is the number of precomputable pyramid levels
    (level 0 .. depth-1); ``margin`` pads the world bounding box so
    boundary disks aren't cut at level 0. ``supersample``/``edge_samples``/
    ``backend`` pass through to ``RenderConfig``. ``drill_iterations`` is
    the FA2 iteration *cap* of a drill-down's internal layout and
    ``drill_node_radius`` its (world-unit) dot size.

    ``drill_stop_tolerance``/``drill_min_iterations`` enable FA2's
    adaptive stop for drill layouts (core/forceatlas2.py): a drill miss
    is the service's worst-case latency, and freezing the scan once
    global swing stabilizes cuts it without a quality cliff
    (benchmarks/quality_bench.py gates the equal-quality claim). The
    defaults keep the legacy fixed-iteration behavior (tolerance 0)."""

    tile_size: int = 256
    depth: int = 3
    margin: float = 0.05
    supersample: int = 1
    edge_samples: int = 8
    backend: str = "auto"
    drill_iterations: int = 60
    drill_node_radius: float = 2.0
    drill_stop_tolerance: float = 0.0
    drill_min_iterations: int = 0


# ---------------------------------------------------------------------------
# LRU tile cache


class TileCache:
    """Byte-capped LRU cache of rendered tiles.

    ``get`` counts a hit (and freshens recency) or a miss; ``put``
    inserts/replaces and evicts least-recently-used entries until the
    byte total fits ``capacity_bytes`` (a tile larger than the whole
    capacity is dropped immediately — capacity 0 caches nothing).
    Accounting: ``hits``/``misses``/``evictions``/``bytes``.
    """

    def __init__(self, capacity_bytes: int):
        self.capacity_bytes = int(capacity_bytes)
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:  # stats-neutral membership probe
        return key in self._entries

    def keys(self):
        """Keys in eviction order (least recently used first)."""
        return list(self._entries)

    def get(self, key):
        tile = self._entries.get(key)
        if tile is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return tile

    def put(self, key, tile: np.ndarray) -> None:
        if key in self._entries:
            self.bytes -= self._entries[key].nbytes
            del self._entries[key]
        self._entries[key] = tile
        self.bytes += tile.nbytes
        while self.bytes > self.capacity_bytes and self._entries:
            _, evicted = self._entries.popitem(last=False)
            self.bytes -= evicted.nbytes
            self.evictions += 1

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


# ---------------------------------------------------------------------------
# Drill-down helpers (pure functions — the bit-identity tests re-derive
# their outputs independently)


def community_members(labels: np.ndarray, community: int) -> np.ndarray:
    """Node ids whose dense community label equals ``community``."""
    return np.nonzero(np.asarray(labels) == community)[0].astype(np.int32)


def community_subgraph(
    edges: np.ndarray, labels: np.ndarray, community: int
) -> tuple[np.ndarray, np.ndarray]:
    """Induced internal subgraph of one community.

    Returns ``(sub_edges [k, 2] int32, members [m] int32)`` with edge
    endpoints remapped to member-local ids ``[0, m)`` preserving member
    order — the input to a drill-down ``full_layout_colored``.
    """
    edges = np.asarray(edges)
    labels = np.asarray(labels)
    members = community_members(labels, community)
    internal = (labels[edges[:, 0]] == community) & (
        labels[edges[:, 1]] == community
    )
    remap = np.full(len(labels), -1, np.int32)
    remap[members] = np.arange(len(members), dtype=np.int32)
    return remap[edges[internal]], members


# ---------------------------------------------------------------------------
# Tile pyramid


class TilePyramid:
    """Multi-resolution tile addressing + rendering over a ``BGVResult``.

    ``source`` (any ``repro.data.edge_store`` edge source) and ``bgv_cfg``
    enable drill-down tiles; without them only pyramid (supergraph) tiles
    are renderable. The supergraph scene arrays are materialized once at
    construction, so every ``render_tile`` call reuses identical shapes.
    """

    def __init__(
        self,
        result: BGVResult,
        cfg: TileConfig | None = None,
        *,
        source=None,
        bgv_cfg: BGVConfig | None = None,
    ):
        self.result = result
        self.cfg = cfg or TileConfig()
        sizes = np.maximum(np.asarray(result.sizes, np.float32), 0.0)
        self._radii = np.sqrt(sizes)  # paper §4.1: radius ∝ √size
        self._positions = np.asarray(result.positions, np.float32)
        self._groups = np.asarray(result.groups, np.int32)
        sg = result.supergraph
        self._sg_edges = None if sg is None else np.asarray(sg.edges)
        self._sg_weights = None if sg is None else np.asarray(sg.weights)
        self.bounds = self._square_bounds()
        self.bgv_cfg = bgv_cfg
        self._edges_np = None
        if source is not None:
            store = as_edge_store(source)
            self._edges_np = np.asarray(store.read(0, store.n_edges))
        self._drillable = None

    def _square_bounds(self) -> tuple[float, float, float, float]:
        """Square world bbox of the alive supernodes, padded by ``margin``
        per side — the level-0 viewport every level subdivides."""
        alive = self._radii > 0
        p = self._positions[alive] if alive.any() else self._positions
        lo = p.min(axis=0).astype(np.float64)
        hi = p.max(axis=0).astype(np.float64)
        cx, cy = (lo + hi) / 2.0
        half = float(max(np.max(hi - lo) / 2.0, 1e-6))
        half *= 1.0 + 2.0 * self.cfg.margin
        return (cx - half, cy - half, cx + half, cy + half)

    # -- addressing ---------------------------------------------------------

    @staticmethod
    def n_tiles(level: int) -> int:
        """Tiles per axis at ``level`` (the level is ``n × n`` tiles)."""
        return 1 << level

    def specs(self, levels=None):
        """Every ``TileSpec`` of the given levels (default: all
        ``cfg.depth`` levels), level-major."""
        for level in levels if levels is not None else range(self.cfg.depth):
            n = self.n_tiles(level)
            for y in range(n):
                for x in range(n):
                    yield TileSpec(level, x, y)

    def tile_viewport(self, level: int, x: int, y: int):
        """World rect ``(x0, y0, x1, y1)`` of tile ``(level, x, y)``;
        ``y`` counts from the top row (world y-up, raster y-down)."""
        n = self.n_tiles(level)
        if not (0 <= x < n and 0 <= y < n):
            raise ValueError(f"tile ({x}, {y}) outside level {level} (n={n})")
        bx0, by0, _bx1, by1 = self.bounds
        w = (self.bounds[2] - bx0) / n
        return (bx0 + x * w, by1 - (y + 1) * w, bx0 + (x + 1) * w, by1 - y * w)

    # -- rendering ----------------------------------------------------------

    def render_config(self, spec: TileSpec) -> RenderConfig:
        """The exact ``RenderConfig`` a direct one-shot ``render_arrays``
        of this tile's viewport would use — the bit-identity oracle."""
        c = self.cfg
        return RenderConfig(
            width=c.tile_size,
            height=c.tile_size,
            supersample=c.supersample,
            edge_samples=c.edge_samples,
            backend=c.backend,
            viewport=self.tile_viewport(spec.level, spec.x, spec.y),
        )

    def render_tile(self, spec) -> np.ndarray:
        """Render one tile (pyramid or drill) → [tile, tile, 3] uint8."""
        if isinstance(spec, TileSpec):
            with get_tracer().span(
                "serve.render_tile", level=spec.level, x=spec.x, y=spec.y
            ):
                img, _ = render_arrays(
                    self._positions,
                    self._radii,
                    self._groups,
                    self._sg_edges,
                    edge_weights=self._sg_weights,
                    cfg=self.render_config(spec),
                )
            return img
        if isinstance(spec, DrillSpec):
            with get_tracer().span(
                "serve.render_drill", community=spec.community
            ):
                return self._render_drill(spec.community)
        raise TypeError(f"unknown tile spec {spec!r}")

    def _render_drill(self, community: int) -> np.ndarray:
        """GMine-style drill-down: lay out + recolor the community's
        internal subgraph (``full_layout_colored`` re-runs detection inside
        it) and render to a fitted fixed-size tile."""
        if self._edges_np is None or self.bgv_cfg is None:
            raise RuntimeError(
                "drill-down needs TilePyramid(source=..., bgv_cfg=...): the "
                "supergraph result alone has no member edges to expand"
            )
        sub_edges, members = community_subgraph(
            self._edges_np, self.result.labels, community
        )
        if len(members) < 2 or len(sub_edges) == 0:
            raise ValueError(
                f"community {community} has {len(members)} members and "
                f"{len(sub_edges)} internal edges — nothing to drill into"
            )
        c = self.cfg
        pos, groups = full_layout_colored(
            sub_edges, len(members), self.bgv_cfg,
            iterations=c.drill_iterations,
            stop_tolerance=c.drill_stop_tolerance,
            min_iterations=c.drill_min_iterations,
        )
        img, _ = render_arrays(
            pos,
            np.full(len(members), c.drill_node_radius, np.float32),
            groups,
            sub_edges,
            cfg=RenderConfig(
                width=c.tile_size,
                height=c.tile_size,
                supersample=c.supersample,
                edge_samples=c.edge_samples,
                backend=c.backend,
            ),
        )
        return img

    def drillable_communities(self, min_members: int = 2) -> np.ndarray:
        """Community ids with ≥ ``min_members`` members and ≥ 1 internal
        edge, largest first — the valid ``DrillSpec`` targets."""
        if self._edges_np is None:
            return np.empty(0, np.int32)
        if self._drillable is None:
            labels = np.asarray(self.result.labels)
            s = len(self.result.sizes)
            counts = np.bincount(labels[labels >= 0], minlength=s)[:s]
            lu = labels[self._edges_np[:, 0]]
            lv = labels[self._edges_np[:, 1]]
            internal = np.bincount(
                lu[(lu == lv) & (lu >= 0)], minlength=s
            )[:s]
            ids = np.nonzero((counts >= max(min_members, 2)) & (internal > 0))[0]
            self._drillable = ids[np.argsort(-counts[ids], kind="stable")]
        return self._drillable.astype(np.int32)


# ---------------------------------------------------------------------------
# Serving engine


def error_tile(size: int) -> np.ndarray:
    """The degraded-service tile: a dark field with a bright diagonal
    cross — visually unmistakable, never cached, returned when a render
    fails or a queued miss is shed past the deadline (ISSUE 10: a bad
    tile must not take down the service or poison the cache)."""
    img = np.zeros((size, size, 3), np.uint8)
    img[..., 0] = 40
    d = np.arange(size)
    img[d, d] = (255, 64, 64)
    img[d, size - 1 - d] = (255, 64, 64)
    return img


@dataclass
class TileRequest:
    """One pan/zoom request: a tile address in, a rendered tile out.
    ``hit`` records whether the cache served it without a render;
    ``latency_s`` is submit → completion; ``failed`` marks a degraded
    completion (error tile from a failed render or a shed request)."""

    spec: TileSpec | DrillSpec
    tile: np.ndarray | None = None
    done: bool = False
    hit: bool = False
    failed: bool = False
    latency_s: float = 0.0
    _t0: float = field(default=0.0, repr=False)


class TileEngine:
    """Pan/zoom tile server: LRU cache in front of slot-batched re-renders.

    Mirrors ``serve/engine.py``'s continuous-batching shape: ``submit``
    attaches a request (cache hits complete immediately), ``tick`` takes
    up to ``slots`` *distinct* queued tile addresses, renders them — every
    render hits the already-compiled fixed-shape jit entries, so ticks
    never recompile in steady state — and completes all requests waiting
    on those tiles (duplicates collapse into one render).
    """

    def __init__(self, pyramid: TilePyramid, cache_bytes: int = 256 << 20,
                 slots: int = 8, deadline_s: float | None = None):
        if slots < 1:
            raise ValueError(f"slots must be positive, got {slots}")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, got {deadline_s}")
        self.pyramid = pyramid
        self.cache = TileCache(cache_bytes)
        self.slots = slots
        self.deadline_s = deadline_s
        self._pending: deque[TileRequest] = deque()
        self.ticks = 0
        self.served = 0
        self.rendered = 0
        self.failed = 0
        self.shed = 0
        self.render_s = 0.0
        ensure_error_counters()

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def _complete(self, req: TileRequest, tile: np.ndarray, hit: bool,
                  failed: bool = False) -> None:
        req.tile = tile
        req.hit = hit
        req.failed = failed
        req.done = True
        req.latency_s = time.perf_counter() - req._t0
        self.served += 1
        REGISTRY.histogram("serve.latency_s").record(req.latency_s)
        if not hit:
            REGISTRY.histogram("serve.miss_latency_s").record(req.latency_s)

    def submit(self, req: TileRequest) -> bool:
        """Attach a request. Cache hits complete before returning; misses
        queue for the next ``tick``. Always accepts (returns True — the
        slot cap bounds per-tick render work, not the backlog)."""
        REGISTRY.counter("serve.requests").inc()
        req._t0 = time.perf_counter()
        tile = self.cache.get(req.spec)
        if tile is not None:
            self._complete(req, tile, hit=True)
        else:
            self._pending.append(req)
        return True

    def publish_cache_metrics(self, registry=None) -> None:
        """Mirror the LRU cache accounting into ``serve.cache_*`` gauges
        (last-value snapshots; called per tick and safe to call anytime)."""
        reg = registry if registry is not None else REGISTRY
        reg.gauge("serve.cache_bytes").set(self.cache.bytes)
        reg.gauge("serve.cache_tiles").set(len(self.cache))
        reg.gauge("serve.cache_hits").set(self.cache.hits)
        reg.gauge("serve.cache_misses").set(self.cache.misses)
        reg.gauge("serve.cache_evictions").set(self.cache.evictions)
        reg.gauge("serve.cache_hit_rate").set(self.cache.hit_rate)

    def _shed_overdue(self, done: list[TileRequest]) -> None:
        """Load-shed queued misses older than ``deadline_s``: complete
        them with an error tile instead of letting an ever-growing
        backlog starve fresh requests. Sheds from the front (oldest)."""
        if self.deadline_s is None or not self._pending:
            return
        now = time.perf_counter()
        remaining: deque[TileRequest] = deque()
        for req in self._pending:
            if now - req._t0 > self.deadline_s:
                self.shed += 1
                REGISTRY.counter("errors.shed_tiles").inc()
                self._complete(req, error_tile(self.pyramid.cfg.tile_size),
                               hit=False, failed=True)
                done.append(req)
            else:
                remaining.append(req)
        self._pending = remaining

    def tick(self) -> list[TileRequest]:
        """Render up to ``slots`` distinct pending tile addresses and
        complete every request waiting on them; returns completions.

        Degradation policy (ISSUE 10): a render that raises is isolated
        to its own spec — waiters get an ``error_tile`` with
        ``failed=True`` and the error tile is *never* cached, so a
        transient failure retries on the next request instead of
        poisoning the cache. With ``deadline_s`` set, overdue queued
        misses are shed the same way before any render work."""
        done: list[TileRequest] = []
        self._shed_overdue(done)
        if not self._pending:
            return done
        self.ticks += 1
        batch: list = []
        for req in self._pending:
            if req.spec not in batch:
                batch.append(req.spec)
                if len(batch) >= self.slots:
                    break
        t0 = time.perf_counter()
        tiles: dict = {}
        broken: set = set()
        with get_tracer().span("serve.tick", batch=len(batch)):
            for spec in batch:
                try:
                    tiles[spec] = self.pyramid.render_tile(spec)
                except Exception:
                    broken.add(spec)
                    self.failed += 1
                    REGISTRY.counter("errors.failed_tiles").inc()
        tick_s = time.perf_counter() - t0
        self.render_s += tick_s
        self.rendered += len(tiles)
        REGISTRY.histogram("serve.tick_render_s").record(tick_s)
        for spec, tile in tiles.items():
            self.cache.put(spec, tile)
        remaining = deque()
        for req in self._pending:
            if req.spec in tiles:
                self._complete(req, tiles[req.spec], hit=False)
                done.append(req)
            elif req.spec in broken:
                self._complete(req, error_tile(self.pyramid.cfg.tile_size),
                               hit=False, failed=True)
                done.append(req)
            else:
                remaining.append(req)
        self._pending = remaining
        self.publish_cache_metrics()
        return done

    def request(self, spec) -> np.ndarray:
        """Synchronous convenience: submit one address and tick to
        completion. Returns the tile image."""
        req = TileRequest(spec)
        self.submit(req)
        while not req.done:
            self.tick()
        return req.tile

    def warmup(self, levels=None, drills=()) -> int:
        """Precompute pyramid tiles (default: all ``depth`` levels) and the
        given drill-down communities straight into the cache. This is the
        service's compile warm-up too: pyramid tiles share one fixed-shape
        jit entry set, and each drill's subgraph shapes compile on first
        render — after a warm-up covering the serving mix, steady-state
        ticks recompile nothing. Returns tiles rendered."""
        n = 0
        specs = list(self.pyramid.specs(levels))
        specs += [DrillSpec(int(c)) for c in drills]
        with get_tracer().span("serve.warmup", tiles=len(specs)):
            for spec in specs:
                if spec not in self.cache:
                    t0 = time.perf_counter()
                    self.cache.put(spec, self.pyramid.render_tile(spec))
                    self.render_s += time.perf_counter() - t0
                    self.rendered += 1
                    n += 1
        self.publish_cache_metrics()
        return n


# ---------------------------------------------------------------------------
# Synthetic traffic


def synthetic_trace(
    pyramid: TilePyramid,
    n_requests: int,
    *,
    zipf_a: float = 1.1,
    pan_p: float = 0.45,
    zoom_p: float = 0.2,
    drill_frac: float = 0.05,
    drill_pool: int = 8,
    seed: int = 0,
) -> list:
    """Zipfian pan/zoom request trace over a pyramid — the traffic model
    behind ``benchmarks/serve_bench.py`` and ``launch/serve.py``.

    A session walks the pyramid: with probability ``pan_p`` the next
    request pans to a neighboring tile of the current level, with
    ``zoom_p`` it zooms one level in/out (coordinates re-anchored so the
    view stays over the same world region), with ``drill_frac`` it drills
    into one of the ``drill_pool`` largest drillable communities
    (zipf-weighted), and otherwise it jumps to a fresh tile drawn from a
    zipf(``zipf_a``) popularity ranking over all tiles (low-zoom tiles
    rank hottest, matching real tile-server skew). Deterministic in
    ``seed``; returns a list of ``TileSpec``/``DrillSpec``.
    """
    rng = np.random.default_rng(seed)
    specs = list(pyramid.specs())
    ranks = np.arange(1, len(specs) + 1, dtype=np.float64)
    popularity = ranks ** -float(zipf_a)
    popularity /= popularity.sum()
    drills = pyramid.drillable_communities()[:drill_pool]
    if len(drills):
        dranks = np.arange(1, len(drills) + 1, dtype=np.float64)
        dpop = dranks ** -float(zipf_a)
        dpop /= dpop.sum()
    trace: list = []
    cur = specs[0]
    for _ in range(n_requests):
        r = rng.random()
        if r < drill_frac and len(drills):
            trace.append(DrillSpec(int(rng.choice(drills, p=dpop))))
            continue  # drill is a detour; the pan/zoom session resumes
        if r < drill_frac + pan_p:
            n = pyramid.n_tiles(cur.level)
            dx, dy = rng.integers(-1, 2, size=2)
            cur = TileSpec(
                cur.level,
                int(np.clip(cur.x + dx, 0, n - 1)),
                int(np.clip(cur.y + dy, 0, n - 1)),
            )
        elif r < drill_frac + pan_p + zoom_p:
            if cur.level + 1 < pyramid.cfg.depth and rng.random() < 0.5:
                cur = TileSpec(
                    cur.level + 1,
                    int(2 * cur.x + rng.integers(0, 2)),
                    int(2 * cur.y + rng.integers(0, 2)),
                )
            elif cur.level > 0:
                cur = TileSpec(cur.level - 1, cur.x // 2, cur.y // 2)
        else:
            cur = specs[int(rng.choice(len(specs), p=popularity))]
        trace.append(cur)
    return trace
