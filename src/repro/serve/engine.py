"""Batched serving engine: continuous-batching LM decode over a shared KV
cache + SASRec scoring service.

The LM engine keeps a fixed slot pool (batch dimension); requests attach to
free slots, prefill writes their prompt KV, and a single jitted decode step
advances every live slot per tick (continuous batching — new requests join
between ticks without recompilation). Greedy sampling keeps the engine
deterministic for tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm


@dataclass
class Request:
    prompt: np.ndarray  # [P] int32
    max_new: int
    out: list = field(default_factory=list)
    slot: int = -1
    pos: int = 0
    done: bool = False


class LMEngine:
    def __init__(self, cfg: tfm.LMConfig, params, n_slots: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        shape = (cfg.n_layers, n_slots, max_len, cfg.n_kv_heads, cfg.head_dim)
        self.cache = {
            "k": jnp.zeros(shape, cfg.act_dtype),
            "v": jnp.zeros(shape, cfg.act_dtype),
        }
        self._decode = jax.jit(tfm.make_decode_step(cfg))
        self._free = list(range(n_slots))
        self._live: dict[int, Request] = {}
        # per-slot current length (host-side; decode uses the max — slots
        # padded with pos masking via kv_valid_len)
        self._pos = np.zeros(n_slots, np.int32)

    def submit(self, req: Request) -> bool:
        if not self._free:
            return False
        req.slot = self._free.pop()
        # prefill: feed all but the LAST prompt token through the decode
        # step (the last one is fed by the first tick, whose logits produce
        # the first generated token — feeding it here would double-count it)
        for t in req.prompt[:-1]:
            self._step_token(req.slot, int(t))
        req.pos = len(req.prompt) - 1
        self._live[req.slot] = req
        return True

    def _step_token(self, slot: int, token: int) -> int:
        tokens = jnp.zeros((self.n_slots, 1), jnp.int32).at[slot, 0].set(token)
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": tokens, "cur_len": jnp.int32(int(self._pos[slot]))},
        )
        self._pos[slot] += 1
        nxt = int(jnp.argmax(logits[slot, 0, : self.cfg.vocab]))
        return nxt

    def tick(self) -> list[Request]:
        """Advance every live request one token; return completions."""
        finished = []
        for slot, req in list(self._live.items()):
            last = req.prompt[-1] if not req.out else req.out[-1]
            nxt = self._step_token(slot, int(last))
            req.out.append(nxt)
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                del self._live[slot]
                self._free.append(slot)
                self._pos[slot] = 0
                # zero the slot's cache lines for the next tenant
                self.cache = {
                    k: v.at[:, slot].set(0.0) for k, v in self.cache.items()
                }
        return finished

    @property
    def n_live(self) -> int:
        return len(self._live)
