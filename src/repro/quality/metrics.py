"""Layout-quality metrics that scale: sampling, not all-pairs.

Every classical layout metric (stress, neighborhood preservation,
crossings) is O(n²) or worse when computed exactly — useless at the
paper's millions of nodes. This module keeps each one subquadratic:

  * ``sampled_stress`` — pivot-based normalized stress: P BFS passes give
    graph distances from P pivots to everyone (O(P·E)), a closed-form
    optimal scale α aligns the layout to those distances, and the result
    is the mean squared relative error in [0, 1] (0 = distances perfectly
    realized, 1 = what the degenerate all-points-coincident layout gets).
  * ``neighborhood_preservation`` — for S sampled nodes: the k-ring graph
    neighborhood (≤ ``ring`` hops, capped at ``k_cap``) vs the spatial
    k-nearest neighbors of the layout, where the spatial candidates come
    from the same uniform-grid binning FA2's repulsion uses
    (kernels/grid ``bin_and_sort``): candidates are a ±``band`` window in
    cell-sorted order — locality-approximate, but identical across the
    layouts being compared, which is what a ratio gate needs. Returns the
    mean Jaccard-style overlap |graph ∩ spatial-kNN| / k in [0, 1].
  * ``edge_length_cv`` — coefficient of variation of edge lengths (lower
    = more uniform; aesthetic-uniformity proxy).
  * ``crossing_proxy`` — fraction of sampled edge pairs (disjoint
    endpoints) whose segments properly intersect; an unbiased estimate of
    crossing density at O(samples) cost.

All functions take host numpy arrays: ``pos`` [n, 2] float, ``edges``
[e, 2] int int (unpadded — no trash endpoints). Sampling is seeded and
deterministic; comparisons must reuse one seed across layouts.
"""
from __future__ import annotations

import numpy as np


def _csr(edges: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
    """Undirected CSR adjacency (indptr [n+1], indices [2e]), self-loops
    and duplicate edges kept as given (they only re-weight neighbors)."""
    edges = np.asarray(edges, np.int64)
    src = np.concatenate([edges[:, 0], edges[:, 1]])
    dst = np.concatenate([edges[:, 1], edges[:, 0]])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, dst.astype(np.int32)


def _frontier_neighbors(indptr, indices, frontier):
    """All neighbors (with multiplicity) of the frontier nodes, vectorized."""
    counts = indptr[frontier + 1] - indptr[frontier]
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int32)
    starts = np.repeat(indptr[frontier], counts)
    offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
    return indices[starts + offs]


def bfs_hops(
    indptr: np.ndarray,
    indices: np.ndarray,
    source: int,
    n: int,
    max_hops: int | None = None,
) -> np.ndarray:
    """Hop distances from ``source`` ([n] int32, −1 = unreached), breadth
    first over the CSR adjacency; stops after ``max_hops`` levels."""
    dist = np.full(n, -1, np.int32)
    dist[source] = 0
    frontier = np.array([source], np.int64)
    d = 0
    while frontier.size and (max_hops is None or d < max_hops):
        d += 1
        nbr = _frontier_neighbors(indptr, indices, frontier)
        nbr = nbr[dist[nbr] < 0]
        if nbr.size == 0:
            break
        frontier = np.unique(nbr).astype(np.int64)
        dist[frontier] = d
    return dist


def sampled_stress(
    pos: np.ndarray,
    edges: np.ndarray,
    n: int,
    n_pivots: int = 16,
    seed: int = 0,
) -> float:
    """Pivot-sampled normalized stress in [0, 1] (lower is better).

    With graph distances δ from ``n_pivots`` BFS sources and layout
    distances e, the scale-optimal α = Σ(e/δ) / Σ(e²/δ²) minimizes
    Σ (αe − δ)²/δ², and the minimum divided by the pair count is the
    reported stress (δ-weighting makes it scale-free; α makes it
    invariant to the layout's arbitrary global scale).
    """
    pos = np.asarray(pos, np.float64)
    indptr, indices = _csr(edges, n)
    rng = np.random.default_rng(seed)
    pivots = rng.choice(n, size=min(n_pivots, n), replace=False)
    num = den = sq = 0.0
    count = 0
    for p in pivots:
        dist = bfs_hops(indptr, indices, int(p), n)
        reach = np.nonzero(dist > 0)[0]
        if reach.size == 0:
            continue
        delta = dist[reach].astype(np.float64)
        e = np.linalg.norm(pos[reach] - pos[int(p)], axis=1)
        num += float(np.sum(e / delta))
        den += float(np.sum((e / delta) ** 2))
        count += reach.size
    if count == 0 or den == 0.0:
        return 0.0
    alpha = num / den
    # Σ((αe − δ)/δ)² = α²·den − 2α·num + count, evaluated at the optimum.
    total = alpha * alpha * den - 2.0 * alpha * num + count
    return float(total / count)


def neighborhood_preservation(
    pos: np.ndarray,
    edges: np.ndarray,
    n: int,
    n_samples: int = 256,
    ring: int = 1,
    k_cap: int = 20,
    grid_size: int | None = None,
    band: int = 128,
    seed: int = 0,
) -> float:
    """Mean overlap between k-ring graph neighborhoods and spatial k-NN.

    For each sampled node i with graph neighborhood N_g(i) (nodes ≤
    ``ring`` hops away, truncated to the ``k_cap`` nearest-in-layout
    would bias toward the layout, so truncation is arbitrary-but-fixed:
    the first ``k_cap`` in node-id order), k = |N_g(i)|; the spatial side
    takes the k nearest layout neighbors of i among a ±``band`` window in
    kernels/grid cell-sorted order (the same binning FA2 repulsion uses).
    Scores |N_g ∩ kNN| / k, averaged over samples with k ≥ 1.

    ``grid_size=None`` sizes the grid so one cell holds ~64 nodes: the
    band window walks consecutive cell ids (one grid *column* strip), so
    cells must be coarse enough that a node's true spatial neighbors sit
    in its own/adjacent cells rather than in adjacent columns the strip
    never reaches.
    """
    from repro.kernels.grid.ref import bin_and_sort

    if grid_size is None:
        grid_size = max(4, int(np.sqrt(n / 64.0)))

    pos = np.asarray(pos, np.float64)
    indptr, indices = _csr(edges, n)
    rng = np.random.default_rng(seed)
    deg = (indptr[1:] - indptr[:-1]).astype(np.int64)
    eligible = np.nonzero(deg > 0)[0]
    if eligible.size == 0:
        return 0.0
    samples = rng.choice(
        eligible, size=min(n_samples, eligible.size), replace=False
    )

    cell, order = bin_and_sort(np.asarray(pos, np.float32), grid_size)
    order = np.asarray(order)
    rank = np.zeros(n, np.int64)
    rank[order] = np.arange(n)

    scores = []
    for i in samples:
        i = int(i)
        if ring == 1:
            nbrs = np.unique(indices[indptr[i]:indptr[i + 1]])
        else:
            dist = bfs_hops(indptr, indices, i, n, max_hops=ring)
            nbrs = np.nonzero(dist > 0)[0]
        nbrs = nbrs[nbrs != i][:k_cap]
        k = nbrs.size
        if k == 0:
            continue
        p = rank[i]
        lo, hi = max(0, p - band), min(n, p + band + 1)
        cand = order[lo:hi]
        cand = cand[cand != i]
        if cand.size == 0:
            scores.append(0.0)
            continue
        d = np.linalg.norm(pos[cand] - pos[i], axis=1)
        kk = min(k, cand.size)
        near = cand[np.argpartition(d, kk - 1)[:kk]]
        scores.append(np.intersect1d(near, nbrs).size / k)
    return float(np.mean(scores)) if scores else 0.0


def edge_length_cv(pos: np.ndarray, edges: np.ndarray) -> float:
    """Coefficient of variation (σ/μ) of edge lengths; 0 = all equal."""
    edges = np.asarray(edges, np.int64)
    if len(edges) == 0:
        return 0.0
    pos = np.asarray(pos, np.float64)
    lengths = np.linalg.norm(pos[edges[:, 0]] - pos[edges[:, 1]], axis=1)
    mean = float(lengths.mean())
    if mean == 0.0:
        return 0.0
    return float(lengths.std() / mean)


def crossing_proxy(
    pos: np.ndarray,
    edges: np.ndarray,
    n_pairs: int = 4096,
    seed: int = 0,
) -> float:
    """Fraction of sampled endpoint-disjoint edge pairs that properly
    cross (strict segment intersection, shared endpoints excluded)."""
    edges = np.asarray(edges, np.int64)
    e = len(edges)
    if e < 2:
        return 0.0
    rng = np.random.default_rng(seed)
    a = rng.integers(0, e, size=n_pairs)
    b = rng.integers(0, e, size=n_pairs)
    ok = a != b
    ea, eb = edges[a], edges[b]
    # Endpoint-disjoint pairs only: shared endpoints touch, never cross.
    for i in range(2):
        for j in range(2):
            ok &= ea[:, i] != eb[:, j]
    if not ok.any():
        return 0.0
    ea, eb = ea[ok], eb[ok]
    pos = np.asarray(pos, np.float64)
    p, q = pos[ea[:, 0]], pos[ea[:, 1]]
    r, s = pos[eb[:, 0]], pos[eb[:, 1]]

    def orient(o, x, y):
        return (x[:, 0] - o[:, 0]) * (y[:, 1] - o[:, 1]) - (
            x[:, 1] - o[:, 1]
        ) * (y[:, 0] - o[:, 0])

    d1, d2 = orient(p, q, r), orient(p, q, s)
    d3, d4 = orient(r, s, p), orient(r, s, q)
    cross = (d1 * d2 < 0) & (d3 * d4 < 0)
    return float(cross.mean())


def layout_quality(
    pos: np.ndarray,
    edges: np.ndarray,
    n: int,
    seed: int = 0,
    n_pivots: int = 16,
    n_samples: int = 256,
    ring: int = 1,
) -> dict:
    """All four metrics under one seed — the quality_bench record shape."""
    return {
        "stress": sampled_stress(pos, edges, n, n_pivots=n_pivots, seed=seed),
        "neighborhood": neighborhood_preservation(
            pos, edges, n, n_samples=n_samples, ring=ring, seed=seed
        ),
        "edge_cv": edge_length_cv(pos, edges),
        "crossing": crossing_proxy(pos, edges, seed=seed),
    }
