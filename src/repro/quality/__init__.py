"""Scalable layout-quality metrics (ROADMAP "Convergence engineering").

The harness that turns "converged in 150 instead of 500 iterations" into
a gated claim (benchmarks/quality_bench.py): sampled stress, k-ring
neighborhood preservation (spatial side via the kernels/grid binning),
and edge-length-uniformity / crossing proxies. See quality/metrics.py
for the definitions and sampling contracts.
"""
from repro.quality.metrics import (
    bfs_hops,
    crossing_proxy,
    edge_length_cv,
    layout_quality,
    neighborhood_preservation,
    sampled_stress,
)

__all__ = [
    "bfs_hops",
    "crossing_proxy",
    "edge_length_cv",
    "layout_quality",
    "neighborhood_preservation",
    "sampled_stress",
]
