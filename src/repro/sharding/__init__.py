from repro.sharding.rules import (
    PROFILES,
    spec_for,
    filter_spec,
    params_shardings,
    batch_sharding,
    row_chunk_spec,
    block_chunk_spec,
    linear_axis_index,
)
