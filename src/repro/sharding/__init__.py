from repro.sharding.rules import (
    PROFILES,
    spec_for,
    filter_spec,
    params_shardings,
    batch_sharding,
)
