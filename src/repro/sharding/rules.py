"""Logical-axis → mesh-axis rules (t5x/MaxText style).

Every ParamSpec carries logical axis names; a *profile* is an ordered map
logical-axis → mesh-axis (or tuple of mesh axes). ``spec_for`` resolves one
param: each dimension takes its mapped mesh axis unless (a) the axis is
already used by an earlier dimension of the same param, or (b) the dim
size is not divisible by the mesh-axis extent (XLA requires divisibility —
verified empirically, DESIGN.md §8). Rules therefore degrade gracefully:
granite's per-expert d_ff=512 simply stays unsharded after "expert" takes
the model axis.

Profiles (DESIGN.md §4):
  tp      — Megatron TP over "model" (heads/mlp/vocab/expert) + ZeRO-3-style
            param sharding of the d_model ("embed") dim over "data".
  fsdp    — gemma3 (8 heads < 16): weights sharded on embed→model and
            mlp/vocab→data; attention heads replicated.
  gnn     — replicated (small) params; nodes/edges sharded over all axes.
  recsys  — item table sharded on vocab→model; everything else replicated.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.param import is_spec

PROFILES: dict[str, dict[str, tuple[str, ...]]] = {
    "tp": {
        "heads": ("model",),
        "expert": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        # ZeRO-3-style param sharding of d_model over every DP axis: on the
        # multi-pod mesh this is 32-way (pod×data) — the 1T cells need it.
        "embed": ("pod", "data"),
    },
    "fsdp": {
        "embed": ("model",),
        "mlp": ("data",),
        "vocab": ("data",),
    },
    "gnn": {},
    "recsys": {
        "vocab": ("model",),
    },
}


def filter_spec(spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that don't exist on this mesh (e.g. "pod" on single-pod)."""
    names = set(mesh.axis_names)
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            out.append(kept if kept else None)
        else:
            out.append(entry if entry in names else None)
    return P(*out)


def spec_for(shape, axes, profile: dict, mesh: Mesh) -> P:
    used: set[str] = set()
    out = []
    for dim, ax in zip(shape, axes):
        mapped = profile.get(ax)
        if mapped is None:
            out.append(None)
            continue
        cand = tuple(a for a in mapped if a in mesh.axis_names and a not in used)
        extent = 1
        for a in cand:
            extent *= mesh.shape[a]
        if cand and extent > 1 and dim % extent == 0:
            out.append(cand if len(cand) > 1 else cand[0])
            used.update(cand)
        else:
            out.append(None)
    return P(*out)


def params_shardings(specs, profile_name: str, mesh: Mesh):
    """ParamSpec tree → NamedSharding tree."""
    profile = PROFILES[profile_name]

    def one(s):
        return NamedSharding(mesh, spec_for(s.shape, s.logical_axes, profile, mesh))

    return jax.tree_util.tree_map(one, specs, is_leaf=is_spec)


def shardings_for_axes(abstract_tree, axes_tree, profile_name: str, mesh: Mesh):
    """Same resolution for arbitrary (ShapeDtypeStruct, logical-axes) trees —
    used for optimizer state."""
    profile = PROFILES[profile_name]

    def one(a, ax):
        return NamedSharding(mesh, spec_for(a.shape, ax, profile, mesh))

    return jax.tree_util.tree_map(one, abstract_tree, axes_tree)


def batch_sharding(mesh: Mesh, *specs: P):
    """Helper: NamedShardings for batch pytrees, filtering missing axes."""
    return tuple(NamedSharding(mesh, filter_spec(s, mesh)) for s in specs)


# --------------------------------------------------------------------------
# Stream-chunk placements (core/stream.py sharded engine, launch/
# stream_runner.py). Chunks are edge buffers, not params, so they shard
# over EVERY mesh axis: a chunk row belongs to exactly one device.
# --------------------------------------------------------------------------


def row_chunk_spec(mesh: Mesh) -> P:
    """Row-shard an [C, 2] edge chunk over all mesh axes (contiguous rows
    per device — the supergraph/degree/modularity pass placement)."""
    return P(tuple(mesh.axis_names), None)


def block_chunk_spec(mesh: Mesh) -> P:
    """Shard a [B, block_size, 2] chunk view on the within-block axis, so
    every device owns the same slice of EVERY SCoDA block (the detect-pass
    placement — the block scan then runs in lockstep across devices with
    per-block all-reduces, preserving the sequential block order that
    bit-exactness requires)."""
    return P(None, tuple(mesh.axis_names), None)


def linear_axis_index(axis_names: tuple, axis_sizes: tuple):
    """Traced linearized device index inside a ``shard_map`` body, matching
    the row order of ``P(tuple(axis_names))`` sharding (row-major over the
    mesh axes, the same order ``lax.all_gather`` tiles shards in)."""
    idx = jax.lax.axis_index(axis_names[0])
    for name, size in zip(axis_names[1:], axis_sizes[1:]):
        idx = idx * size + jax.lax.axis_index(name)
    return idx
