"""BigGraphVis CLI — the paper's end-user driver.

    PYTHONPATH=src python -m repro.launch.layout --edges graph.txt \
        --out layout.svg [--rounds 4] [--iterations 100] [--threshold 0]

``--edges``: whitespace-separated "src dst" lines (SNAP format; '#'
comments ignored) or ``synthetic:<n>:<communities>`` for a generated
planted-partition graph. Writes the supergraph SVG + a CSV of
(community, size, x, y, color_group).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import biggraphvis, default_config, write_svg
from repro.graph import mode_degree, planted_partition
from repro.obs.cli import add_obs_args, obs_session


def load_edges(spec: str) -> tuple[np.ndarray, int]:
    if spec.startswith("synthetic:"):
        _, n, k = spec.split(":")
        edges, _ = planted_partition(int(n), int(k), 0.15, 0.001, seed=0)
        return edges, int(n)
    rows = []
    with open(spec) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(("#", "%")):
                continue
            a, b, *_ = line.split()
            rows.append((int(a), int(b)))
    edges = np.asarray(rows, dtype=np.int64)
    # compact node ids (SNAP ids are sparse)
    uniq, inv = np.unique(edges.ravel(), return_inverse=True)
    edges = inv.reshape(-1, 2).astype(np.int32)
    edges = edges[edges[:, 0] != edges[:, 1]]
    return edges, len(uniq)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--edges", required=True)
    ap.add_argument("--out", default="biggraphvis.svg")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--iterations", type=int, default=100)
    ap.add_argument("--threshold", type=int, default=0, help="0 = mode degree (paper)")
    ap.add_argument("--s-cap", type=int, default=65536)
    ap.add_argument("--repulsion", default="exact",
                    choices=("exact", "grid", "grid_pallas", "grid_dense"),
                    help="FA2 repulsion backend (core/forceatlas2.py matrix: "
                         "exact n² tiles for supergraphs, tiled grid for "
                         "full-graph scale)")
    ap.add_argument("--grid-size", type=int, default=64,
                    help="G for the grid backends (G×G cells)")
    ap.add_argument("--grid-window", type=int, default=32,
                    help="near-field band half-width of grid repulsion")
    ap.add_argument("--grid-rebuild", type=int, default=1,
                    help="re-bin/re-sort grid cells every k iterations "
                         "(1 = every iteration, exact semantics)")
    ap.add_argument("--stop-tolerance", type=float, default=0.0,
                    help="adaptive stop: freeze the layout scan once global "
                         "swing <= tol * traction (0 = fixed iterations)")
    ap.add_argument("--min-iterations", type=int, default=0,
                    help="never stop the layout before this many iterations")
    ap.add_argument("--init", default="random",
                    choices=("random", "degree", "bfs"),
                    help="FA2 initial positions: uniform random, degree-"
                         "ranked sunflower spiral, or BFS hop-distance rings")
    add_obs_args(ap)
    args = ap.parse_args()

    with obs_session(args):
        _run(args)


def _run(args) -> None:
    edges, n = load_edges(args.edges)
    delta = args.threshold or mode_degree(edges, n)
    print(f"graph: {n} nodes, {len(edges)} edges, δ={delta}", file=sys.stderr)

    cfg = default_config(n, len(edges), delta, rounds=args.rounds,
                         iterations=args.iterations,
                         s_cap=min(args.s_cap, n),
                         repulsion=args.repulsion, grid_size=args.grid_size,
                         grid_window=args.grid_window,
                         grid_rebuild=args.grid_rebuild,
                         stop_tolerance=args.stop_tolerance,
                         min_iterations=args.min_iterations,
                         init=args.init)
    t0 = time.perf_counter()
    res = biggraphvis(edges, n, cfg)
    print(f"BigGraphVis: {res.n_supernodes} supernodes / {res.n_superedges} "
          f"superedges, modularity {res.modularity:.3f}, "
          f"layout ran {res.timings['layout_iterations']}/"
          f"{cfg.layout.iterations} iterations, "
          f"{time.perf_counter()-t0:.1f}s", file=sys.stderr)

    live = res.sizes > 0
    # write_svg delegates >max_nodes inputs to the rasterizer as a .png —
    # report the path it actually wrote.
    drawn = write_svg(args.out, res.positions[live],
                      np.sqrt(np.maximum(res.sizes[live], 1.0)),
                      res.groups[live])
    csv = args.out.rsplit(".", 1)[0] + ".csv"
    with open(csv, "w") as f:
        f.write("community,size,x,y,color_group\n")
        for i in np.nonzero(live)[0]:
            f.write(f"{i},{res.sizes[i]:.0f},{res.positions[i,0]:.2f},"
                    f"{res.positions[i,1]:.2f},{res.groups[i]}\n")
    print(f"wrote {drawn} + {csv}", file=sys.stderr)


if __name__ == "__main__":
    main()
