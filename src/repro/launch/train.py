"""Training driver: real steps on the current backend (CPU smoke scale or
TPU full scale), with checkpoint/restart, preemption handling, straggler
watchdog, and deterministic data sharding.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --preset smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

``--preset smoke`` swaps in the reduced same-family config (CPU-sized);
``--preset full`` uses the assigned config (needs a real pod).
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.gnn_archs import smoke_gnn
from repro.configs.lm_archs import smoke_lm
from repro.configs.sasrec import smoke_sasrec
from repro.data.pipeline import LMStream, MoleculeStream, SASRecStream
from repro.models import gnn as gnn_lib
from repro.models import sasrec as sas_lib
from repro.models import transformer as tfm
from repro.models.param import init_params
from repro.train.fault_tolerance import CheckpointManager
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import StepWatchdog, TrainConfig, make_train_step


def build_smoke(arch_name: str, batch: int, seq: int):
    arch = get_config(arch_name)
    if arch.family == "lm":
        cfg = smoke_lm(moe=arch.model.moe is not None,
                       sliding=arch.model.sliding_window is not None)
        loss_fn = functools.partial(tfm.lm_loss, cfg, tfm.Constraints())
        specs = tfm.param_specs(cfg)
        stream = LMStream(vocab=cfg.vocab, batch=batch, seq_len=seq)
    elif arch.family == "gnn":
        cfg = smoke_gnn(arch.model.arch)
        from dataclasses import replace
        cfg = replace(cfg, task="graph_class", n_out=2)
        loss_fn = functools.partial(gnn_lib.gnn_loss, cfg)
        specs = gnn_lib.param_specs(cfg)
        stream = MoleculeStream(batch=batch, n_nodes=12, n_edges=24, d_feat=cfg.d_feat)
    else:
        cfg = smoke_sasrec()
        loss_fn = functools.partial(sas_lib.sasrec_loss, cfg)
        specs = sas_lib.param_specs(cfg)
        stream = SASRecStream(n_items=cfg.n_items, batch=batch, seq_len=cfg.seq_len)
    return cfg, specs, loss_fn, stream


def run(arch_name: str, steps: int, batch: int, seq: int, ckpt_dir: str,
        ckpt_every: int, lr: float, log_every: int = 10,
        state_bits: int = 32) -> dict:
    cfg, specs, loss_fn, stream = build_smoke(arch_name, batch, seq)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=lr, state_bits=state_bits))
    step_fn = jax.jit(make_train_step(loss_fn, tcfg))

    params = init_params(jax.random.PRNGKey(0), specs)
    state = init_opt_state(params, tcfg.adamw)

    mgr = CheckpointManager(ckpt_dir, every_steps=ckpt_every)
    mgr.install_preemption_handler()
    start, restored, meta = mgr.restore_latest((params, state))
    if start is not None:
        params, state = restored
        print(f"restored checkpoint @ step {start} ({meta})")
        start += 1
    else:
        start = 0

    watchdog = StepWatchdog()
    losses = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        batch_np = stream.batch_at(step)
        watchdog.start()
        params, state, metrics = step_fn(params, state, batch_np)
        loss = float(metrics["loss"])
        straggler = watchdog.stop()
        losses.append(loss)
        if straggler:
            print(f"step {step}: STRAGGLER detected (>{watchdog.threshold}× median) "
                  "— pod-scale policy: checkpoint + reschedule")
            mgr.save(step, (params, state), extra={"reason": "straggler"})
        if mgr.should_save(step):
            mgr.save(step, (params, state), extra={"loss": loss})
        if step % log_every == 0:
            print(f"step {step}: loss={loss:.4f} grad_norm={float(metrics['grad_norm']):.3f}")
        assert np.isfinite(loss), f"loss diverged at step {step}"
    wall = time.perf_counter() - t0
    mgr.save(steps - 1, (params, state), extra={"final_loss": losses[-1]})
    print(f"done: {len(losses)} steps in {wall:.1f}s; "
          f"loss {losses[0]:.4f} → {losses[-1]:.4f}")
    return {"losses": losses, "wall_s": wall}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--state-bits", type=int, default=32, choices=[8, 32])
    from repro.obs.cli import add_obs_args, obs_session

    add_obs_args(ap)
    args = ap.parse_args()
    if args.preset == "full":
        raise SystemExit(
            "--preset full lowers the assigned config and requires a TPU pod; "
            "use launch/dryrun.py for the compile-only proof on CPU."
        )
    with obs_session(args):
        run(args.arch, args.steps, args.batch, args.seq, args.ckpt_dir,
            args.ckpt_every, args.lr, state_bits=args.state_bits)


if __name__ == "__main__":
    main()
