"""Launch driver for the streaming rasterizer (repro/render).

Runs the BigGraphVis pipeline on a generated (or on-disk) graph and
rasterizes the result on-device: the supergraph drawing by default
(supernode disks radius ∝ √size + weighted superedges, paper §4.3), or
with ``--full`` the full-graph ForceAtlas2 layout with *every* edge
streamed through the raster chunk path — host/device residency
independent of |E|, like the detection engine itself.

    PYTHONPATH=src python -m repro.launch.render_runner \
        --nodes 20000 --communities 200 --out graph.png

    PYTHONPATH=src python -m repro.launch.render_runner \
        --full --width 2048 --height 2048 --supersample 2 --no-edges

    PYTHONPATH=src python -m repro.launch.render_runner \
        --edges edges.npy --nodes 100000 --chunk 65536

prints raster throughput (edges/s, Mpixels/s), chunk counts, and the
renderer's peak device residency.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.pipeline import biggraphvis, default_config, full_layout_colored
from repro.data.edge_store import open_edge_store
from repro.obs.cli import add_obs_args, obs_session
from repro.obs.metrics import REGISTRY
from repro.render import RenderConfig, render_arrays, write_png


def _report(stats) -> None:
    """Timing report read from the metrics registry (the render stage
    publishes its ``render.*`` gauges there — one source of truth for the
    printout, ``--metrics-out``, and CI step summaries); only identity
    fields (sizes/counts) still come from the stats object."""
    v = REGISTRY.value
    print(
        f"render: {stats.width}x{stats.height} (ss={stats.supersample}) "
        f"nodes={stats.nodes_drawn} edge_rows={stats.edges_streamed} "
        f"chunks={stats.chunks}"
    )
    print(
        f"timings: node_raster={v('render.node_raster_s') * 1e3:.1f}ms "
        f"edge_raster={v('render.edge_raster_s') * 1e3:.1f}ms "
        f"compose={v('render.compose_s') * 1e3:.1f}ms "
        f"total={v('render.seconds') * 1e3:.1f}ms"
    )
    print(
        f"throughput: {v('render.edges_per_s') / 1e6:.2f}M edges/s, "
        f"{v('render.mpixels_per_s'):.1f} Mpixels/s"
    )
    print(f"peak device bytes (render): {int(v('render.peak_device_bytes')):,}")
    if stats.stream is not None:
        s = stats.stream
        print(
            f"edge stream: host_fill={s.host_fill_s * 1e3:.1f}ms "
            f"copy_stall={s.copy_stall_s * 1e3:.1f}ms "
            f"raster_chunks={s.raster_chunks}"
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--communities", type=int, default=200)
    ap.add_argument("--edges", default="",
                    help="render an on-disk edge store (.npy/.bin/shard dir) "
                         "instead of generating a graph (requires --nodes)")
    ap.add_argument("--out", default="graph.png")
    ap.add_argument("--width", type=int, default=1024)
    ap.add_argument("--height", type=int, default=1024)
    ap.add_argument("--supersample", type=int, default=1)
    ap.add_argument("--edge-samples", type=int, default=8)
    ap.add_argument("--no-edges", action="store_true",
                    help="skip the edge splat pass (nodes only)")
    ap.add_argument("--backend", choices=("auto", "ref", "pallas", "interpret"),
                    default="auto", help="kernels/raster dispatch")
    ap.add_argument("--chunk", type=int, default=1 << 16,
                    help="edges per streamed raster chunk")
    ap.add_argument("--prefetch", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="render the full-graph layout (every edge streamed) "
                         "instead of the supergraph drawing")
    ap.add_argument("--iterations", type=int, default=60)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--seed", type=int, default=5)
    add_obs_args(ap)
    args = ap.parse_args()

    with obs_session(args):
        _run(args)


def _run(args) -> None:
    from repro.graph import mode_degree, planted_partition

    n = args.nodes
    if args.edges:
        store = open_edge_store(args.edges)
        edges = store.read(0, store.n_edges)
        print(f"graph: {n} nodes, {store.n_edges} edges (from {args.edges})")
    else:
        edges, _ = planted_partition(
            n, args.communities, 0.12, 2e-4, seed=args.seed
        )
        print(f"graph: {n} nodes, {len(edges)} edges (planted partition)")
    delta = mode_degree(edges, n)
    cfg = default_config(n, len(edges), delta, rounds=args.rounds,
                         iterations=args.iterations)
    rcfg = RenderConfig(
        width=args.width, height=args.height, supersample=args.supersample,
        edge_samples=args.edge_samples, draw_edges=not args.no_edges,
        backend=args.backend, chunk_size=args.chunk, prefetch=args.prefetch,
        time_raster=True,
    )

    if args.full:
        pos, groups = full_layout_colored(
            edges, n, cfg, iterations=args.iterations
        )
        image, stats = render_arrays(
            pos, np.full(n, 2.0), groups,
            None if args.no_edges else edges, cfg=rcfg,
        )
        write_png(args.out, image)
    else:
        res = biggraphvis(edges, n, cfg)
        print(
            f"BigGraphVis: {res.n_supernodes} supernodes, "
            f"{res.n_superedges} superedges, Q={res.modularity:.3f}"
        )
        _image, stats = res.render(args.out, cfg=rcfg)
    print(f"wrote {args.out}")
    _report(stats)


if __name__ == "__main__":
    main()
