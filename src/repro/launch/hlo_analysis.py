"""HLO-text analysis for the roofline: loop-adjusted FLOPs, dot HBM
traffic, and collective payloads — from the *partitioned* module, so all
shapes are per-device.

XLA's HloCostAnalysis counts while-loop bodies ONCE (verified empirically,
DESIGN.md §8). jax lowers lax.scan to while ops carrying
``backend_config={"known_trip_count":{"n":...}}``, and every op's metadata
``op_name`` records its logical nesting path (".../while/body/..."). So:

  1. map every while op's op_name path → trip count,
  2. build a symbol table %name → (dtype, dims) from op definitions,
  3. for every dot: flops = 2·prod(out)·prod(lhs contracted dims), traffic
     = bytes(lhs)+bytes(rhs)+bytes(out); for every collective: payload =
     operand bytes × ring factor (2(k−1)/k all-reduce, (k−1)/k AG/RS);
  4. multiply each contribution by the product of enclosing trip counts.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_TRIP_RE = re.compile(r"known_trip_count[\\\"':{\s]*n[\\\"':\s]*(\d+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _nbytes(dtype: str, dims: tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 4)


def _parse_dims(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.split(",") if x)


@dataclass
class HLOStats:
    dot_flops: float = 0.0  # per-device, loop-adjusted
    dot_traffic_bytes: float = 0.0  # per-device HBM traffic through dots
    collective_bytes: float = 0.0  # per-device link payload, ring-adjusted
    collective_counts: dict = field(default_factory=dict)
    n_whiles: int = 0
    n_dots: int = 0


def _group_size(line: str, default_k: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _IOTA_GROUPS_RE.search(line)
    if m:  # [num_groups, group_size]
        return int(m.group(2))
    return default_k


def analyze_hlo(text: str, default_group: int = 16) -> HLOStats:
    stats = HLOStats()
    counts: dict[str, float] = defaultdict(float)

    # pass 1: symbol table + while trip counts
    symbols: dict[str, tuple[str, tuple[int, ...]]] = {}
    trips: dict[str, int] = {}
    lines = text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            symbols[m.group(1)] = (m.group(2), _parse_dims(m.group(3)))
        if " while(" in line:
            om = _OPNAME_RE.search(line)
            tm = _TRIP_RE.search(line)
            if om and tm:
                trips[om.group(1)] = int(tm.group(1))
    stats.n_whiles = len(trips)

    def multiplier(path: str) -> int:
        mult = 1
        for wpath, n in trips.items():
            if path.startswith(wpath + "/") or path.startswith(wpath + "."):
                mult *= n
        return mult

    # pass 2: dots + collectives
    for line in lines:
        ls = line.strip()
        if not ls.startswith("%") and "=" not in ls[:60]:
            continue
        om = _OPNAME_RE.search(ls)
        path = om.group(1) if om else ""
        mult = multiplier(path)

        if " dot(" in ls:
            dm = _DEF_RE.match(ls)
            opm = re.search(r"dot\(([^)]*)\)", ls)
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ls)
            if dm and opm:
                out_t, out_d = dm.group(2), _parse_dims(dm.group(3))
                out_elems = 1
                for d in out_d:
                    out_elems *= d
                # Operands print either bare ("%a, %b") or typed
                # ("f32[32,128]{1,0} %a, ..." — commas inside the dims), so
                # pull the %-prefixed names rather than splitting on commas.
                operands = re.findall(r"%([\w.\-]+)", opm.group(1))
                if not operands:
                    operands = [o.strip() for o in opm.group(1).split(",")]
                contract = 1
                traffic = _nbytes(out_t, out_d)
                lhs = symbols.get(operands[0]) if operands else None
                if lhs and cm:
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(lhs[1]):
                            contract *= lhs[1][int(ci)]
                for o in operands[:2]:
                    if o in symbols:
                        t, d = symbols[o]
                        traffic += _nbytes(t, d)
                stats.dot_flops += 2.0 * out_elems * max(contract, 1) * mult
                stats.dot_traffic_bytes += traffic * mult
                stats.n_dots += 1
            continue

        for cname in COLLECTIVES:
            if f" {cname}(" in ls or f" {cname}-start(" in ls:
                dm = _DEF_RE.match(ls)
                if dm:
                    nbytes = _nbytes(dm.group(2), _parse_dims(dm.group(3)))
                    k = _group_size(ls, default_group)
                    if cname == "all-reduce":
                        factor = 2.0 * (k - 1) / max(k, 1)
                    elif cname in ("all-gather", "reduce-scatter"):
                        factor = (k - 1) / max(k, 1)
                    else:
                        factor = 1.0
                    payload = nbytes * factor * mult
                    counts[cname] += payload
                    stats.collective_bytes += payload
                break

    stats.collective_counts = dict(counts)
    return stats
