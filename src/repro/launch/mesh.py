"""Production meshes. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names — lets the same
    sharded step functions run on one CPU device (smoke tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_stream_mesh(devices: int | None = None):
    """1-D "data" mesh over the local devices — the streaming engine's
    sharded detect/layout placement (core/stream.py, StreamConfig.mesh).
    ``devices`` caps the mesh size (None = all available); on CPU, force
    a multi-device mesh with XLA_FLAGS=--xla_force_host_platform_device_count=N.
    """
    avail = jax.device_count()
    d = avail if devices is None else min(devices, avail)
    return jax.make_mesh((d,), ("data",))
