"""Serving launcher: the interactive tile-pyramid layout service.

    PYTHONPATH=src python -m repro.launch.serve --nodes 3000 --depth 3
    PYTHONPATH=src python -m repro.launch.serve --edges edges.npy --nodes 50000

Computes a BigGraphVis layout (synthetic planted-partition graph by
default, or any ``repro.data.edge_store`` source via ``--edges``), builds
the tile pyramid (``repro/serve/tiles.py``), precomputes the low-zoom
levels into the LRU cache, and serves a synthetic zipfian pan/zoom trace,
reporting tiles/s, cache hit rate, miss-latency percentiles, and the
steady-state recompile count (which should be zero — fixed tile shapes).

The start path points JAX's persistent compilation cache at
``--compile-cache`` (default ``.bgv-compile-cache/``; ``--no-compile-cache``
disables), so a restarted service deserializes its compiled render/layout
steps instead of recompiling them — cold-start compile otherwise dominates
first-request latency. The former LM decode demo lives on in
``examples/serve_lm.py`` (engine: ``repro/serve/engine.py``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.kernels.compat import enable_persistent_compilation_cache
from repro.obs.cli import add_obs_args, obs_session


def percentile(values, q: float) -> float:
    return float(np.percentile(np.asarray(values), q)) if len(values) else 0.0


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Interactive tile-pyramid layout service over a "
                    "BigGraphVis result"
    )
    ap.add_argument("--edges", default="",
                    help="edge source (.npy/.bin/shard dir); default: "
                         "synthetic planted-partition graph")
    ap.add_argument("--nodes", type=int, default=3000, help="node count")
    ap.add_argument("--communities", type=int, default=30,
                    help="planted communities (synthetic graph only)")
    ap.add_argument("--depth", type=int, default=3,
                    help="pyramid levels (level z = 2^z x 2^z tiles)")
    ap.add_argument("--tile-size", type=int, default=256,
                    help="square tile resolution in pixels")
    ap.add_argument("--cache-mb", type=float, default=64.0,
                    help="tile cache capacity in MiB")
    ap.add_argument("--slots", type=int, default=8,
                    help="max tile renders per engine tick")
    ap.add_argument("--iterations", type=int, default=60,
                    help="supergraph FA2 iterations")
    ap.add_argument("--requests", type=int, default=400,
                    help="synthetic pan/zoom requests to serve")
    ap.add_argument("--zipf", type=float, default=1.1,
                    help="zipf exponent of the tile popularity ranking")
    ap.add_argument("--drill-frac", type=float, default=0.05,
                    help="fraction of requests drilling into a community")
    ap.add_argument("--seed", type=int, default=0, help="traffic seed")
    ap.add_argument("--compile-cache", default=".bgv-compile-cache",
                    help="persistent XLA compilation cache directory")
    ap.add_argument("--no-compile-cache", action="store_true",
                    help="skip persistent compilation caching")
    add_obs_args(ap)
    args = ap.parse_args()

    with obs_session(args):
        _run(args)


def _run(args) -> None:
    # Before any compilation: a warm cache turns the service's cold-start
    # compiles into deserialization.
    cache_on = False
    if not args.no_compile_cache:
        cache_on = enable_persistent_compilation_cache(args.compile_cache)
    print(f"compile cache: {'on (' + args.compile_cache + ')' if cache_on else 'off'}")

    import jax

    from repro.core import biggraphvis, default_config
    from repro.graph import mode_degree, planted_partition
    from repro.serve.tiles import (
        DrillSpec,
        TileConfig,
        TileEngine,
        TilePyramid,
        TileRequest,
        jit_compile_count,
        synthetic_trace,
    )

    n = args.nodes
    if args.edges:
        from repro.data.edge_store import as_edge_store

        store = as_edge_store(args.edges)
        edges = store.read(0, store.n_edges)
    else:
        edges, _ = planted_partition(
            n, args.communities, 0.15, 0.001, seed=42
        )
    print(f"graph: {n} nodes, {len(edges)} edges on {jax.default_backend()}")

    cfg = default_config(
        n, len(edges), mode_degree(np.asarray(edges), n),
        iterations=args.iterations, s_cap=min(n, 4096),
    )
    t0 = time.perf_counter()
    result = biggraphvis(edges, n, cfg)
    print(
        f"layout: {result.n_supernodes} supernodes, "
        f"{result.n_superedges} superedges, Q={result.modularity:.3f} "
        f"in {time.perf_counter() - t0:.1f}s"
    )

    pyramid = TilePyramid(
        result,
        TileConfig(tile_size=args.tile_size, depth=args.depth),
        source=edges,
        bgv_cfg=cfg,
    )
    engine = TileEngine(
        pyramid, cache_bytes=int(args.cache_mb * (1 << 20)), slots=args.slots
    )

    t0 = time.perf_counter()
    # Warm the full serving mix: every pyramid tile plus the drill pool the
    # trace samples from — after this, misses re-render on compiled code.
    drill_pool = pyramid.drillable_communities()[:8]
    warmed = engine.warmup(drills=drill_pool)
    n_tiles = sum(pyramid.n_tiles(z) ** 2 for z in range(args.depth))
    print(
        f"warmup: {warmed} tiles ({n_tiles} pyramid + {len(drill_pool)} "
        f"drill-downs) precomputed in {time.perf_counter() - t0:.1f}s "
        f"({engine.cache.bytes / (1 << 20):.1f} MiB cached)"
    )

    trace = synthetic_trace(
        pyramid, args.requests, zipf_a=args.zipf,
        drill_frac=args.drill_frac, seed=args.seed,
    )
    c0 = jit_compile_count()
    hits0 = engine.cache.hits
    miss_lat: list[float] = []
    t0 = time.perf_counter()
    for spec in trace:
        req = TileRequest(spec)
        engine.submit(req)
        while not req.done:
            engine.tick()
        if not req.hit:
            miss_lat.append(req.latency_s)
    dt = time.perf_counter() - t0

    served = len(trace)
    hits = engine.cache.hits - hits0
    drills = sum(1 for s in trace if isinstance(s, DrillSpec))
    print(
        f"served {served} requests ({drills} drill-downs) in {dt:.1f}s: "
        f"{served / dt:.1f} tiles/s, hit rate {hits / served:.1%}, "
        f"{len(miss_lat)} misses "
        f"(p50 {percentile(miss_lat, 50) * 1e3:.0f}ms, "
        f"p99 {percentile(miss_lat, 99) * 1e3:.0f}ms)"
    )
    print(
        f"steady-state recompiles: {jit_compile_count() - c0} "
        f"(fixed tile shapes), cache: {len(engine.cache)} tiles / "
        f"{engine.cache.bytes / (1 << 20):.1f} MiB, "
        f"{engine.cache.evictions} evictions, {engine.ticks} ticks"
    )


if __name__ == "__main__":
    main()
