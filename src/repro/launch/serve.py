"""Serving launcher: continuous-batching LM decode on the current backend.

    PYTHONPATH=src python -m repro.launch.serve --requests 16 --slots 4

Uses the reduced same-family config on CPU (the full configs are proven
via launch/dryrun.py decode cells); on a TPU pod the same engine runs the
assigned config with the decode-cell shardings from launch/steps.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.lm_archs import smoke_lm
from repro.models import transformer as tfm
from repro.models.param import init_params
from repro.serve.engine import LMEngine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = smoke_lm(moe=False)
    params = init_params(jax.random.PRNGKey(0), tfm.param_specs(cfg))
    engine = LMEngine(cfg, params, n_slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    backlog = [
        Request(prompt=rng.integers(1, cfg.vocab, size=int(rng.integers(2, 10))),
                max_new=args.max_new)
        for _ in range(args.requests)
    ]
    done, ticks = [], 0
    t0 = time.perf_counter()
    while backlog or engine.n_live:
        while backlog and engine.submit(backlog[0]):
            backlog.pop(0)
        done += engine.tick()
        ticks += 1
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {tokens} tokens in {ticks} ticks "
          f"({dt:.1f}s, {tokens/dt:.1f} tok/s on {jax.default_backend()})")


if __name__ == "__main__":
    main()
