import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell and record memory/cost/collective evidence.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json, consumed
by benchmarks/roofline.py. The XLA_FLAGS line above MUST precede any jax
import (device count locks at first init) and is deliberately NOT set
anywhere else in the repo — smoke tests see 1 device.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import REGISTRY, get_config
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _mem_dict(ma) -> dict:
    keys = [
        "generated_code_size_in_bytes", "argument_size_in_bytes",
        "output_size_in_bytes", "alias_size_in_bytes", "temp_size_in_bytes",
    ]
    return {k: getattr(ma, k, 0) for k in keys}


def run_cell(arch_name: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, save_hlo: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch_name}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    arch = get_config(arch_name)
    shape = arch.shapes[shape_name]
    rec = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "status": "", "profile": arch.profile,
    }
    if shape.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = shape.skip
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        built = build_step(arch, shape, mesh)
        t0 = time.time()
        with mesh:
            lowered = jax.jit(
                built.fn,
                in_shardings=built.in_shardings,
                out_shardings=built.out_shardings,
                donate_argnums=built.donate,
            ).lower(*built.abstract_args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        print(f"[{arch_name}/{shape_name}/{mesh_kind}] memory_analysis:", ma)
        # jax < 0.5 returns a one-element list of dicts from cost_analysis;
        # newer jax returns the dict directly.
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        print(f"[{arch_name}/{shape_name}/{mesh_kind}] cost_analysis flops:",
              ca.get("flops"), "bytes:", ca.get("bytes accessed"))
        txt = compiled.as_text()
        hlo = analyze_hlo(txt)

        per_dev = (
            _mem_dict(ma)["argument_size_in_bytes"]
            + _mem_dict(ma)["output_size_in_bytes"]
            + _mem_dict(ma)["temp_size_in_bytes"]
            - _mem_dict(ma)["alias_size_in_bytes"]
        )
        rec.update(
            status="ok",
            n_devices=len(mesh.devices.flatten()),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=_mem_dict(ma),
            bytes_per_device=per_dev,
            cost={k: v for k, v in ca.items()},
            hlo_dot_flops=hlo.dot_flops,
            hlo_dot_traffic=hlo.dot_traffic_bytes,
            collective_bytes=hlo.collective_bytes,
            collective_counts=hlo.collective_counts,
            n_whiles=hlo.n_whiles,
            n_dots=hlo.n_dots,
            meta=built.meta,
        )
        if save_hlo:
            with open(path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(txt)
    except Exception as e:  # record the failure — it is a bug to fix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{arch_name}/{shape_name}/{mesh_kind}] FAILED: {rec['error'][:200]}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    from repro.obs.cli import add_obs_args, obs_session

    add_obs_args(ap)
    args = ap.parse_args()

    with obs_session(args):
        _run(args)


def _run(args) -> None:
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for name, builder in REGISTRY.items():
            for sname in builder().shapes:
                cells.append((name, sname))
    else:
        arch = get_config(args.arch)
        shapes = [args.shape] if args.shape else list(arch.shapes)
        cells = [(args.arch, s) for s in shapes]

    n_ok = n_fail = n_skip = 0
    for arch_name, shape_name in cells:
        for mk in meshes:
            rec = run_cell(arch_name, shape_name, mk, args.out,
                           force=args.force, save_hlo=args.save_hlo)
            s = rec["status"]
            n_ok += s == "ok"
            n_fail += s == "error"
            n_skip += s == "skipped"
            print(f"  -> {arch_name}/{shape_name}/{mk}: {s} "
                  f"(compile {rec.get('compile_s', '-')}s, "
                  f"{rec.get('bytes_per_device', 0)/2**30:.2f} GiB/dev)")
    print(f"dry-run done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
