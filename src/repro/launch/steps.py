"""Step builders: one jittable function + abstract inputs + in_shardings per
(arch × shape × mesh) cell. This is where the logical-axis sharding system
meets the model zoo; launch/dryrun.py lowers exactly what train.py/serve.py
execute.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, input_specs
from repro.models import gnn as gnn_lib
from repro.models import sasrec as sas_lib
from repro.models import transformer as tfm
from repro.models.param import abstract_params, logical_axes, param_count
from repro.sharding.rules import (
    filter_spec,
    params_shardings,
    shardings_for_axes,
)
from repro.train import optimizer as opt
from repro.train.train_loop import TrainConfig, make_train_step


@dataclass
class BuiltStep:
    fn: Callable  # jittable
    abstract_args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    meta: dict  # roofline metadata (scan trip counts, model flops, ...)
    out_shardings: Any = None  # None = let GSPMD choose
    donate: tuple = ()  # donate_argnums (params/opt for train, cache for decode)


def _ns(mesh, *spec):
    return NamedSharding(mesh, filter_spec(P(*spec), mesh))


def _shard_batch_dim(mesh, b: int):
    """("pod","data") if it divides the batch, else replicated."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    extent = 1
    for a in axes:
        extent *= mesh.shape[a]
    return axes if (b % extent == 0 and extent > 1) else None


def _batch_shardings(mesh: Mesh, abstract: dict, leading_axes) -> dict:
    out = {}
    for k, v in abstract.items():
        if v.ndim == 0:
            out[k] = NamedSharding(mesh, P())
        else:
            dim0 = leading_axes if (leading_axes and v.shape[0] % _extent(mesh, leading_axes) == 0) else None
            out[k] = NamedSharding(mesh, P(dim0, *([None] * (v.ndim - 1))))
    return out


def _extent(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    e = 1
    for a in axes:
        e *= mesh.shape[a]
    return e


def _all_axes(mesh):
    return tuple(mesh.axis_names)


# ------------------------------------------------------------------ LM cells

def _lm_flops_meta(cfg: tfm.LMConfig, shape: ShapeSpec) -> dict:
    """Analytic MODEL_FLOPS: 6·N_active·D for train, 2·N_active·D for fwd."""
    d, nl = cfg.d_model, cfg.n_layers
    att = d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * d
    if cfg.moe is None:
        mlp = 3 * d * cfg.d_ff
    else:
        m = cfg.moe
        mlp = m.top_k * 3 * d * m.d_ff_expert + m.n_shared * 3 * d * m.d_ff_expert \
            + d * m.n_experts
    n_active = nl * (att + mlp) + 2 * d * cfg.vocab_padded
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    # attention score flops (per token ~ 2·S·H·hd for scores+values)
    s_eff = shape.seq_len
    attn_extra = 2 * 2 * s_eff * cfg.n_heads * cfg.head_dim * (0.5 if shape.kind != "decode" else 1.0)
    return {
        "model_flops": float(mult * n_active * tokens + mult / 2 * attn_extra * tokens * nl),
        "n_params_active": float(n_active),
        "scan_trip_count": nl,
        "tokens": tokens,
    }


def build_lm_step(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> BuiltStep:
    from dataclasses import replace as _replace

    cfg: tfm.LMConfig = arch.model
    if shape.kind == "train" and arch.name == "kimi-k2-1t-a32b":
        # 1T params: bf16 weights + 8-bit Adam (EXPERIMENTS §Perf)
        cfg = _replace(cfg, param_dtype=jnp.bfloat16)
    if shape.kind in ("prefill", "decode"):
        # Serving weights are stored in the activation dtype: passing f32
        # params and casting inside doubles residency (EXPERIMENTS §Perf).
        cfg = _replace(cfg, param_dtype=cfg.act_dtype)
    specs = tfm.param_specs(cfg)
    aparams = abstract_params(specs)
    p_shard = params_shardings(specs, arch.profile, mesh)

    bdim = _shard_batch_dim(mesh, shape.global_batch)
    # Sequence parallelism for train/prefill (MaxText-style activation
    # partitioning): the scan carry is the dominant live tensor — sharding
    # its seq dim over "model" cuts it 16× (yi-6b: 49→~4 GiB/dev, §Perf).
    seq_ok = shape.kind in ("train", "prefill") and shape.seq_len % mesh.shape["model"] == 0
    cons = tfm.Constraints(
        activations=_ns(mesh, bdim, "model" if seq_ok else None, None),
        logits=_ns(mesh, bdim, None, "model"),
        kv_cache=_ns(mesh, None, bdim, "model", None, None),
        # SP: gather seq once before attention; q heads shard over model
        # where divisible, kv heads replicate (GQA)
        attn_q=(
            _ns(mesh, bdim, None, "model", None)
            if cfg.n_heads % mesh.shape["model"] == 0
            else _ns(mesh, bdim, None, None, None)
        ) if seq_ok else None,
        attn_kv=_ns(mesh, bdim, None, None, None) if seq_ok else None,
        # MoE: expert-parallel shard_map path (layers.moe_mlp_shmap)
        mesh=mesh if cfg.moe else None,
        expert_axis="model",
        token_axes=(bdim if isinstance(bdim, tuple) else (bdim,)) if bdim else (),
    )
    abstract_batch = input_specs(arch, shape)
    b_shard = _batch_shardings(mesh, abstract_batch, bdim)
    meta = _lm_flops_meta(cfg, shape)
    meta["param_count"] = param_count(specs)

    rep = NamedSharding(mesh, P())
    if shape.kind == "train":
        acfg = opt.AdamWConfig(state_bits=arch.opt_state_bits)
        tcfg = TrainConfig(adamw=acfg, microbatch=arch.microbatch_train)
        loss_fn = functools.partial(tfm.lm_loss, cfg, cons)
        fn = make_train_step(loss_fn, tcfg)
        aopt = opt.abstract_opt_state(aparams, acfg)
        o_axes = opt.opt_logical_axes(logical_axes(specs), acfg)
        o_shard = shardings_for_axes(aopt, o_axes, arch.profile, mesh)
        m_shard = {"loss": rep, "grad_norm": rep}
        return BuiltStep(fn, (aparams, aopt, abstract_batch),
                         (p_shard, o_shard, b_shard), meta,
                         out_shardings=(p_shard, o_shard, m_shard),
                         donate=(0, 1))

    if shape.kind == "prefill":
        fn = tfm.make_prefill(cfg, cons)
        return BuiltStep(fn, (aparams, abstract_batch), (p_shard, b_shard), meta,
                         out_shardings=cons.logits)

    # decode
    fn = tfm.make_decode_step(cfg, cons)
    acache = tfm.abstract_kv_cache(cfg, shape.global_batch, shape.seq_len)
    c_shard = {k: cons.kv_cache for k in acache}
    return BuiltStep(fn, (aparams, acache, abstract_batch),
                     (p_shard, c_shard, b_shard), meta,
                     out_shardings=(_ns(mesh, bdim, None, "model"), c_shard),
                     donate=(1,))


# ----------------------------------------------------------------- GNN cells

def _gnn_flops_meta(cfg: gnn_lib.GNNConfig, shape: ShapeSpec) -> dict:
    d = cfg.d_hidden
    n, e = shape.n_nodes, shape.n_edges
    if cfg.arch in ("meshgraphnet", "graphcast"):
        per_layer = e * (3 * d * d + d * d) * 2 + n * (2 * d * d + d * d) * 2
    elif cfg.arch == "gin":
        per_layer = n * 2 * d * d * 2 + e * 2 * d
    else:  # gat
        per_layer = n * 2 * d * d + e * 8 * d
    fwd = cfg.n_layers * per_layer + n * 2 * (shape.d_feat + shape.n_out) * d
    return {
        "model_flops": float(3 * fwd),  # train = fwd + 2×bwd
        "scan_trip_count": cfg.n_layers,
        "tokens": n,
    }


def build_gnn_step(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> BuiltStep:
    cfg = arch.model_for(shape)
    specs = gnn_lib.param_specs(cfg)
    aparams = abstract_params(specs)
    p_shard = params_shardings(specs, arch.profile, mesh)
    node_axes = _all_axes(mesh)
    constraint = _ns(mesh, node_axes, None)

    acfg = opt.AdamWConfig()
    tcfg = TrainConfig(adamw=acfg)
    loss_fn = functools.partial(gnn_lib.gnn_loss, cfg, constraint=constraint)
    fn = make_train_step(loss_fn, tcfg)
    aopt = opt.abstract_opt_state(aparams, acfg)
    o_axes = opt.opt_logical_axes(logical_axes(specs), acfg)
    o_shard = shardings_for_axes(aopt, o_axes, arch.profile, mesh)

    abstract_batch = input_specs(arch, shape)
    b_shard = {}
    for k, v in abstract_batch.items():
        if v.ndim and v.shape[0] % _extent(mesh, node_axes) == 0:
            b_shard[k] = _ns(mesh, node_axes, *([None] * (v.ndim - 1)))
        elif v.ndim and v.shape[0] % _extent(mesh, _shard_batch_dim(mesh, v.shape[0]) or ()) == 0:
            b_shard[k] = _ns(mesh, _shard_batch_dim(mesh, v.shape[0]), *([None] * (v.ndim - 1)))
        else:
            b_shard[k] = _ns(mesh)
    meta = _gnn_flops_meta(cfg, shape)
    meta["param_count"] = param_count(specs)
    rep = NamedSharding(mesh, P())
    return BuiltStep(fn, (aparams, aopt, abstract_batch),
                     (p_shard, o_shard, b_shard), meta,
                     out_shardings=(p_shard, o_shard, {"loss": rep, "grad_norm": rep}),
                     donate=(0, 1))


# -------------------------------------------------------------- recsys cells

def build_recsys_step(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> BuiltStep:
    cfg: sas_lib.SASRecConfig = arch.model
    specs = sas_lib.param_specs(cfg)
    aparams = abstract_params(specs)
    p_shard = params_shardings(specs, arch.profile, mesh)
    bdim = _shard_batch_dim(mesh, shape.global_batch)
    act = _ns(mesh, bdim, None, None)
    abstract_batch = input_specs(arch, shape)
    b_shard = _batch_shardings(mesh, abstract_batch, bdim)

    d, s, v = cfg.embed_dim, cfg.seq_len, cfg.n_items
    b = shape.global_batch
    enc_flops = b * s * (4 * d * d + 2 * d * d + 2 * s * d) * cfg.n_blocks
    meta = {"scan_trip_count": cfg.n_blocks, "param_count": param_count(specs), "tokens": b * s}

    rep = NamedSharding(mesh, P())
    if shape.kind == "train":
        acfg = opt.AdamWConfig()
        tcfg = TrainConfig(adamw=acfg)
        loss_fn = functools.partial(sas_lib.sasrec_loss, cfg, constraint=act)
        fn = make_train_step(loss_fn, tcfg)
        aopt = opt.abstract_opt_state(aparams, acfg)
        o_axes = opt.opt_logical_axes(logical_axes(specs), acfg)
        o_shard = shardings_for_axes(aopt, o_axes, arch.profile, mesh)
        meta["model_flops"] = float(3 * (enc_flops + b * s * 2 * 2 * d))
        return BuiltStep(fn, (aparams, aopt, abstract_batch),
                         (p_shard, o_shard, b_shard), meta,
                         out_shardings=(p_shard, o_shard, {"loss": rep, "grad_norm": rep}),
                         donate=(0, 1))
    if shape.kind == "serve":
        logits_c = _ns(mesh, bdim, "model")
        fn = sas_lib.make_serve_step(cfg, constraint=act, logits_constraint=logits_c)
        meta["model_flops"] = float(enc_flops + b * 2 * d * v)
        return BuiltStep(fn, (aparams, abstract_batch), (p_shard, b_shard), meta,
                         out_shardings=logits_c)
    # retrieval
    fn = sas_lib.make_retrieval_step(cfg, constraint=act)
    c = shape.n_candidates
    b_shard["candidates"] = _ns(mesh, _all_axes(mesh))
    meta["model_flops"] = float(enc_flops + 2 * d * c)
    return BuiltStep(fn, (aparams, abstract_batch), (p_shard, b_shard), meta,
                     out_shardings=_ns(mesh, _all_axes(mesh)))


# ----------------------------------------------------------------- BGV cells

def build_bgv_step(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> BuiltStep:
    """The paper's pipeline, distributed: see configs/biggraphvis.py."""
    import repro.core.cms as cms_lib
    from repro.core import forceatlas2 as fa2
    from repro.core.scoda import ScodaConfig, _scoda_update_body

    n, e = shape.n_nodes, shape.n_edges
    all_ax = _all_axes(mesh)
    edge_shard = _ns(mesh, all_ax, None)
    node_rep = _ns(mesh)  # labels/degrees replicated (all-reduce merged)

    if shape.kind == "bgv_detect":
        cms_cfg = cms_lib.CMSConfig(rows=4, cols=shape.n_out)
        # The engine's chunk-update body with one block = the whole device
        # shard: the multi-device analog of core/stream.py's per-chunk step.
        scoda_cfg = ScodaConfig(
            degree_threshold=16, rounds=1, block_size=e, tie_break="join",
            degree_update="scoda", exact_block_degrees=False,
            conflict="min", propagate_jumps=0,
        )

        def detect_step(com, deg, edges):
            # One streaming round over the device-sharded edge list: each
            # device's scatter lands in the replicated (com, deg) arrays —
            # XLA merges with all-reduce-min / all-reduce-add, the TPU
            # equivalent of the paper's atomics (DESIGN.md §2).
            com, deg = _scoda_update_body((com, deg), edges, 16, scoda_cfg)
            sketch = cms_lib.init_sketch(cms_cfg)
            sketch = cms_lib.update(sketch, com[:-1], deg[:-1].astype(jnp.float32), cms_cfg)
            return com, deg, sketch

        abstract = (
            jax.ShapeDtypeStruct((n + 1,), jnp.int32),
            jax.ShapeDtypeStruct((n + 1,), jnp.int32),
            jax.ShapeDtypeStruct((e, 2), jnp.int32),
        )
        shards = (node_rep, node_rep, edge_shard)
        meta = {"model_flops": float(30 * e), "scan_trip_count": 1, "tokens": e}
        return BuiltStep(detect_step, abstract, shards, meta,
                         out_shardings=(node_rep, node_rep, node_rep))

    # bgv_layout: one FA2 iteration on the supergraph, node tiles sharded.
    # The repulsion backend comes from the arch config (exact n² tiles for
    # supergraph shapes; the tiled grid family for full-graph cells).
    model = arch.model
    cfg = fa2.FA2Config(
        iterations=1, use_radii=True,
        repulsion=getattr(model, "layout_repulsion", "exact"),
        grid_size=getattr(model, "layout_grid_size", 64),
        grid_window=getattr(model, "layout_grid_window", 32),
    )

    grid_cell = cfg.repulsion in ("grid", "grid_pallas")
    if grid_cell:
        # Grid cells take precomputed (cell, order) from kernels/grid
        # ``bin_and_sort`` so the per-step re-bin + argsort is hoisted to
        # the caller, which refreshes them on its own cadence (the
        # repeated-step analog of ``layout``'s grid_rebuild scan carry).
        def layout_step(pos, prev_f, mass, radii, edges, weights, cell, order):
            state = (pos, prev_f, jnp.float32(1.0))
            (pos, f, _), _ = fa2.step(
                state, edges, weights, mass, radii, cfg, n,
                cell=cell, order=order,
            )
            return pos, f
    else:
        def layout_step(pos, prev_f, mass, radii, edges, weights):
            state = (pos, prev_f, jnp.float32(1.0))
            (pos, f, _), _ = fa2.step(state, edges, weights, mass, radii, cfg, n)
            return pos, f

    abstract = (
        jax.ShapeDtypeStruct((n, 2), jnp.float32),
        jax.ShapeDtypeStruct((n, 2), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((e, 2), jnp.int32),
        jax.ShapeDtypeStruct((e,), jnp.float32),
    )
    node_shard = _ns(mesh, all_ax, None)
    vec_shard = _ns(mesh, all_ax)
    shards = (node_shard, node_shard, vec_shard, vec_shard, edge_shard, vec_shard)
    if grid_cell:
        abstract = abstract + (
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        )
        shards = shards + (vec_shard, vec_shard)
    meta = {"model_flops": float(10.0 * n * n + 20 * e), "scan_trip_count": 1, "tokens": n}
    return BuiltStep(layout_step, abstract, shards, meta,
                     out_shardings=(node_shard, node_shard))


def build_step(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh) -> BuiltStep:
    if arch.family == "lm":
        return build_lm_step(arch, shape, mesh)
    if arch.family == "gnn":
        return build_gnn_step(arch, shape, mesh)
    if arch.family == "recsys":
        return build_recsys_step(arch, shape, mesh)
    if arch.family == "bgv":
        return build_bgv_step(arch, shape, mesh)
    raise ValueError(arch.family)
