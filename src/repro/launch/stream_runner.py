"""Launch driver for the streaming chunked-edge engine (core/stream.py).

Owns device placement for the chunk buffers: single-device by default, or
row-sharded across a mesh's devices (the per-chunk scatter updates then
merge through XLA's all-reduce — the same collective structure as the
``bgv_detect`` dry-run cells in launch/steps.py). Transfers are forced-copy
``device_put``s (kernels/compat.py) so the engine's reusable staging
buffers are never aliased by device arrays, and the engine overlaps them
with compute via its double-buffered staging ring
(``EdgeChunkStream.device_chunks``).

    PYTHONPATH=src python -m repro.launch.stream_runner \
        --nodes 20000 --communities 200 --chunk 8192 --rounds 4

prints a one-shot vs streamed comparison: identical labels/supergraph,
pass count, chunk throughput, and peak device bytes. With ``--source
npy|bin|shards`` the streamed run is driven out-of-core from a converted
edge file (written to a temp dir via repro/data/edge_store.py), adding
host-residency and copy/compute-overlap numbers:

    PYTHONPATH=src python -m repro.launch.stream_runner \
        --nodes 20000 --source npy --chunk 8192
"""
from __future__ import annotations

import argparse
import tempfile
from dataclasses import dataclass, replace

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pipeline import BGVConfig, BGVResult, biggraphvis
from repro.core.stream import StreamConfig, oneshot_device_bytes
from repro.data.edge_store import write_bin, write_npy, write_shards
from repro.kernels.compat import device_put_copied
from repro.obs.cli import add_obs_args, obs_session
from repro.resilience.checkpoint import Preempted, StreamCheckpointer


@dataclass(frozen=True)
class StreamRunnerConfig:
    stream: StreamConfig = StreamConfig()
    shard_chunks: bool = False  # row-shard chunk buffers across the mesh


class StreamRunner:
    """Binds the engine to devices: placement/sharding of chunk buffers.

    ``put`` is handed to the engine as the host→device transfer; with a mesh
    it places each chunk row-sharded over every mesh axis, so each device
    streams its own slice of the chunk (edge shards, DESIGN.md §4). Either
    way it copies (never aliases host memory), as the engine's staged disk
    path requires.

    A chunk whose row count doesn't divide by the mesh device count can't
    be row-sharded; ``put`` pads it to the next multiple with the engine's
    invalid-edge sentinel (the trash node, a no-op row for every chunk
    update — ``run`` records it). Standalone use before any ``run`` has no
    sentinel to pad with, so such chunks fall back to replication.

    Chunks-only sharding (``shard_chunks`` without ``shard_detect``) is a
    placement mode: the compiler auto-partitions the per-chunk updates
    around the sharded operand, which is a valid SCoDA run but may break
    scatter ties in a different order than one device. Bit-identical
    multi-device results are the ``StreamConfig.shard_detect`` /
    ``shard_layout`` contract (explicit shard_map collectives,
    core/stream.py), verified by the device-count CI matrix.

    When constructed with a mesh and a ``StreamConfig`` that requests
    sharding (``shard_detect``/``shard_layout``) without carrying a mesh of
    its own, the runner threads its mesh into the engine config.
    """

    def __init__(self, cfg: BGVConfig, runner_cfg: StreamRunnerConfig | None = None,
                 mesh: Mesh | None = None):
        self.cfg = cfg
        self.runner_cfg = runner_cfg or StreamRunnerConfig()
        self.mesh = mesh
        self._trash = None  # invalid-edge sentinel (n_nodes); set by run()
        stream = self.runner_cfg.stream
        if (mesh is not None and stream.mesh is None
                and (stream.shard_detect or stream.shard_layout)):
            self.runner_cfg = replace(
                self.runner_cfg, stream=replace(stream, mesh=mesh)
            )
        if mesh is not None and self.runner_cfg.shard_chunks:
            self._sharding = NamedSharding(mesh, P(tuple(mesh.axis_names), None))
        else:
            self._sharding = None

    def put(self, chunk_np: np.ndarray) -> jax.Array:
        if self._sharding is not None:
            rem = chunk_np.shape[0] % self.mesh.size
            if rem:
                if self._trash is None:
                    # No sentinel to pad with: replicate rather than shard.
                    return device_put_copied(chunk_np, None)
                pad = np.full(
                    (self.mesh.size - rem, 2), self._trash, chunk_np.dtype
                )
                chunk_np = np.concatenate([chunk_np, pad])
        return device_put_copied(chunk_np, self._sharding)

    def run(self, source, n_nodes: int, checkpoint=None,
            resume: bool | str = False) -> BGVResult:
        """``source``: host edge array, EdgeStore, or edge-file path.
        ``checkpoint``/``resume`` pass through to the streaming pipeline
        (repro/resilience/checkpoint.py ``StreamCheckpointer``)."""
        self._trash = n_nodes
        return biggraphvis(
            source, n_nodes, self.cfg,
            stream=self.runner_cfg.stream, put=self.put,
            checkpoint=checkpoint, resume=resume,
        )


def _materialize(edges: np.ndarray, source: str, directory: str):
    """Write the edge list to the requested on-disk form; returns a path."""
    if source == "npy":
        return write_npy(f"{directory}/edges.npy", edges)
    if source == "bin":
        return write_bin(f"{directory}/edges.bin", edges)
    write_shards(f"{directory}/shards", edges, shard_edges=max(1, len(edges) // 5))
    return f"{directory}/shards"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--communities", type=int, default=200,
                    help="number of planted communities")
    ap.add_argument("--chunk", type=int, default=8192)
    ap.add_argument("--prefetch", type=int, default=1)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=4096)
    ap.add_argument("--agg-backend", choices=("merge", "lexsort"),
                    default="merge",
                    help="superedge aggregation: two-level sorted-merge "
                         "(kernels/merge) or the lexsort re-sort baseline")
    ap.add_argument("--iterations", type=int, default=30)
    ap.add_argument("--repulsion", default="exact",
                    choices=("exact", "grid", "grid_pallas", "grid_dense"),
                    help="FA2 repulsion backend for the supergraph layout "
                         "(core/forceatlas2.py backend matrix)")
    ap.add_argument("--grid-rebuild", type=int, default=1,
                    help="re-bin/re-sort grid cells every k layout iterations")
    ap.add_argument("--stop-tolerance", type=float, default=0.0,
                    help="FA2 adaptive stop: freeze the layout scan once "
                         "global swing <= tol * traction (0 = fixed count)")
    ap.add_argument("--min-iterations", type=int, default=0,
                    help="never stop the layout before this many iterations")
    ap.add_argument("--init", default="random",
                    choices=("random", "degree", "bfs"),
                    help="FA2 initial positions (core/forceatlas2.py)")
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--source", choices=("memory", "npy", "bin", "shards"),
                    default="memory",
                    help="edge source for the streamed run (non-memory "
                         "forms are written to a temp dir first)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for streaming detect/supergraph "
                         "checkpoints (atomic .npz + meta.json, "
                         "repro/resilience/checkpoint.py); also installs "
                         "a SIGTERM handler that checkpoints at the next "
                         "chunk boundary and exits cleanly")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint every N chunk boundaries "
                         "(0 = round boundaries only)")
    ap.add_argument("--checkpoint-keep", type=int, default=3,
                    help="keep the newest K checkpoints")
    ap.add_argument("--resume", action="store_true",
                    help="resume the streamed run from the latest valid "
                         "checkpoint in --checkpoint-dir")
    ap.add_argument("--nan-guard", action="store_true",
                    help="FA2 divergence sentinel: roll back and damp "
                         "speed on non-finite forces instead of "
                         "propagating NaNs into the layout")
    ap.add_argument("--shard", choices=("none", "chunks", "detect", "layout", "all"),
                    default="none",
                    help="multi-device mode over a 1-D mesh of all local "
                         "devices: 'chunks' row-shards chunk buffers only "
                         "(placement; scatter ties may break differently "
                         "than one device), 'detect' shards the per-chunk "
                         "edge passes and 'layout' node-partitions FA2 "
                         "(both bit-identical), 'all' does everything "
                         "(on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    add_obs_args(ap)
    args = ap.parse_args()

    with obs_session(args):
        _run(args)


def _run(args) -> None:
    from repro.core.pipeline import default_config
    from repro.graph import mode_degree, planted_partition

    n = args.nodes
    edges, _ = planted_partition(n, args.communities, 0.12, 2e-4, seed=args.seed)
    delta = mode_degree(edges, n)
    print(f"graph: {n} nodes, {len(edges)} edges, mode degree δ={delta}")

    cfg = default_config(n, len(edges), delta, rounds=args.rounds,
                         iterations=args.iterations,
                         repulsion=args.repulsion,
                         grid_rebuild=args.grid_rebuild,
                         stop_tolerance=args.stop_tolerance,
                         min_iterations=args.min_iterations,
                         init=args.init,
                         nan_guard=args.nan_guard)
    cfg = replace(cfg, scoda=replace(cfg.scoda, block_size=args.block_size))

    ckpt = None
    if args.checkpoint_dir:
        ckpt = StreamCheckpointer(
            args.checkpoint_dir, every_chunks=args.checkpoint_every,
            keep=args.checkpoint_keep, exit_on_preempt=True,
        )
        ckpt.install_preemption_handler()
        print(f"checkpointing to {args.checkpoint_dir} "
              f"(every={args.checkpoint_every or 'round boundaries'}, "
              f"keep={args.checkpoint_keep}; SIGTERM checkpoints and exits)")
    elif args.resume:
        raise SystemExit("--resume requires --checkpoint-dir")

    res_one = biggraphvis(edges, n, cfg)
    mesh = None
    if args.shard != "none":
        from repro.launch.mesh import make_stream_mesh

        mesh = make_stream_mesh()
        print(f"mesh: {mesh.size} devices ({jax.default_backend()})")
    runner = StreamRunner(cfg, StreamRunnerConfig(
        stream=StreamConfig(
            chunk_size=args.chunk, prefetch=args.prefetch,
            agg_backend=args.agg_backend,
            shard_detect=args.shard in ("detect", "all"),
            shard_layout=args.shard in ("layout", "all"),
        ),
        shard_chunks=args.shard in ("chunks", "all"),
    ), mesh=mesh)
    try:
        with tempfile.TemporaryDirectory() as tmp:
            if args.source == "memory":
                src = edges
            else:
                src = _materialize(edges, args.source, tmp)
                print(f"streaming from {args.source} store: {src}")
            res_str = runner.run(src, n, checkpoint=ckpt, resume=args.resume)
    except Preempted as e:
        print(f"preempted: {e} — checkpoint saved, exiting cleanly "
              f"(restart with --resume)")
        raise SystemExit(0)
    if res_str.stream.resumed_at:
        print(f"resumed from checkpoint at {res_str.stream.resumed_at}")

    match = (
        np.array_equal(res_one.labels, res_str.labels)
        and np.array_equal(np.asarray(res_one.supergraph.edges),
                           np.asarray(res_str.supergraph.edges))
        and np.array_equal(res_one.sizes, res_str.sizes)
    )
    s = res_str.stream
    print(f"streamed == one-shot: {match}")
    print(f"supernodes={res_str.n_supernodes} superedges={res_str.n_superedges} "
          f"Q={res_str.modularity:.3f}")
    print(f"passes={s.passes} chunks={s.chunks} chunk_size={s.chunk_size} "
          f"throughput={s.edges_per_s / 1e6:.2f}M edges/s")
    print(f"overlap: host_fill={s.host_fill_s * 1e3:.1f}ms "
          f"copy_stall={s.copy_stall_s * 1e3:.1f}ms of {s.seconds * 1e3:.1f}ms")
    print(f"peak host bytes: streamed={s.peak_host_bytes:,} "
          f"(in-memory edge list={edges.nbytes:,})")
    print(f"peak device bytes: streamed={s.peak_device_bytes:,} "
          f"one-shot={res_one.stream.peak_device_bytes:,} "
          f"(one-shot input residency={oneshot_device_bytes(len(edges), n):,})")


if __name__ == "__main__":
    main()
