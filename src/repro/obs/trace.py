"""Nested wall-clock span tracing — the repo's one timing instrument.

A ``Tracer`` hands out context-manager spans::

    with tracer.span("detect.chunk", chunk=i):
        ...

Spans nest through a **thread-local** stack, so concurrent callers (the
serve engine's tick loop, the prefetch ring's staging fills) each build
their own correctly-parented tree while completed spans land in one
shared, locked list. Timestamps are ``time.perf_counter`` relative to the
tracer's construction, so every span of a process shares one clock.

Everything here is host-side: a span brackets the *dispatch* of jitted
work, not its device execution (JAX is async). Stages that must attribute
device time block inside their span exactly where the pre-obs code called
``block_until_ready`` — the tracer never adds synchronization of its own,
which is how the ``benchmarks/obs_bench`` ≤ 3 % overhead gate holds.

Disabled tracers (``Tracer(enabled=False)``, the module's ``NULL_TRACER``,
and the process-global default before ``enable_tracing()``) return a
shared no-op span: one attribute check + one call per ``span()``, no
allocation, no lock.

Exports: Chrome trace-event JSON (``to_chrome`` — loadable by Perfetto /
``chrome://tracing``), JSON-lines (``to_jsonl``), and an indented text
tree (``format_tree``) for terminals and docs.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from dataclasses import dataclass, field


@dataclass
class Span:
    """One completed span. ``t0``/``t1`` are seconds on the tracer's
    clock (0 = tracer construction); ``parent`` is the enclosing span's
    ``span_id`` or None for a root; ``tid`` is the OS thread ident."""

    name: str
    t0: float
    t1: float
    span_id: int
    parent: int | None = None
    tid: int = 0
    attrs: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class _NullSpan:
    """Shared no-op span for disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class _SpanHandle:
    """Live (open) span: context manager pushed on the thread's stack."""

    __slots__ = ("_tracer", "name", "attrs", "span_id", "parent", "t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = next(tracer._ids)
        self.parent = None
        self.t0 = 0.0

    def set(self, **attrs):
        """Attach/overwrite attributes on the open span."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        stack = self._tracer._stack()
        if stack:
            self.parent = stack[-1].span_id
        stack.append(self)
        self.t0 = self._tracer._now()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer._now()
        stack = self._tracer._stack()
        # Tolerate out-of-order exits (a caller leaking a span) by popping
        # back to this handle instead of corrupting deeper frames.
        while stack and stack[-1] is not self:
            stack.pop()
        if stack:
            stack.pop()
        self._tracer._record(
            Span(
                name=self.name,
                t0=self.t0,
                t1=t1,
                span_id=self.span_id,
                parent=self.parent,
                tid=threading.get_ident(),
                attrs=self.attrs,
            )
        )
        return False


class Tracer:
    """Thread-safe collector of nested wall-clock spans."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._epoch = time.perf_counter()
        self._ids = itertools.count(1)  # thread-safe in CPython
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._local = threading.local()

    # -- recording ----------------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, span: Span) -> None:
        with self._lock:
            self._spans.append(span)

    def span(self, name: str, **attrs):
        """Open a nested span; use as ``with tracer.span("phase"):``."""
        if not self.enabled:
            return NULL_SPAN
        return _SpanHandle(self, name, attrs)

    # -- inspection ---------------------------------------------------------

    def spans(self) -> list[Span]:
        """Snapshot of completed spans, in completion order."""
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    def span_names(self) -> set[str]:
        return {s.name for s in self.spans()}

    def children(self, span_id: int | None) -> list[Span]:
        """Completed spans whose parent is ``span_id`` (None = roots),
        ordered by start time."""
        return sorted(
            (s for s in self.spans() if s.parent == span_id),
            key=lambda s: s.t0,
        )

    # -- exporters ----------------------------------------------------------

    def to_chrome(self, path: str) -> str:
        """Write the Chrome trace-event (Perfetto-loadable) ``.trace.json``:
        one complete ("ph": "X") event per span, µs timestamps, span
        attributes under "args". Returns ``path``."""
        pid = os.getpid()
        events = [
            {
                "name": s.name,
                "ph": "X",
                "ts": s.t0 * 1e6,
                "dur": max(s.duration, 0.0) * 1e6,
                "pid": pid,
                "tid": s.tid,
                "args": {k: _jsonable(v) for k, v in s.attrs.items()},
            }
            for s in self.spans()
        ]
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        return path

    def to_jsonl(self, path: str) -> str:
        """Write one JSON object per span (name, t0, t1, duration, span_id,
        parent, tid, attrs) — the grep/pandas-friendly log form."""
        with open(path, "w") as f:
            for s in self.spans():
                f.write(
                    json.dumps(
                        {
                            "name": s.name,
                            "t0": s.t0,
                            "t1": s.t1,
                            "duration": s.duration,
                            "span_id": s.span_id,
                            "parent": s.parent,
                            "tid": s.tid,
                            "attrs": {
                                k: _jsonable(v) for k, v in s.attrs.items()
                            },
                        }
                    )
                    + "\n"
                )
        return path

    def format_tree(self, max_children: int = 8) -> str:
        """Indented text rendering of the span forest (first
        ``max_children`` children per span, a summary line for the rest)."""
        lines: list[str] = []

        def walk(span: Span, depth: int) -> None:
            lines.append(
                f"{'  ' * depth}{span.name:<24} {span.duration * 1e3:9.2f} ms"
            )
            kids = self.children(span.span_id)
            for kid in kids[:max_children]:
                walk(kid, depth + 1)
            if len(kids) > max_children:
                rest = kids[max_children:]
                total = sum(k.duration for k in rest)
                lines.append(
                    f"{'  ' * (depth + 1)}… {len(rest)} more "
                    f"{total * 1e3:9.2f} ms"
                )

        for root in self.children(None):
            walk(root, 0)
        return "\n".join(lines)


def _jsonable(v):
    """Span attribute → JSON-safe scalar (numpy ints, tile specs, …)."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    try:
        return int(v)
    except (TypeError, ValueError):
        try:
            return float(v)
        except (TypeError, ValueError):
            return repr(v)


NULL_TRACER = Tracer(enabled=False)

# Process-global default: subsystems fall back to this when no tracer was
# threaded through their config, so a CLI flag can light up the whole
# pipeline without touching call signatures. Disabled until
# ``enable_tracing()``.
_GLOBAL = NULL_TRACER


def get_tracer() -> Tracer:
    """The process-global tracer (disabled no-op until enabled)."""
    return _GLOBAL


def set_tracer(tracer: Tracer | None) -> Tracer:
    """Install ``tracer`` as the process-global default (None resets to
    the disabled NULL_TRACER). Returns the installed tracer."""
    global _GLOBAL
    _GLOBAL = tracer if tracer is not None else NULL_TRACER
    return _GLOBAL


def enable_tracing() -> Tracer:
    """Install (and return) a fresh enabled process-global tracer — the
    ``--trace-out`` CLI entry point."""
    return set_tracer(Tracer(enabled=True))
