"""Unified tracing/metrics subsystem — the one instrumentation layer every
subsystem reports through (the paper's claims are performance claims; this
is where "where did this request's 40 ms go?" gets answered across
stream → layout → render → serve boundaries).

Three zero-dependency pieces:

* ``Tracer`` (``repro.obs.trace``) — nested wall-clock spans via context
  managers with thread-local span stacks, exported as Chrome
  trace-event/Perfetto JSON, JSONL, or an indented text tree.
* ``MetricsRegistry`` (``repro.obs.metrics``) — process-global named
  counters / gauges / log-bucket histograms (p50/p99 without numpy);
  ``REGISTRY`` is the global instance the stats dataclasses publish into.
* meters (``repro.obs.meters``) — ``jit_compile_count`` (idempotent
  ``jax.monitoring`` compile-event listener; moved here from
  ``repro/serve/tiles.py``), live-array/device-memory gauges, and the
  ``jax.profiler.trace`` wrapper behind every launcher's ``--profile``.

Wiring: ``StreamConfig.obs`` / ``BGVConfig.obs`` / ``RenderConfig.obs``
carry an explicit ``Tracer``; subsystems fall back to the process-global
tracer (``enable_tracing()`` / ``get_tracer()``), which is what the
``--trace-out`` / ``--metrics-out`` / ``--profile`` flags on every
``repro.launch`` CLI toggle (``repro.obs.cli``). Tracing off costs one
attribute check per span site; tracing on is gated ≤ 3 % overhead on the
stream bench by ``benchmarks/obs_bench.py`` (CI ``obs-smoke``).

Importing ``repro.obs`` pulls only the stdlib pieces; the jax-facing
meters load lazily (PEP 562).
"""
import importlib

from repro.obs.metrics import (  # noqa: F401  (stdlib-only, eager)
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    REGISTRY,
    counter,
    gauge,
    histogram,
)
from repro.obs.trace import (  # noqa: F401  (stdlib-only, eager)
    NULL_TRACER,
    Span,
    Tracer,
    enable_tracing,
    get_tracer,
    set_tracer,
)

_LAZY = {
    "add_obs_args": "repro.obs.cli",
    "jit_compile_count": "repro.obs.meters",
    "live_array_bytes": "repro.obs.meters",
    "obs_session": "repro.obs.cli",
    "profile_trace": "repro.obs.meters",
    "register_compile_listener": "repro.obs.meters",
    "update_memory_gauges": "repro.obs.meters",
}

__all__ = sorted(
    [
        "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
        "NULL_TRACER", "Span", "Tracer", "counter", "gauge", "histogram",
        "enable_tracing", "get_tracer", "set_tracer",
    ]
    + list(_LAZY)
)


def __getattr__(name):
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro.obs' has no attribute '{name}'")


def __dir__():
    return sorted(set(globals()) | set(__all__))
