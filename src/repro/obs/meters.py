"""JAX-facing meters: compile events, device/host memory, XLA profiles.

* ``jit_compile_count`` — monotone count of XLA backend compiles via
  ``jax.monitoring`` (moved here from ``repro/serve/tiles.py``; the old
  import path re-exports). Listener registration is **idempotent**: one
  process-wide listener whatever the import path or how many engines are
  constructed — the pre-move code could double-register (and so
  double-count) if a second registration path ever ran. Compile durations
  also land in the ``jax.compile_seconds`` histogram.
* ``update_memory_gauges`` — snapshot ``jax.live_arrays()`` bytes and
  per-device allocator peaks (``device.memory_stats()`` where the backend
  reports them; CPU typically doesn't) into ``jax.*`` gauges.
* ``profile_trace`` — opt-in ``jax.profiler.trace`` wrapper so a CLI flag
  (``--profile DIR``) captures an XLA/TensorBoard profile around any
  phase, degrading to a no-op where the profiler is unavailable.

Importing this module does NOT import jax (lazy inside functions), so
``repro.obs`` stays importable in jax-free tooling contexts.
"""
from __future__ import annotations

import contextlib
import threading

from repro.obs.metrics import REGISTRY

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_register_lock = threading.Lock()
_listener_registered = False


def _on_compile_event(name, *args, **kwargs):
    if name == _COMPILE_EVENT:
        REGISTRY.counter("jax.compiles").inc()
        if args:
            REGISTRY.histogram("jax.compile_seconds").record(args[0])


def register_compile_listener() -> bool:
    """Idempotently attach the compile-event listener. Returns True the
    one time it actually registers, False every call after — however many
    modules, engines, or re-imports ask."""
    global _listener_registered
    with _register_lock:
        if _listener_registered:
            return False
        from jax import monitoring

        monitoring.register_event_duration_secs_listener(_on_compile_event)
        _listener_registered = True
        return True


def jit_compile_count() -> int:
    """Monotone count of XLA backend compiles in this process (cache hits
    — including persistent-cache hits — do not fire the event). Counting
    starts at the first call; callers take deltas. The serve benchmark's
    "steady-state ticks trigger zero recompilation" check is a flat delta
    across the measured phase."""
    register_compile_listener()
    return int(REGISTRY.counter("jax.compiles").value)


def live_array_bytes() -> int:
    """Total bytes of every live jax array in the process — the
    host-visible view of device residency (covers backends whose
    ``memory_stats`` is unavailable, e.g. CPU)."""
    import jax

    total = 0
    for a in jax.live_arrays():
        try:
            total += a.size * a.dtype.itemsize
        except Exception:  # deleted/donated buffers race the walk
            pass
    return total


def update_memory_gauges(registry=None) -> dict:
    """Refresh the memory gauges and return their snapshot:

    * ``jax.live_bytes`` — current ``live_arrays`` total (gauge) and its
      process high-watermark ``jax.live_bytes_peak``.
    * ``jax.dev<i>.peak_bytes`` — per-device allocator peak from
      ``device.memory_stats()["peak_bytes_in_use"]`` where the backend
      reports it (GPU/TPU; absent on CPU).
    """
    import jax

    reg = registry if registry is not None else REGISTRY
    live = live_array_bytes()
    reg.gauge("jax.live_bytes").set(live)
    reg.gauge("jax.live_bytes_peak").set_max(live)
    out = {"jax.live_bytes": live}
    for i, dev in enumerate(jax.devices()):
        stats = None
        try:
            stats = dev.memory_stats()
        except Exception:
            pass
        if stats and "peak_bytes_in_use" in stats:
            name = f"jax.dev{i}.peak_bytes"
            reg.gauge(name).set_max(stats["peak_bytes_in_use"])
            out[name] = stats["peak_bytes_in_use"]
    return out


@contextlib.contextmanager
def profile_trace(out_dir: str | None):
    """Capture a ``jax.profiler`` trace of the enclosed block into
    ``out_dir`` (viewable in Perfetto/TensorBoard). ``None`` or an
    unavailable profiler degrade to a plain no-op block — callers treat a
    missing profile as a missing artifact, never an error."""
    if not out_dir:
        yield
        return
    try:
        import jax

        ctx = jax.profiler.trace(str(out_dir))
    except Exception:
        yield
        return
    with ctx:
        yield
