"""Process-global metrics: named counters, gauges, and log-bucket
histograms — the single sink the scattered per-subsystem stats dataclasses
(``StreamStats``, ``RenderStats``, ``BGVResult.timings``, the tile cache
accounting) publish into, and the single source exporters read from.

Zero dependencies and no numpy on the hot path: a histogram is a fixed
array of power-of-two buckets indexed by ``math.frexp`` — O(1) record,
O(buckets) quantile — so per-request serving code can record latencies
without touching the device or allocating.

``REGISTRY`` is the process-global instance (module helpers ``counter`` /
``gauge`` / ``histogram`` resolve against it). Metric names are
dot-namespaced by subsystem: ``stream.*``, ``layout.*``, ``render.*``,
``serve.*``, ``jax.*`` — the glossary lives in README "Observability".
"""
from __future__ import annotations

import json
import math
import threading

# Histogram bucket i covers [2^(i + _EXP_LO - 1), 2^(i + _EXP_LO)).
# Exponent range [-40, 40] spans ~1e-12 .. 1e12 — nanoseconds to
# terabytes — with out-of-range values clamped to the end buckets.
_EXP_LO = -40
_EXP_HI = 40
_N_BUCKETS = _EXP_HI - _EXP_LO + 1


class Counter:
    """Monotone counter. ``inc`` is the only mutator."""

    kind = "counter"

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, v: int = 1) -> None:
        with self._lock:
            self.value += v

    def snapshot(self):
        return self.value


class Gauge:
    """Last-value gauge with ``set`` / ``set_max`` (high-watermark)."""

    kind = "gauge"

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v) -> None:
        self.value = v

    def set_max(self, v) -> None:
        with self._lock:
            if v > self.value:
                self.value = v

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed log2-bucket histogram of positive values.

    ``record`` maps a value to its power-of-two bucket via ``math.frexp``
    (no numpy, no allocation); non-positive values land in a dedicated
    underflow count so latency code never has to pre-filter. Quantiles
    interpolate linearly inside the covering bucket — worst-case relative
    error is the bucket width (2×), plenty for p50/p99 dashboards.
    """

    kind = "histogram"

    __slots__ = ("name", "buckets", "count", "total", "vmin", "vmax",
                 "underflow", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.buckets = [0] * _N_BUCKETS
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.underflow = 0  # values <= 0
        self._lock = threading.Lock()

    @staticmethod
    def bucket_index(v: float) -> int:
        _, e = math.frexp(v)  # v = m * 2^e, m in [0.5, 1)
        return min(max(e - _EXP_LO, 0), _N_BUCKETS - 1)

    @staticmethod
    def bucket_bounds(i: int) -> tuple[float, float]:
        e = i + _EXP_LO
        return (2.0 ** (e - 1), 2.0**e)

    def record(self, v: float) -> None:
        v = float(v)
        with self._lock:
            if v <= 0.0 or v != v:  # non-positive or NaN
                self.underflow += 1
                return
            self.buckets[self.bucket_index(v)] += 1
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """q ∈ [0, 1] → interpolated value; 0.0 with no samples."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.buckets):
            if c == 0:
                continue
            if seen + c >= target:
                lo, hi = self.bucket_bounds(i)
                lo = max(lo, self.vmin)
                hi = min(hi, self.vmax)
                if hi <= lo:
                    return lo
                frac = (target - seen) / c
                return lo + frac * (hi - lo)
            seen += c
        return self.vmax

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    def snapshot(self):
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.p50,
            "p99": self.p99,
            "min": self.vmin if self.count else 0.0,
            "max": self.vmax if self.count else 0.0,
            "underflow": self.underflow,
        }


class MetricsRegistry:
    """Name → metric map with get-or-create accessors.

    Asking for an existing name with a different kind raises — one name,
    one schema, process-wide.
    """

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls(name)
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"not {cls.kind}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get_or_create(name, Histogram)

    def get(self, name: str):
        """The metric registered under ``name`` or None."""
        return self._metrics.get(name)

    def value(self, name: str, default=0):
        """Scalar value of a counter/gauge (default when unregistered)."""
        m = self._metrics.get(name)
        return default if m is None else m.value

    def names(self, prefix: str = "") -> list[str]:
        return sorted(n for n in self._metrics if n.startswith(prefix))

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    def snapshot(self, prefix: str = "") -> dict:
        """{name: scalar-or-histogram-dict} for every matching metric."""
        return {
            n: self._metrics[n].snapshot() for n in self.names(prefix)
        }

    def dump_text(self, prefix: str = "") -> str:
        """Plain-text dump, one ``name value`` line per metric (histograms
        expand to count/mean/p50/p99) — the ``--metrics-out`` /
        ``$GITHUB_STEP_SUMMARY`` format."""
        lines = []
        for n in self.names(prefix):
            m = self._metrics[n]
            if m.kind == "histogram":
                s = m.snapshot()
                lines.append(
                    f"{n} count={s['count']} mean={s['mean']:.6g} "
                    f"p50={s['p50']:.6g} p99={s['p99']:.6g}"
                )
            else:
                v = m.value
                lines.append(
                    f"{n} {v:.6g}" if isinstance(v, float) else f"{n} {v}"
                )
        return "\n".join(lines)

    def to_json(self, path: str, prefix: str = "") -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(prefix), f, indent=2)
        return path


REGISTRY = MetricsRegistry()

# The error/degradation counters (ISSUE 10 satellite): every recovery path in
# the codebase increments one of these at the point of occurrence, and they
# are pre-registered (``ensure_error_counters``) by the subsystems that can
# produce them — so ``dump_text``/``snapshot`` always show them at 0 instead
# of silently omitting "no errors", and CI can assert on their presence.
ERROR_COUNTERS = (
    "errors.io_retries",  # transient store reads retried (resilience.validate)
    "errors.quarantined_chunks",  # chunks replaced by trash padding
    "errors.invalid_edges",  # out-of-range/self-loop rows dropped to trash
    "errors.fa2_recoveries",  # non-finite FA2 iterations rolled back + damped
    "errors.failed_tiles",  # tile renders that returned an error tile
    "errors.shed_tiles",  # queued tile misses shed past the deadline
)


def ensure_error_counters(registry: MetricsRegistry | None = None) -> None:
    """Register every ``errors.*`` counter (at 0) so degradation is visible
    in metric dumps even when nothing has failed yet."""
    reg = registry if registry is not None else REGISTRY
    for name in ERROR_COUNTERS:
        reg.counter(name)


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)
