"""Shared CLI plumbing: every ``repro.launch`` driver grows the same three
observability flags through ``add_obs_args`` + ``obs_session``::

    add_obs_args(parser)
    ...
    with obs_session(args):
        <existing driver body>

* ``--trace-out PATH``  — enable the process-global tracer for the run and
  write the Chrome trace-event (Perfetto-loadable) span file at exit
  (``PATH.jsonl`` alongside it with ``--trace-jsonl``).
* ``--metrics-out PATH`` — write the plain-text metrics dump (the
  ``$GITHUB_STEP_SUMMARY`` format) at exit, after refreshing the memory
  gauges and the compile counter.
* ``--profile DIR``     — capture a ``jax.profiler`` XLA trace of the whole
  run into DIR (no-op where the profiler is unavailable).
"""
from __future__ import annotations

import contextlib

from repro.obs.meters import (
    jit_compile_count,
    profile_trace,
    update_memory_gauges,
)
from repro.obs.metrics import REGISTRY
from repro.obs.trace import enable_tracing, get_tracer


def add_obs_args(ap) -> None:
    g = ap.add_argument_group("observability (repro.obs)")
    g.add_argument("--trace-out", default="",
                   help="write a Chrome-trace/Perfetto span file of the run")
    g.add_argument("--trace-jsonl", action="store_true",
                   help="also write <trace-out>.jsonl (one span per line)")
    g.add_argument("--metrics-out", default="",
                   help="write the plain-text metrics registry dump")
    g.add_argument("--profile", default="",
                   help="capture a jax.profiler XLA trace into this dir")


@contextlib.contextmanager
def obs_session(args):
    """Run the enclosed driver body under the requested instrumentation
    and write the artifacts on the way out. Yields the active tracer."""
    if args.trace_out or args.metrics_out:
        jit_compile_count()  # start the compile meter before any compiles
    tracer = enable_tracing() if args.trace_out else get_tracer()
    try:
        with profile_trace(args.profile or None):
            yield tracer
    finally:
        if args.metrics_out or args.trace_out:
            update_memory_gauges()
        if args.trace_out:
            tracer.to_chrome(args.trace_out)
            print(f"obs: wrote {args.trace_out} "
                  f"({len(tracer.spans())} spans)")
            if args.trace_jsonl:
                print(f"obs: wrote {tracer.to_jsonl(args.trace_out + '.jsonl')}")
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                f.write(REGISTRY.dump_text() + "\n")
            print(f"obs: wrote {args.metrics_out} "
                  f"({len(REGISTRY.names())} metrics)")
        if args.profile:
            print(f"obs: wrote jax profiler trace under {args.profile}")
