"""Dependency-free PNG I/O (stdlib zlib only — no display stack on TPU
hosts, same constraint that shaped ``coloring.write_svg``).

``write_png`` emits 8-bit RGB, one IDAT, filter type 0 on every scanline —
the simplest spec-conformant stream, readable by any viewer. ``read_png``
is the matching subset decoder (8-bit RGB/RGBA, filters 0–2, single image)
used by the round-trip tests and the CI ``render-smoke`` content check; it
is not a general PNG reader.
"""
from __future__ import annotations

import struct
import zlib

import numpy as np

_SIGNATURE = b"\x89PNG\r\n\x1a\n"


def _chunk(tag: bytes, payload: bytes) -> bytes:
    return (
        struct.pack(">I", len(payload))
        + tag
        + payload
        + struct.pack(">I", zlib.crc32(tag + payload) & 0xFFFFFFFF)
    )


def write_png(path: str, image: np.ndarray) -> str:
    """Write an [H, W, 3] uint8 RGB image; returns ``path``."""
    img = np.asarray(image)
    if img.ndim != 3 or img.shape[2] != 3 or img.dtype != np.uint8:
        raise ValueError(
            f"write_png expects [H, W, 3] uint8, got {img.shape} {img.dtype}"
        )
    h, w = img.shape[:2]
    ihdr = struct.pack(">IIBBBBB", w, h, 8, 2, 0, 0, 0)  # 8-bit truecolor
    # Filter byte 0 (None) before every scanline.
    raw = np.empty((h, 1 + w * 3), np.uint8)
    raw[:, 0] = 0
    raw[:, 1:] = img.reshape(h, w * 3)
    idat = zlib.compress(raw.tobytes(), 6)
    with open(path, "wb") as f:
        f.write(_SIGNATURE)
        f.write(_chunk(b"IHDR", ihdr))
        f.write(_chunk(b"IDAT", idat))
        f.write(_chunk(b"IEND", b""))
    return str(path)


def read_png(path: str) -> np.ndarray:
    """Read a PNG written by ``write_png`` (or any 8-bit RGB/RGBA stream
    using only filters 0–2); returns [H, W, 3] uint8."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:8] != _SIGNATURE:
        raise ValueError(f"{path}: not a PNG file")
    pos = 8
    w = h = None
    channels = 3
    idat = b""
    while pos < len(data):
        (length,) = struct.unpack(">I", data[pos : pos + 4])
        tag = data[pos + 4 : pos + 8]
        payload = data[pos + 8 : pos + 8 + length]
        pos += 12 + length
        if tag == b"IHDR":
            w, h, depth, color = struct.unpack(">IIBB", payload[:10])
            if depth != 8 or color not in (2, 6):
                raise ValueError(
                    f"{path}: unsupported PNG (depth={depth}, color={color})"
                )
            channels = 3 if color == 2 else 4
        elif tag == b"IDAT":
            idat += payload
        elif tag == b"IEND":
            break
    if w is None:
        raise ValueError(f"{path}: missing IHDR")
    raw = np.frombuffer(zlib.decompress(idat), np.uint8)
    stride = 1 + w * channels
    raw = raw.reshape(h, stride)
    out = np.zeros((h, w * channels), np.uint8)
    for y in range(h):
        filt, line = raw[y, 0], raw[y, 1:].astype(np.int32)
        if filt == 0:
            out[y] = line
        elif filt == 1:  # Sub: add left pixel
            row = line.reshape(w, channels)
            np.cumsum(row, axis=0, out=row)  # mod-256 via uint8 cast below
            out[y] = (row % 256).reshape(-1)
        elif filt == 2:  # Up: add pixel above
            out[y] = (line + out[y - 1]) % 256
        else:
            raise ValueError(f"{path}: unsupported PNG filter {filt}")
    return out.reshape(h, w, channels)[:, :, :3].copy()
