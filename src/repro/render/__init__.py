"""Streaming GPU rasterization subsystem: density-accumulation rendering
of (positions, sizes, groups, edges) to RGB images on-device, with edges
streamed through the engine's EdgeChunkStream (raster.py) and
dependency-free PNG I/O (png.py)."""
from repro.render.png import read_png, write_png
from repro.render.raster import (
    RenderConfig,
    RenderStats,
    image_summary,
    render,
    render_arrays,
)
