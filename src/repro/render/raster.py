"""Streaming density-accumulation rasterizer: (positions, sizes, groups,
edges) → RGB image, entirely on-device (paper §4.3's colored drawing at
BigGraphVis scale — the stage ``coloring.write_svg``'s per-edge Python
string loop could not scale past ~2·10⁵ nodes).

Accumulation model (GMine/BatchLayout lesson: the drawing stage must be
batch-parallel too):

* **edges** — splatted as ``RenderConfig.edge_samples`` points along each
  segment, each sample crediting the color group of its nearer endpoint.
  Chunks stream through the engine's ``EdgeChunkStream`` double-buffered
  path (``repro.data.edge_store`` sources all work), so host and device
  residency are independent of |E|; per-chunk raster timing lands in
  ``StreamStats.raster_update_s`` under ``RenderConfig.time_raster``.
* **nodes** — radius-∝-√size disks, dense per-pixel coverage.

Both passes accumulate **int32 counts** into a per-community-color buffer
[n_groups, H·ss, W·ss] (``kernels/raster``: Pallas on TPU, XLA scatter
elsewhere). Integer adds are associative, so a chunked render is
bit-identical to the one-shot render of the same edge list — the
renderer's analogue of the engine's chunked==one-shot contract
(tests/test_render.py). Tone mapping is log1p density → palette-weighted
color + saturating alpha, composited edges-under-nodes over the
background, then box-downsampled by the supersample factor.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coloring import PALETTE
from repro.core.stream import EdgeChunkStream, StreamStats, tree_bytes
from repro.data.edge_store import as_edge_store
from repro.kernels.raster import ops as raster_ops
from repro.obs.metrics import REGISTRY
from repro.obs.trace import get_tracer

_INT32_MAX = np.iinfo(np.int32).max
_MAX_INC = 1 << 20  # per-sample increment clamp (keeps counts far from 2³¹)

# Node disks split by pixel radius: disks ≤ _SMALL_R rasterize via a
# _BBOX×_BBOX bounding-box scatter (work ∝ n·_BBOX², not n·H·W); only the
# few larger disks take the dense per-pixel kernel. _BBOX covers every
# pixel a radius-_SMALL_R disk can touch (2·(_SMALL_R+1)) and the per-pixel
# inside test is identical, so hybrid == all-dense, bit for bit.
_SMALL_R = 8.0
_BBOX = 2 * (int(_SMALL_R) + 1)


@dataclass(frozen=True)
class RenderConfig:
    """Rasterizer knobs. ``supersample`` renders at k× resolution and
    box-downsamples, ``edge_samples`` is points splatted per edge segment,
    ``backend`` is the kernels/raster dispatch (auto/ref/pallas/interpret),
    ``chunk_size``/``prefetch`` drive the EdgeChunkStream edge pass, and
    ``time_raster`` blocks per chunk to fill StreamStats raster timing
    (costs copy/compute overlap; leave off outside benchmarks).

    ``viewport`` renders a fixed world rectangle ``(x0, y0, x1, y1)``
    instead of auto-fitting the scene's bounding box: the rect maps onto
    the full image (no ``margin``), off-rect geometry is clipped by the
    rasterizer's bounds checks, and splats crossing the rect boundary are
    cut exactly at the pixel edge — so a grid of adjacent viewports tiles
    the scene seamlessly (the tile-pyramid service, repro/serve/tiles.py).
    Non-square rects keep the uniform (min-axis) scale, centered."""

    width: int = 1024
    height: int = 1024
    supersample: int = 1
    edge_samples: int = 8
    draw_edges: bool = True
    draw_nodes: bool = True
    backend: str = "auto"
    chunk_size: int = 1 << 16  # edges resident on device per raster chunk
    prefetch: int = 1
    margin: float = 0.04  # blank border as a fraction of the image
    viewport: tuple | None = None  # world rect (x0, y0, x1, y1) to render
    background: tuple = (255, 255, 255)
    edge_gain: float = 1.0  # density → intensity gains (log1p tone map)
    node_gain: float = 4.0
    edge_alpha: float = 0.85  # max edge-layer opacity
    min_radius_px: float = 1.0  # node radius floor, in output pixels
    max_radius_frac: float = 0.125  # radius cap as a fraction of min(H, W)
    time_raster: bool = False
    # Optional repro.obs.Tracer for the render spans (render.nodes /
    # render.edges / render.compose); None falls back to the
    # process-global tracer — disabled (no-op) by default.
    obs: object = None


@dataclass
class RenderStats:
    """Per-render accounting. ``peak_device_bytes`` is the analytic
    resident footprint (accumulation buffers + node state + in-flight
    chunk buffers) — independent of |E|, the number render_bench.py
    checks. ``stream`` carries the edge pass's engine-level accounting
    (chunks, stall/fill overlap, per-chunk raster timing)."""

    width: int = 0
    height: int = 0
    supersample: int = 1
    n_groups: int = 0
    nodes_drawn: int = 0
    edges_streamed: int = 0
    chunks: int = 0
    node_raster_s: float = 0.0
    edge_raster_s: float = 0.0
    compose_s: float = 0.0
    seconds: float = 0.0
    peak_device_bytes: int = 0
    stream: StreamStats | None = None
    timings: dict = field(default_factory=dict)

    @property
    def edges_per_s(self) -> float:
        return self.edges_streamed / self.edge_raster_s if self.edge_raster_s else 0.0

    @property
    def mpixels_per_s(self) -> float:
        px = self.width * self.height * self.supersample**2
        return px / self.seconds / 1e6 if self.seconds else 0.0

    def publish(self, registry=None) -> None:
        """Mirror this render's accounting into the metrics registry
        (``render.*`` — README "Observability" glossary). Gauges hold the
        last render; counters/watermarks accumulate across renders."""
        reg = registry if registry is not None else REGISTRY
        reg.counter("render.renders").inc()
        reg.counter("render.edges").inc(self.edges_streamed)
        for name, value in (
            ("render.node_raster_s", self.node_raster_s),
            ("render.edge_raster_s", self.edge_raster_s),
            ("render.compose_s", self.compose_s),
            ("render.seconds", self.seconds),
            ("render.edges_per_s", self.edges_per_s),
            ("render.mpixels_per_s", self.mpixels_per_s),
        ):
            reg.gauge(name).set(value)
        reg.gauge("render.peak_device_bytes").set_max(self.peak_device_bytes)


def _fit_transform(pos: np.ndarray, ws: int, hs: int, margin: float):
    """Uniform scale + center mapping world coords into the supersampled
    image with a blank margin, y flipped (world y-up → raster y-down)."""
    lo = pos.min(axis=0)
    hi = pos.max(axis=0)
    span = np.maximum(hi - lo, 1e-6)
    scale = (1.0 - 2.0 * margin) * min(ws / span[0], hs / span[1])
    center = (lo + hi) / 2.0
    return float(scale), float(center[0]), float(center[1])


def _viewport_transform(viewport, ws: int, hs: int):
    """Uniform scale + center mapping the fixed world rect onto the full
    image — the same (scale, ox, oy) form as ``_fit_transform`` so both
    paths share the pixel-coordinate arithmetic bit for bit."""
    x0, y0, x1, y1 = (float(c) for c in viewport)
    if not (x1 > x0 and y1 > y0):
        raise ValueError(f"degenerate viewport {viewport!r}: need x1>x0, y1>y0")
    scale = min(ws / (x1 - x0), hs / (y1 - y0))
    return scale, (x0 + x1) / 2.0, (y0 + y1) / 2.0


@functools.partial(
    jax.jit, static_argnames=("n_groups", "hs", "ws", "backend")
)
def _small_disk_splat(
    px: jnp.ndarray,  # [m] float32 pixel centers (small-radius subset)
    py: jnp.ndarray,
    r: jnp.ndarray,  # [m] float32 radii (≤ 0 rows draw nothing)
    groups: jnp.ndarray,  # [m] int32
    n_groups: int,
    hs: int,
    ws: int,
    backend: str,
) -> jnp.ndarray:
    """Bounding-box rasterization of small disks → flat [G·hs·ws] counts.

    Same per-pixel predicate as ``kernels/raster`` ``disk_accum`` ((x−cx)²
    + (y−cy)² ≤ r²) over the _BBOX×_BBOX pixel grid around each center;
    pixels outside the disk, the image, or the palette drop out via the
    scatter's INT32_MAX marker.
    """
    bx = jnp.floor(px).astype(jnp.int32) - _BBOX // 2  # [m]
    by = jnp.floor(py).astype(jnp.int32) - _BBOX // 2
    d = jnp.arange(_BBOX, dtype=jnp.int32)
    xs = bx[:, None] + d[None, :]  # [m, B]
    ys = by[:, None] + d[None, :]
    dx2 = (xs.astype(jnp.float32) - px[:, None]) ** 2  # [m, B]
    dy2 = (ys.astype(jnp.float32) - py[:, None]) ** 2
    inside = dy2[:, :, None] + dx2[:, None, :] <= (r * r)[:, None, None]
    ok = (
        inside
        & (r > 0)[:, None, None]
        & ((groups >= 0) & (groups < n_groups))[:, None, None]
        & ((ys >= 0) & (ys < hs))[:, :, None]
        & ((xs >= 0) & (xs < ws))[:, None, :]
    )
    flat = (groups[:, None, None] * hs + ys[:, :, None]) * ws + xs[:, None, :]
    flat = jnp.where(ok, flat, _INT32_MAX)
    return raster_ops.count_scatter_into(
        jnp.zeros(n_groups * hs * ws, jnp.int32), flat.reshape(-1), None, backend
    )


def _pad_pow2(arrs: tuple, fill, lo: int = 16) -> tuple:
    """Pad same-length 1-D host arrays to the next power of two (≥ lo) so
    jitted shapes recompile O(log n) times, not per scene."""
    m = len(arrs[0])
    target = max(lo, 1 << max(0, (m - 1).bit_length()))
    return tuple(
        np.concatenate([a, np.full(target - m, fill_v, a.dtype)])
        for a, fill_v in zip(arrs, fill)
    )


def _node_pass(px, py, r_px, groups, n_groups, hs, ws, backend):
    """Hybrid node rasterization: bbox scatter for small disks, dense
    per-pixel kernel for the (few) large ones. Integer counts over
    disjoint node subsets sum to exactly the all-dense result."""
    small = (r_px > 0) & (r_px <= _SMALL_R)
    large = r_px > _SMALL_R
    acc = None
    if small.any():
        args = _pad_pow2(
            (px[small].astype(np.float32), py[small].astype(np.float32),
             r_px[small].astype(np.float32), groups[small]),
            fill=(0.0, 0.0, 0.0, -1),
        )
        acc = _small_disk_splat(
            *(jnp.asarray(a) for a in args), n_groups, hs, ws, backend
        ).reshape(n_groups, hs, ws)
    if large.any():
        args = _pad_pow2(
            (px[large].astype(np.float32), py[large].astype(np.float32),
             r_px[large].astype(np.float32), groups[large]),
            fill=(0.0, 0.0, 0.0, -1),
        )
        dense = raster_ops.disk_accum(
            *(jnp.asarray(a) for a in args), n_groups, hs, ws, backend
        )
        acc = dense if acc is None else acc + dense
    return acc


@functools.partial(
    jax.jit,
    donate_argnums=(0,),
    static_argnames=("hs", "ws", "samples", "n_groups", "backend"),
)
def _edge_splat_update(
    acc: jnp.ndarray,  # [n_groups·hs·ws] int32, donated
    chunk: jnp.ndarray,  # [C, 2] int32 (trash id n_nodes = padding)
    pxy_ext: jnp.ndarray,  # [n_nodes+1, 2] float32 pixel coords
    groups_ext: jnp.ndarray,  # [n_nodes+1] int32
    winc: jnp.ndarray | None,  # [C] int32 per-edge increments (None = 1)
    hs: int,
    ws: int,
    samples: int,
    n_groups: int,
    backend: str,
):
    """One chunk of the streamed edge pass: sample segments, scatter-add."""
    n_nodes = pxy_ext.shape[0] - 1
    u, v = chunk[:, 0], chunk[:, 1]
    valid = (u >= 0) & (u < n_nodes) & (v >= 0) & (v < n_nodes)
    ui = jnp.clip(u, 0, n_nodes)
    vi = jnp.clip(v, 0, n_nodes)
    pu = pxy_ext[ui]  # [C, 2]
    pv = pxy_ext[vi]
    t = (jnp.arange(samples, dtype=jnp.float32) + 0.5) / samples  # [S]
    p = pu[:, None, :] + t[None, :, None] * (pv - pu)[:, None, :]  # [C, S, 2]
    ix = jnp.floor(p[..., 0]).astype(jnp.int32)
    iy = jnp.floor(p[..., 1]).astype(jnp.int32)
    # Each sample credits the color group of its nearer endpoint.
    g = jnp.where(
        t[None, :] < 0.5, groups_ext[ui][:, None], groups_ext[vi][:, None]
    )
    # Samples outside the image DROP (clamping would smear density streaks
    # along the border — e.g. edges incident to dead nodes whose positions
    # sit outside the alive-node viewport the transform was fitted to).
    ok = (
        valid[:, None]
        & (g >= 0) & (g < n_groups)
        & (ix >= 0) & (ix < ws)
        & (iy >= 0) & (iy < hs)
    )
    flat = jnp.where(ok, (g * hs + iy) * ws + ix, _INT32_MAX)
    inc = (
        None
        if winc is None
        else jnp.broadcast_to(winc[:, None], flat.shape).reshape(-1)
    )
    return raster_ops.count_scatter_into(acc, flat.reshape(-1), inc, backend)


@functools.partial(jax.jit, static_argnames=("ss",))
def _compose(
    node_acc,  # [G, hs, ws] int32 | None
    edge_acc,  # [G, hs, ws] int32 | None
    palette,  # [G, 3] float32
    background,  # [3] float32
    node_gain,
    edge_gain,
    edge_alpha,
    ss: int,
):
    """Tone-map (log1p density), blend palette colors, composite
    edges-under-nodes over the background, box-downsample by ``ss``."""

    def layer(acc, gain):
        i = jnp.log1p(gain * acc.astype(jnp.float32))  # [G, hs, ws]
        tot = jnp.sum(i, axis=0)
        rgb = jnp.einsum("ghw,gc->hwc", i, palette)
        rgb = rgb / jnp.maximum(tot, 1e-9)[..., None]
        alpha = 1.0 - jnp.exp(-tot)
        return rgb, alpha

    some = node_acc if node_acc is not None else edge_acc
    img = jnp.broadcast_to(background, (*some.shape[1:], 3))
    if edge_acc is not None:
        rgb, a = layer(edge_acc, edge_gain)
        a = (edge_alpha * a)[..., None]
        img = a * rgb + (1.0 - a) * img
    if node_acc is not None:
        rgb, a = layer(node_acc, node_gain)
        a = a[..., None]
        img = a * rgb + (1.0 - a) * img
    h, w = img.shape[0] // ss, img.shape[1] // ss
    img = img.reshape(h, ss, w, ss, 3).mean(axis=(1, 3))
    return jnp.clip(jnp.round(img), 0, 255).astype(jnp.uint8)


def render_arrays(
    pos,
    radii,
    groups,
    edge_source=None,
    *,
    edge_weights=None,
    cfg: RenderConfig | None = None,
) -> tuple[np.ndarray, RenderStats]:
    """Rasterize a laid-out graph → ([H, W, 3] uint8 image, RenderStats).

    ``pos`` [n, 2] world coordinates, ``radii`` [n] world radii (≤ 0 slots
    are dead padding and draw nothing), ``groups`` [n] palette indices.
    ``edge_source`` is any engine edge source over node ids < n (array,
    ``EdgeStore``, or path — repro/data/edge_store.py); ids ≥ n (the
    stream's trash padding) draw nothing. ``edge_weights`` (host [E]
    array, in-memory sources only) thickens edges by splat increment.
    """
    cfg = cfg or RenderConfig()
    pos = np.asarray(pos, np.float32).reshape(-1, 2)
    radii = np.asarray(radii, np.float32).reshape(-1)
    groups = np.asarray(groups, np.int32).reshape(-1)
    n = len(pos)
    if len(radii) != n or len(groups) != n:
        raise ValueError(
            f"pos/radii/groups disagree: {n}/{len(radii)}/{len(groups)} rows"
        )
    ss = max(1, int(cfg.supersample))
    hs, ws = cfg.height * ss, cfg.width * ss
    n_groups = len(PALETTE)
    if n_groups * hs * ws >= 2**31:
        raise ValueError(
            f"accumulation buffer {n_groups}×{hs}×{ws} overflows int32 "
            "flat indexing — lower resolution or supersample"
        )
    stats = RenderStats(
        width=cfg.width, height=cfg.height, supersample=ss, n_groups=n_groups
    )
    t_start = time.perf_counter()

    alive = radii > 0
    if cfg.viewport is not None:
        scale, ox, oy = _viewport_transform(cfg.viewport, ws, hs)
    else:
        bounds_src = pos[alive] if alive.any() else pos
        scale, ox, oy = _fit_transform(bounds_src, ws, hs, cfg.margin)
    px = (pos[:, 0] - ox) * scale + ws / 2.0
    py = hs / 2.0 - (pos[:, 1] - oy) * scale  # y-up world → y-down raster
    r_px = np.where(
        alive,
        np.clip(
            radii * scale, cfg.min_radius_px * ss,
            cfg.max_radius_frac * min(hs, ws),
        ),
        0.0,
    ).astype(np.float32)

    tr = cfg.obs if cfg.obs is not None else get_tracer()
    node_acc = None
    if cfg.draw_nodes and alive.any():
        t0 = time.perf_counter()
        with tr.span("render.nodes", n=n, hs=hs, ws=ws):
            node_acc = _node_pass(
                px.astype(np.float32), py.astype(np.float32), r_px, groups,
                n_groups, hs, ws, cfg.backend,
            )
            jax.block_until_ready(node_acc)
        stats.node_raster_s = time.perf_counter() - t0
        stats.nodes_drawn = int(alive.sum())

    edge_acc = None
    sstats = None
    if cfg.draw_edges and edge_source is not None:
        store = as_edge_store(edge_source)
        stream = EdgeChunkStream(store, n, cfg.chunk_size)
        sstats = StreamStats(chunk_size=stream.chunk_size)
        pxy_ext = jnp.asarray(
            np.concatenate([np.stack([px, py], 1), [[0.0, 0.0]]]).astype(
                np.float32
            )
        )
        groups_ext = jnp.asarray(np.concatenate([groups, [0]]).astype(np.int32))
        acc = jnp.zeros(n_groups * hs * ws, jnp.int32)
        cs = stream.chunk_size
        weights = (
            None if edge_weights is None else np.asarray(edge_weights)
        )
        t0 = time.perf_counter()
        with tr.span("render.edges", chunk_size=cs, samples=cfg.edge_samples):
            for i, chunk in enumerate(
                stream.device_chunks(prefetch=cfg.prefetch, stats=sstats)
            ):
                winc = None
                if weights is not None:
                    wsl = weights[i * cs : (i + 1) * cs]
                    if len(wsl) < cs:
                        wsl = np.pad(wsl, (0, cs - len(wsl)))
                    winc = jnp.asarray(
                        np.clip(np.round(wsl), 1, _MAX_INC).astype(np.int32)
                    )
                t1 = time.perf_counter()
                with tr.span("render.edge_chunk", chunk=i):
                    acc = _edge_splat_update(
                        acc, chunk, pxy_ext, groups_ext, winc,
                        hs, ws, cfg.edge_samples, n_groups, cfg.backend,
                    )
                    if cfg.time_raster:
                        jax.block_until_ready(acc)
                        sstats.raster_update_s += time.perf_counter() - t1
                        sstats.raster_chunks += 1
                sstats.chunks += 1
                sstats.edges_streamed += chunk.shape[0]
            jax.block_until_ready(acc)
        stats.edge_raster_s = time.perf_counter() - t0
        sstats.passes += 1
        sstats.seconds = stats.edge_raster_s
        edge_acc = acc.reshape(n_groups, hs, ws)
        stats.edges_streamed = sstats.edges_streamed
        stats.chunks = sstats.chunks
        stats.peak_device_bytes += (
            stream.chunk_bytes * stream.inflight_buffers(cfg.prefetch)
            + tree_bytes(pxy_ext, groups_ext)
        )
        sstats.peak_device_bytes = stats.peak_device_bytes + tree_bytes(
            edge_acc, node_acc
        )
        sstats.peak_host_bytes = stream.host_bytes(cfg.prefetch)

    t0 = time.perf_counter()
    with tr.span("render.compose", ss=ss):
        if node_acc is None and edge_acc is None:
            image = np.broadcast_to(
                np.asarray(cfg.background, np.uint8),
                (cfg.height, cfg.width, 3),
            ).copy()
        else:
            image = np.asarray(
                _compose(
                    node_acc,
                    edge_acc,
                    jnp.asarray(PALETTE, jnp.float32),
                    jnp.asarray(np.asarray(cfg.background, np.float32)),
                    cfg.node_gain,
                    cfg.edge_gain,
                    cfg.edge_alpha,
                    ss,
                )
            )
    stats.compose_s = time.perf_counter() - t0
    stats.peak_device_bytes += tree_bytes(node_acc, edge_acc)
    stats.seconds = time.perf_counter() - t_start
    stats.stream = sstats
    stats.timings = {
        "node_raster_s": stats.node_raster_s,
        "edge_raster_s": stats.edge_raster_s,
        "compose_s": stats.compose_s,
    }
    stats.publish()
    return image, stats


def render(
    result,
    path: str | None = None,
    cfg: RenderConfig | None = None,
) -> tuple[np.ndarray, RenderStats]:
    """Render a ``BGVResult`` supergraph drawing (paper §4.3): supernode
    disks radius ∝ √size, superedges weighted by aggregated multiplicity.
    Writes a PNG when ``path`` is given; returns (image, RenderStats)."""
    cfg = cfg or RenderConfig()
    sizes = np.maximum(np.asarray(result.sizes, np.float32), 0.0)
    radii = np.sqrt(sizes)  # paper §4.1: radius ∝ √size; 0 = dead slot
    sg = result.supergraph
    edge_source = None
    weights = None
    if cfg.draw_edges and sg is not None:
        edge_source = np.asarray(sg.edges)
        weights = np.asarray(sg.weights)
    image, stats = render_arrays(
        result.positions, radii, result.groups,
        edge_source, edge_weights=weights, cfg=cfg,
    )
    if path is not None:
        from repro.render.png import write_png

        write_png(path, image)
    return image, stats


def image_summary(
    image: np.ndarray,
    background: tuple = (255, 255, 255),
    tol: float = 60.0,
) -> tuple[float, np.ndarray]:
    """(non-background pixel fraction, per-palette-entry pixel counts).

    A pixel counts toward a palette entry when that entry is its nearest
    palette color within euclidean RGB distance ``tol`` — the CI
    render-smoke content check (≥ 1% non-background, ≥ 3 palette colors).
    """
    flat = np.asarray(image).reshape(-1, 3).astype(np.int32)
    bg = np.asarray(background, np.int32)
    nonbg = np.any(flat != bg, axis=1)
    frac = float(nonbg.mean()) if len(flat) else 0.0
    sub = flat[nonbg]
    counts = np.zeros(len(PALETTE), np.int64)
    if len(sub):
        d2 = ((sub[:, None, :] - PALETTE.astype(np.int32)[None]) ** 2).sum(-1)
        near = d2.argmin(axis=1)
        close = d2[np.arange(len(sub)), near] <= tol * tol
        counts = np.bincount(near[close], minlength=len(PALETTE)).astype(
            np.int64
        )
    return frac, counts
