"""Train-step factories (loss → grad → AdamW) shared by every family,
with microbatch gradient accumulation, optional gradient compression for
the DP all-reduce, and a step-time watchdog for straggler detection.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt
from repro.train.compression import compress_decompress


@dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    microbatch: int = 0  # 0 = no accumulation; else split batch dim
    compress_grads: bool = False  # int8 gradient compression (error-feedback-free)


def make_train_step(loss_fn: Callable, tcfg: TrainConfig):
    """loss_fn(params, batch) → scalar. Returns train_step(params, state,
    batch) → (params, state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, state, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            n = tcfg.microbatch

            def split(x):
                b = x.shape[0]
                return x.reshape(n, b // n, *x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def body(carry, b_i):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, b_i)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None

            # Accumulate in the PARAM dtype: an f32 accumulator for a
            # bf16-param 1T model costs 2× the grads themselves.
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, p.dtype), params
            )
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros), mb)
            loss = loss / n
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
        else:
            loss, grads = grads_of(params, batch)

        if tcfg.compress_grads:
            grads = jax.tree_util.tree_map(compress_decompress, grads)

        params, state, metrics = opt.apply_updates(params, grads, state, tcfg.adamw)
        metrics["loss"] = loss
        return params, state, metrics

    return train_step


class StepWatchdog:
    """Host-side straggler detector: flags steps slower than
    ``threshold ×`` the running median. At pod scale the launcher uses this
    to trigger checkpoint-and-reschedule (see fault_tolerance.py)."""

    def __init__(self, threshold: float = 3.0, warmup: int = 3):
        self.threshold = threshold
        self.warmup = warmup
        self.durations: list[float] = []
        self._t0 = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        dt = time.perf_counter() - self._t0
        self.durations.append(dt)
        if len(self.durations) <= self.warmup:
            return False
        med = sorted(self.durations[:-1])[len(self.durations[:-1]) // 2]
        return dt > self.threshold * med
