"""DEPRECATED shim — the checkpoint module moved to
``repro.resilience.checkpoint`` (ISSUE 10), which the streaming pipeline's
fault-tolerance layer is built on. Same format, same functions; this path
re-exports them for the training substrate and existing callers. New code
should import from ``repro.resilience``.
"""
from repro.resilience.checkpoint import (  # noqa: F401
    SEP,
    _flatten,
    _key_str,
    _prune,
    latest_step,
    restore,
    save,
)
