"""Checkpoint save/restore with elastic resharding.

Format: one .npz per checkpoint (flattened pytree with '/'-joined path
keys) + a meta.json (step, PRNG key, data cursor, config fingerprint).
Writes are atomic (tmp + rename) and a keep-last-k window is enforced —
the two properties that make checkpoint/restart safe under preemption.

Elasticity: arrays are stored unsharded; ``restore`` device_puts every
leaf onto the *target* shardings, so a checkpoint taken on one mesh
restores onto any other (scale up/down) as long as shapes match. On a
real multi-host pod this module would sit on tensorstore/OCDBT; the
format here keeps the same interface with a single-file backend.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = SEP.join(_key_str(k) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 etc.): npz-opaque
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        flat[key] = arr
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None,
         keep: int = 3) -> str:
    """Atomically write checkpoint ``step``; prune to the newest ``keep``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, final)  # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    meta = {"step": step, **(extra or {})}
    with open(final + ".meta.json", "w") as f:
        json.dump(meta, f)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    ckpts = sorted(
        f for f in os.listdir(ckpt_dir) if f.startswith("step_") and f.endswith(".npz")
    )
    for old in ckpts[:-keep]:
        os.unlink(os.path.join(ckpt_dir, old))
        meta = os.path.join(ckpt_dir, old + ".meta.json")
        if os.path.exists(meta):
            os.unlink(meta)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(f[len("step_") : -len(".npz")])
        for f in os.listdir(ckpt_dir)
        if f.startswith("step_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any | None = None):
    """Rebuild the pytree of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (matching pytree of NamedSharding)
    re-shards onto the CURRENT mesh — elastic restore."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    leaves_with_path = jax.tree_util.tree_leaves_with_path(like)
    vals = []
    for kpath, leaf in leaves_with_path:
        key = SEP.join(_key_str(k) for k in kpath)
        arr = data[key]
        want = np.dtype(leaf.dtype) if not hasattr(leaf.dtype, "itemsize") else leaf.dtype
        if arr.dtype.kind == "u" and np.dtype(want).kind == "V":
            arr = arr.view(want)  # round-trip ml_dtypes (bfloat16) storage
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        vals.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, vals)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    else:
        tree = jax.tree_util.tree_map(jax.device_put, tree)
    meta_path = path + ".meta.json"
    meta = {}
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    return tree, meta
