"""AdamW from scratch (no optax in this environment) with optional
blockwise-8-bit state quantization (Dettmers-style) — the distributed-
optimization trick that lets the 1T-param kimi-k2 cell fit 512×16 GB
(see EXPERIMENTS.md §Perf): m,v stored as int8 + f32 per-block scales
= 2.5 bytes/param of optimizer state instead of 8.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

Q_BLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    # 32: f32 m,v (classic AdamW). 8: int8 row-wise momentum + FACTORED
    # second moment (Adafactor-style row/col statistics). Straight int8 v
    # diverges — small second moments quantize to zero and updates explode
    # (tests/test_train_substrate.py); factored v is the production answer
    # at 1T scale (T5/PaLM lineage) and costs ~0 memory.
    state_bits: int = 32
    grad_clip: float = 1.0


# ------------------------------------------------- row-wise int8 quantizer
#
# One scale per last-dim row: q keeps the param's EXACT shape (and hence
# its logical sharding axes — essential for the 1T-param cells), the scale
# drops the last dim. An earlier block-of-256 layout reshaped the last dim
# and silently lost its sharding: kimi's we_o [L,E,F,D(embed→data)] state
# became unsharded ⇒ 20 GiB int8 + an s8 all-gather + 20 GiB f32 dequant
# per device (EXPERIMENTS §Perf iteration 6). Row-wise is coarser than
# Dettmers' 256-blocks but sharding-transparent; Adam tolerates it (see
# tests/test_train_substrate.py::test_adamw_int8_tracks_fp32).

def _q8(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray, shape):
    return (q.astype(jnp.float32) * scale).reshape(shape)


def _quant_state(x, bits):
    if bits == 8:
        q, s = _q8(x)
        return {"q": q, "s": s}
    return x.astype(jnp.float32)


def _dequant_state(st, shape, bits):
    if bits == 8:
        return _dq8(st["q"], st["s"], shape)
    return st


def _abstract_q8(shape, dtype=jnp.float32):
    ss = tuple(shape[:-1]) + (1,) if shape else (1,)
    return {
        "q": jax.ShapeDtypeStruct(tuple(shape), jnp.int8),
        "s": jax.ShapeDtypeStruct(ss, jnp.float32),
    }


def opt_logical_axes(param_axes_tree, cfg: "AdamWConfig"):
    """Logical axes for the optimizer state, mirroring the param axes.

    f32 state: same axes as the param. int8 state: leading axes preserved,
    block dims unsharded."""

    def one(axes):
        if cfg.state_bits == 8:
            # Row-wise layout: q shares the param's shape AND axes; the
            # scale keeps all axes but the (reduced) last one.
            q_axes = tuple(axes)
            s_axes = (tuple(axes[:-1]) + (None,)) if axes else (None,)
            m = {"q": q_axes, "s": s_axes}
            if len(axes) >= 2:
                v = {"r": tuple(axes[:-1]), "c": tuple(axes[:-2]) + (axes[-1],)}
            else:
                v = axes
            return {"m": m, "v": v}
        return {"m": axes, "v": axes}

    return {
        "count": (),
        "mv": jax.tree_util.tree_map(
            one, param_axes_tree, is_leaf=lambda x: isinstance(x, tuple)
        ),
    }


# ----------------------------------------------------------------- adamw

# ------------------------------------------ factored second moment (v)

def _vrow_vcol_shapes(shape):
    """Factored v: row stats reduce the last dim, col stats the 2nd-to-last."""
    vr = tuple(shape[:-1])
    vc = tuple(shape[:-2]) + (shape[-1],)
    return vr, vc


def _factored_ok(shape) -> bool:
    return len(shape) >= 2


def init_opt_state(params, cfg: AdamWConfig):
    def one(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.state_bits == 8:
            if _factored_ok(p.shape):
                vr, vc = _vrow_vcol_shapes(p.shape)
                v = {"r": jnp.zeros(vr, jnp.float32), "c": jnp.zeros(vc, jnp.float32)}
            else:
                v = jnp.zeros(p.shape, jnp.float32)
            return {"m": _quant_state(z, 8), "v": v}
        return {"m": z, "v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "count": jnp.zeros((), jnp.int32),
        "mv": jax.tree_util.tree_map(one, params),
    }


def abstract_opt_state(abstract_params, cfg: AdamWConfig):
    """ShapeDtypeStruct tree matching init_opt_state — for the dry-run."""
    def one(p):
        if cfg.state_bits == 8:
            if _factored_ok(p.shape):
                vr, vc = _vrow_vcol_shapes(p.shape)
                v = {"r": jax.ShapeDtypeStruct(vr, jnp.float32),
                     "c": jax.ShapeDtypeStruct(vc, jnp.float32)}
            else:
                v = jax.ShapeDtypeStruct(p.shape, jnp.float32)
            return {"m": _abstract_q8(p.shape), "v": v}
        return {
            "m": jax.ShapeDtypeStruct(p.shape, jnp.float32),
            "v": jax.ShapeDtypeStruct(p.shape, jnp.float32),
        }

    return {
        "count": jax.ShapeDtypeStruct((), jnp.int32),
        "mv": jax.tree_util.tree_map(one, abstract_params),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def one(p, g, mv):
        g = g.astype(jnp.float32) * clip
        m = _dequant_state(mv["m"], p.shape, cfg.state_bits)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        if cfg.state_bits == 8 and _factored_ok(p.shape):
            g2 = g * g + 1e-30
            vr = cfg.b2 * mv["v"]["r"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
            vc = cfg.b2 * mv["v"]["c"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
            # V ≈ (vr ⊗ vc) / mean(vr): the Adafactor rank-1 reconstruction
            denom = jnp.mean(vr, axis=-1, keepdims=True)[..., None] + 1e-30
            v_hat = (vr[..., None] * vc[..., None, :]) / denom
            new_v = {"r": vr, "c": vc}
        else:
            v = mv["v"] if cfg.state_bits != 8 else mv["v"]
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            v_hat = v
            new_v = v
        update = (m / b1c) / (jnp.sqrt(v_hat / b2c) + cfg.eps)
        new_p = p.astype(jnp.float32) - cfg.lr * (update + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), {
            "m": _quant_state(m, cfg.state_bits),
            "v": new_v,
        }

    # Liveness discipline: without explicit sequencing XLA schedules every
    # tensor's f32 dequant→update chain concurrently (kimi train_4k:
    # ~61 GiB of simultaneous 5 GiB f32 temporaries). An
    # optimization_barrier token threads each tensor's update after the
    # previous one, so one chain is live at a time. (A lax.map over the
    # layer dim was tried first and REFUTED: scan double-buffers the
    # stacked xs/ys and lost 3–7 GiB — EXPERIMENTS §Perf iteration 7.)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mv = treedef.flatten_up_to(state["mv"])
    # Sequence the big updates: before starting tensor j, its gradient is
    # passed through one optimization_barrier together with tensor i's
    # finished outputs — a pure ordering edge (no arithmetic, shapes and
    # shardings preserved). First attempt used a fake scalar dependency
    # built from ravel()[0]; the reshape of a sharded tensor replicated
    # every parameter (1383 GiB/dev — refuted, EXPERIMENTS §Perf it. 7b).
    BIG = 64 * 2**20
    order = sorted(range(len(flat_p)), key=lambda i: -flat_p[i].size)
    out: list = [None] * len(flat_p)
    pending_idx: int | None = None
    pending = None
    for i in order:
        p, g, mv = flat_p[i], flat_g[i], flat_mv[i]
        big = p.size * 4 >= BIG
        if big and pending is not None:
            g, pending = jax.lax.optimization_barrier((g, pending))
            out[pending_idx] = pending
        new_out = one(p, g, mv)
        if big:
            pending, pending_idx = new_out, i
        else:
            out[i] = new_out
    if pending is not None:
        out[pending_idx] = pending
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mv = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_params, {"count": count, "mv": new_mv}, {"grad_norm": gnorm}
