"""Fault tolerance for 1000+-node operation.

Components:
  * CheckpointManager — periodic async-ish save, restore-latest-valid,
    preemption-signal hook. A corrupt/partial newest checkpoint (killed
    mid-write before the atomic rename) is impossible by construction;
    a corrupt *meta* falls back to the previous window entry.
  * ElasticPlan — maps a checkpoint to a different mesh (scale up/down):
    arrays are stored unsharded, restore() device_puts onto new shardings
    (train/checkpoint.py), so elasticity = recomputing shardings for the
    new topology and re-restoring.
  * Straggler policy — synchronous SPMD steps cannot tolerate a slow host;
    the watchdog (train_loop.StepWatchdog) detects >3× median steps and
    the runner responds checkpoint-now + reschedule. For multi-pod DP,
    gradient all-reduce over the "pod" axis is the only cross-pod
    dependency, so a lost pod degrades to fewer DP replicas after an
    elastic restore — the batch schedule below recomputes per-pod batch.
"""
from __future__ import annotations

import os
import signal
from dataclasses import dataclass, field
from typing import Any

from repro.train import checkpoint as ckpt_lib


@dataclass
class CheckpointManager:
    ckpt_dir: str
    every_steps: int = 100
    keep: int = 3
    _preempted: bool = field(default=False, repr=False)

    def install_preemption_handler(self) -> None:
        """SIGTERM (the cloud preemption signal) ⇒ checkpoint at next step."""
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    def should_save(self, step: int) -> bool:
        return self._preempted or (step > 0 and step % self.every_steps == 0)

    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        path = ckpt_lib.save(self.ckpt_dir, step, tree, extra=extra, keep=self.keep)
        self._preempted = False
        return path

    def restore_latest(self, like: Any, shardings: Any | None = None):
        """Restore the newest valid checkpoint; walk back on corruption."""
        step = ckpt_lib.latest_step(self.ckpt_dir)
        while step is not None:
            try:
                tree, meta = ckpt_lib.restore(self.ckpt_dir, step, like, shardings)
                return step, tree, meta
            except Exception:  # partial/corrupt → try the previous one
                os.unlink(os.path.join(self.ckpt_dir, f"step_{step:08d}.npz"))
                step = ckpt_lib.latest_step(self.ckpt_dir)
        return None, None, {}


@dataclass(frozen=True)
class ElasticPlan:
    """Re-derive the per-pod data schedule after scale change."""
    global_batch: int
    n_pods: int

    def batch_per_pod(self) -> int:
        assert self.global_batch % self.n_pods == 0, (
            "elastic resize requires the global batch to divide the new pod "
            f"count (got {self.global_batch} over {self.n_pods})"
        )
        return self.global_batch // self.n_pods

    def data_shard_for(self, pod_id: int, step: int) -> tuple[int, int]:
        """Deterministic (start, size) cursor into the step's global batch —
        restores exactly-once data consumption after elastic restore."""
        per = self.batch_per_pod()
        return pod_id * per, per
