"""Gradient compression for the data-parallel all-reduce.

At 1000+-node scale the DP all-reduce of dense grads is the dominant
inter-pod collective. Blockwise-int8 compression cuts those bytes 4×
(f32→int8 payload + 1 f32 scale / 256 values). Under jit the
quantize→dequantize pair expresses the wire format; XLA keeps the
all-reduce itself in the compressed domain when executed with
reduce-precision collectives (and the roofline harness books collective
bytes at the compressed width for this mode).

Also provided: top-k sparsification with error feedback (classic DGC) for
host-driven parameter-server style reducers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.optimizer import _dq8, _q8


def compress_decompress(g: jnp.ndarray) -> jnp.ndarray:
    """Blockwise int8 round-trip (the wire format of the compressed
    all-reduce). Bias-free stochastic rounding is unnecessary for Adam."""
    if not jnp.issubdtype(g.dtype, jnp.floating):
        return g
    q, s = _q8(g)
    return _dq8(q, s, g.shape).astype(g.dtype)


def topk_sparsify(g: jnp.ndarray, error: jnp.ndarray, k_frac: float = 0.01):
    """Deep-gradient-compression style top-k with error feedback.

    Returns (sparse_g, new_error): sparse_g keeps the top k_frac magnitudes
    of (g + error); the remainder accumulates into the error buffer.
    """
    acc = g + error
    flat = acc.reshape(-1)
    k = max(1, int(flat.shape[0] * k_frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat, dtype=bool).at[idx].set(True)
    kept = jnp.where(mask, flat, 0.0).reshape(g.shape)
    return kept, (acc - kept)
