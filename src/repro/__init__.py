"""BigGraphVis reproduction — stable public API.

The supported entry points, re-exported from their implementation
modules so user code never needs deep imports:

    from repro import biggraphvis, default_config, render, TileEngine

* pipeline — ``biggraphvis`` / ``default_config`` / ``BGVConfig`` /
  ``BGVResult`` (with ``BGVResult.render``) / ``full_layout_colored``
* streaming engine — ``StreamConfig`` / ``StreamStats`` /
  ``EdgeStore`` sources (``as_edge_store`` accepts arrays, stores, and
  ``.npy``/``.bin``/shard paths)
* rendering — ``render`` / ``render_arrays`` / ``RenderConfig``
* serving — ``TileEngine`` / ``TilePyramid`` / ``TileConfig`` /
  ``TileSpec`` / ``DrillSpec`` (repro/serve/tiles.py)
* observability — ``Tracer`` / ``MetricsRegistry`` / ``enable_tracing``
  / ``get_tracer`` / ``jit_compile_count`` (repro/obs)

Imports are lazy (PEP 562), so ``import repro`` stays cheap and CLI
modules (``python -m repro.data.edge_store`` …) don't pay for the full
stack. Everything outside ``__all__`` is internal and may move between
releases; tests/test_api.py pins this surface and its signatures.
"""
import importlib

_EXPORTS = {
    "BGVConfig": "repro.core.pipeline",
    "BGVResult": "repro.core.pipeline",
    "DrillSpec": "repro.serve.tiles",
    "EdgeStore": "repro.data.edge_store",
    "MetricsRegistry": "repro.obs",
    "RenderConfig": "repro.render",
    "StreamConfig": "repro.core.stream",
    "StreamStats": "repro.core.stream",
    "TileConfig": "repro.serve.tiles",
    "TileEngine": "repro.serve.tiles",
    "TilePyramid": "repro.serve.tiles",
    "TileSpec": "repro.serve.tiles",
    "Tracer": "repro.obs",
    "as_edge_store": "repro.data.edge_store",
    "biggraphvis": "repro.core.pipeline",
    "default_config": "repro.core.pipeline",
    "enable_tracing": "repro.obs",
    "full_layout_colored": "repro.core.pipeline",
    "get_tracer": "repro.obs",
    "jit_compile_count": "repro.obs",
    "render": "repro.render",
    "render_arrays": "repro.render",
}
__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module 'repro' has no attribute '{name}'")


def __dir__():
    return sorted(set(globals()) | set(__all__))
