"""Streaming chunked-edge execution engine (paper §3: community information
extracted "in a few passes on the edge list").

The one-shot pipeline materializes the whole padded edge list on device
before any stage runs, capping the reproduction at device-memory scale.
This engine instead keeps the edge list out of device memory and drives
every edge-consuming stage over fixed-size chunks:

    EdgeStore (host array · mmap .npy/.bin · sharded files)
        ──► EdgeChunkStream (padded chunk buffers)
        ──► double-buffered host staging + forced-copy device_put
        ──► per-chunk jitted update steps, state donated
            (SCoDA labels+degrees · graph degrees · superedge aggregation
             — two-level sorted-merge by default, ``StreamConfig.
             agg_backend`` — · modularity accumulators · CMS sketch)
        ──► finalize: Supergraph + labels, device-resident node-sized state

Device residency is O(n_nodes + chunk_size + max_super_edges + sketch) —
independent of |E| — so edge lists larger than device memory process in
``rounds + 1`` passes. With a disk-backed ``EdgeStore`` (repro/data/
edge_store.py) *host* residency is also |E|-independent: the only host
buffers are the staging pair, filled from the store and overwritten in
place once the in-flight transfer from their previous contents completes
(``EdgeChunkStream.device_chunks``). The transfer is a forced-copy
``jax.device_put`` so a staged buffer can never be aliased by the device
array that compute reads.

Bit-exactness: every stage's one-shot function is a thin wrapper over the
same chunk-update body (single chunk = whole list), and the SCoDA block
partition is preserved because chunk sizes are rounded up to a multiple of
``ScodaConfig.block_size`` — so chunked and one-shot runs produce identical
labels, supergraphs, and modularity whatever the source (see
tests/test_stream.py and tests/test_edge_store.py).

This is the single-device engine; ``launch/stream_runner.py`` adds device
placement/sharding, and is the substrate for the multi-device edge-sharded
form promised in core/pipeline.py's docstring.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cms as cms_lib
from repro.core.modularity import (
    modularity_finalize,
    modularity_init,
    modularity_update,
    sharded_modularity_update,
)
from repro.core.scoda import (
    ScodaConfig,
    dense_labels,
    round_threshold,
    scoda_finalize,
    scoda_init,
    scoda_update,
    sharded_scoda_update,
)
from repro.core.supergraph import (
    Supergraph,
    agg_finalize,
    agg_init,
    agg_update,
    community_sizes,
    sharded_agg_update,
)
from repro.data.edge_store import EDGE_DTYPE, InMemoryEdgeStore, as_edge_store
from repro.kernels.compat import device_put_copied, shard_map_compat
from repro.obs.metrics import REGISTRY, ensure_error_counters
from repro.obs.trace import get_tracer
from repro.resilience.checkpoint import (
    CheckpointMismatchError,
    config_fingerprint,
    restore_latest_valid,
)
from repro.resilience.validate import ValidationAccounting, validated_read


@dataclass(frozen=True)
class StreamConfig:
    """Engine knobs. ``chunk_size`` is rounded up to a multiple of the SCoDA
    block size so the chunked block partition matches the one-shot one.
    ``agg_backend`` selects the superedge-aggregation algorithm ("merge" =
    two-level sorted-merge via kernels/merge, "lexsort" = full re-sort
    baseline; bit-identical below capacity — core/supergraph.py).
    ``time_agg`` blocks on every aggregation update to fill the per-chunk
    ``StreamStats`` aggregation timing (costs copy/compute overlap; leave
    off outside benchmarks).

    Multi-device (DESIGN.md §2, ROADMAP item 1): ``mesh`` + ``shard_detect``
    lower every per-chunk edge pass (SCoDA labels, degrees, superedge
    aggregation, modularity, CMS sizing) onto the mesh via ``shard_map`` —
    chunk buffers are device-sharded, node/sketch/agg state replicated,
    results bit-identical to single-device. ``shard_layout`` asks the
    downstream FA2 layout (core/pipeline.py) to node-partition its force
    pass on the same mesh. Both degrade to the unsharded path when a shape
    doesn't divide by the device count (see ``stream_detect`` /
    ``stream_supergraph`` gates).

    ``obs`` threads a ``repro.obs.Tracer`` through every engine stage
    (per-pass/per-chunk spans); None falls back to the process-global
    tracer, a no-op until ``repro.obs.enable_tracing()``.

    ``validation`` (a ``repro.resilience.ValidationPolicy``) makes every
    chunk read defensive: transient I/O errors retry with backoff, chunks
    that stay unreadable are quarantined (trash-filled, counted in
    ``StreamStats``/``errors.*``), and out-of-range node ids drop to the
    trash node — instead of any of those crashing a multi-pass run."""

    chunk_size: int = 1 << 16  # edges resident on device per chunk
    prefetch: int = 1  # host→device copies dispatched ahead of compute
    agg_backend: str = "merge"  # superedge aggregation: "merge" | "lexsort"
    time_agg: bool = False  # per-chunk aggregation timing in StreamStats
    mesh: object = None  # jax.sharding.Mesh for the sharded paths (or None)
    shard_detect: bool = False  # shard the per-chunk edge passes over mesh
    shard_layout: bool = False  # node-partition the FA2 layout over mesh
    obs: object = None  # repro.obs.Tracer (None = process-global tracer)
    validation: object = None  # resilience.ValidationPolicy (None = trusting)


@dataclass
class StreamStats:
    """Per-run accounting. ``peak_device_bytes`` is the analytic resident
    footprint of the streaming state (chunk buffer + node/sketch/agg state),
    the number the one-shot path's full edge materialization is compared to;
    ``peak_host_bytes`` is its host-side mirror (edge array + tail buffer
    in-memory, staging buffers only when disk-backed). ``host_fill_s`` is
    time spent reading the store into staging; ``copy_stall_s`` is time
    blocked waiting for an in-flight transfer before a staging buffer could
    be reused — both ≈ 0 when copies overlap compute. ``agg_update_s`` /
    ``agg_chunks`` are the blocking per-chunk superedge-aggregation timing,
    populated only under ``StreamConfig.time_agg`` (benchmarks/agg_bench.py
    compares them across ``agg_backend`` values). ``raster_update_s`` /
    ``raster_chunks`` are their per-chunk analogue for the renderer's
    streamed edge-splat pass (repro/render/raster.py, populated under
    ``RenderConfig.time_raster``; benchmarks/render_bench.py).

    ``devices`` is the mesh size the sharded passes actually ran on (1 =
    unsharded); ``peak_local_bytes`` is the analytic *per-device* resident
    footprint — replicated state at full size plus this device's 1/D slice
    of the chunk buffers. With ``devices == 1`` it equals
    ``peak_device_bytes``; benchmarks/shard_bench.py asserts it shrinks
    toward 1/D of the single-device peak as the chunk term dominates."""

    passes: int = 0
    chunks: int = 0
    edges_streamed: int = 0
    seconds: float = 0.0
    chunk_size: int = 0
    devices: int = 1
    peak_device_bytes: int = 0
    peak_local_bytes: int = 0
    peak_host_bytes: int = 0
    host_fill_s: float = 0.0
    copy_stall_s: float = 0.0
    agg_update_s: float = 0.0
    agg_chunks: int = 0
    raster_update_s: float = 0.0
    raster_chunks: int = 0
    stage_seconds: dict = field(default_factory=dict)
    # Resilience accounting (ISSUE 10): validation/quarantine tallies are
    # copied from the stream's ``ValidationAccounting``. ``quarantined_*``
    # report *distinct* chunks (a permanently-bad chunk is hit once per
    # pass; the per-occurrence tally is the ``errors.quarantined_chunks``
    # counter, which increments at the point of occurrence). ``resumed_at``
    # records the checkpoint cursor a resumed run picked up from ("" for
    # an uninterrupted run).
    retries: int = 0
    quarantined_chunks: int = 0
    quarantined_chunk_ids: list = field(default_factory=list)
    dropped_edges: int = 0
    resumed_at: str = ""

    @property
    def edges_per_s(self) -> float:
        return self.edges_streamed / self.seconds if self.seconds > 0 else 0.0

    def publish(self, registry=None) -> None:
        """Mirror this run's accounting into the metrics registry
        (``repro.obs.REGISTRY`` by default) — the engine's side of the
        one-instrumentation-layer contract: counters accumulate across
        runs, per-run seconds land as gauges, residency peaks as
        high-watermark gauges. ``launch/render_runner.py`` and the
        ``--metrics-out`` CLI dumps read these instead of hand-formatting
        the dataclass fields."""
        reg = registry if registry is not None else REGISTRY
        ensure_error_counters(reg)  # degradation visible even at 0
        reg.counter("stream.runs").inc()
        reg.counter("stream.passes").inc(self.passes)
        reg.counter("stream.chunks").inc(self.chunks)
        reg.counter("stream.edges").inc(self.edges_streamed)
        for name, value in (
            ("stream.seconds", self.seconds),
            ("stream.edges_per_s", self.edges_per_s),
            ("stream.chunk_size", self.chunk_size),
            ("stream.devices", self.devices),
            ("stream.host_fill_s", self.host_fill_s),
            ("stream.copy_stall_s", self.copy_stall_s),
            ("stream.agg_update_s", self.agg_update_s),
            ("stream.raster_update_s", self.raster_update_s),
        ):
            reg.gauge(name).set(value)
        for stage, secs in self.stage_seconds.items():
            reg.gauge(f"stream.stage.{stage}").set(secs)
        reg.gauge("stream.peak_device_bytes").set_max(self.peak_device_bytes)
        reg.gauge("stream.peak_local_bytes").set_max(self.peak_local_bytes)
        reg.gauge("stream.peak_host_bytes").set_max(self.peak_host_bytes)


def tree_bytes(*trees) -> int:
    """Total bytes of every array leaf across the given pytrees."""
    total = 0
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "dtype"):
                total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total


class EdgeChunkStream:
    """Chunked view over any edge source (``repro.data.edge_store``).

    Yields [chunk_size, 2] int32 chunks; the tail chunk is padded with the
    trash node ``n_nodes`` (a no-op for every chunk-update body). The source
    is validated (dtype/shape) once here, at construction — a float or
    mis-shaped edge array raises immediately instead of failing deep inside
    a kernel. Iterating counts one pass.

    Two host-side regimes:

    * in-memory source — chunks are zero-copy slices of the edge array;
      the padded tail buffer is allocated once and never mutated, so it is
      safe even when the host→device transfer aliases host memory.
    * disk-backed source — ``device_chunks`` fills a small ring of
      persistent staging buffers (the pinned-staging analog; allocated
      once, reused across chunks and passes) and transfers each with a
      forced-copy ``device_put``, blocking on a buffer's previous transfer
      only when the ring wraps. Plain iteration allocates a fresh buffer
      per chunk instead, since yielded chunks may outlive the next read.
    """

    def __init__(self, source, n_nodes: int, chunk_size: int,
                 block_size: int = 1, policy=None):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        self.store = as_edge_store(source)
        self.n_nodes = n_nodes
        self.n_edges = self.store.n_edges
        # Defensive reads (resilience.ValidationPolicy): every chunk goes
        # through validated_read — retry/quarantine/range checks — which
        # needs a mutable staging buffer, so the in-memory zero-copy slice
        # path is disabled below when a policy is set.
        self.policy = policy
        self.acct = ValidationAccounting()
        # Round up so chunk boundaries align with SCoDA block boundaries,
        # and clamp to the padded edge list — a chunk larger than |E| would
        # only buy a bigger trash-padded buffer.
        bs = max(1, block_size)
        cap = max(bs, ((self.n_edges + bs - 1) // bs) * bs)
        self.chunk_size = min(((chunk_size + bs - 1) // bs) * bs, cap)
        self.n_chunks = max(1, -(-self.n_edges // self.chunk_size))
        self.passes = 0
        self.edges = (
            self.store.array
            if isinstance(self.store, InMemoryEdgeStore) and policy is None
            else None
        )
        self._staging = None  # lazy ring of reusable disk-path buffers
        self._inflight = None  # device array whose transfer reads each buffer
        if self.edges is not None:
            # The tail chunk is identical every pass, so its padded buffer
            # is filled once and never mutated — safe even when the
            # host→device transfer aliases host memory.
            start = (self.n_chunks - 1) * self.chunk_size
            self._tail_buf = np.full(
                (self.chunk_size, 2), n_nodes, dtype=EDGE_DTYPE
            )
            self._tail_buf[: self.n_edges - start] = self.edges[start:]
        else:
            self._tail_buf = None

    def __len__(self) -> int:
        return self.n_chunks

    @property
    def chunk_bytes(self) -> int:
        return self.chunk_size * 2 * 4

    def staging_buffers(self, prefetch: int = 1) -> int:
        """Host staging buffers the disk path keeps in flight: one being
        filled plus one per outstanding transfer (0 for in-memory)."""
        if self.edges is not None:
            return 0
        return max(2, prefetch + 1)

    def inflight_buffers(self, prefetch: int = 1) -> int:
        """Device-side chunk buffers alive at once under ``prefetch``."""
        if self.edges is not None:
            live = 1 + max(0, prefetch)  # dispatch-ahead queue + current
        else:
            live = max(2, prefetch + 1)  # one per staging-ring slot
        return min(self.n_chunks, live)

    def host_bytes(self, prefetch: int = 1) -> int:
        """Host residency of streaming this source: the resident edge array
        + tail buffer in-memory; just the staging ring when disk-backed."""
        base = self.store.resident_bytes
        if self.edges is not None:
            return base + self._tail_buf.nbytes
        return base + self.staging_buffers(prefetch) * self.chunk_bytes

    def _read_chunk(self, i: int, buf: np.ndarray) -> np.ndarray:
        if self.policy is not None:
            return validated_read(
                self.store, i, self.chunk_size, buf, self.n_nodes,
                self.policy, self.acct,
            )
        k = self.store.read_into(i * self.chunk_size, buf)
        if k < self.chunk_size:
            buf[k:] = self.n_nodes  # pad the tail with the trash node
        return buf

    def _host_chunks(self, start: int = 0):
        cs = self.chunk_size
        if self.edges is not None:
            for i in range(start, self.n_chunks - 1):
                yield self.edges[i * cs:(i + 1) * cs]
            if start <= self.n_chunks - 1:
                yield self._tail_buf
        else:
            for i in range(start, self.n_chunks):
                buf = np.empty((cs, 2), dtype=EDGE_DTYPE)
                yield self._read_chunk(i, buf)

    def __iter__(self):
        self.passes += 1
        return self._host_chunks()

    def device_chunks(self, put=None, prefetch: int = 1,
                      stats: StreamStats | None = None, start: int = 0):
        """One pass of device-resident chunks, transfers overlapping compute.

        In-memory sources dispatch ``put`` up to ``prefetch`` chunks ahead
        (chunks are immutable slices, so no staging is needed). Disk-backed
        sources run the double-buffered pipeline described in the class
        docstring; their default ``put`` is a forced-copy ``device_put``,
        and any caller-supplied ``put`` must also copy (StreamRunner's
        sharded ``put`` does). ``start`` skips the first chunks — the
        checkpoint/resume cursor (``stream_detect(resume=)``).
        """
        self.passes += 1
        depth = max(0, prefetch)
        if self.edges is not None:
            yield from _dispatch_ahead(
                self._host_chunks(start), put or jnp.asarray, depth
            )
            return

        put = put or device_put_copied
        nbuf = self.staging_buffers(depth)
        if self._staging is None or len(self._staging) < nbuf:
            self._staging = [
                np.full((self.chunk_size, 2), self.n_nodes, dtype=EDGE_DTYPE)
                for _ in range(nbuf)
            ]
            self._inflight = [None] * nbuf
        # In-flight transfers are tracked on the stream, not the generator:
        # the staging ring persists across passes, so the first fills of a
        # new pass must still wait out the previous pass's tail transfers
        # (device_put is asynchronous; CPU only hides this by luck).
        inflight = self._inflight
        pending = deque()
        for i in range(start, self.n_chunks):
            b = i % nbuf
            if inflight[b] is not None:
                # The ring wrapped: before overwriting this staging buffer,
                # wait out the transfer that still reads from it.
                t0 = time.perf_counter()
                inflight[b].block_until_ready()
                if stats is not None:
                    stats.copy_stall_s += time.perf_counter() - t0
                inflight[b] = None
            t0 = time.perf_counter()
            buf = self._read_chunk(i, self._staging[b])
            if stats is not None:
                stats.host_fill_s += time.perf_counter() - t0
            dev = put(buf)
            inflight[b] = dev
            pending.append(dev)
            if len(pending) > depth:
                yield pending.popleft()
        yield from pending


def _dispatch_ahead(chunks, put, depth: int):
    """Host→device copy dispatched ``depth`` chunks ahead of compute."""
    if depth <= 0:
        for chunk in chunks:
            yield put(chunk)
        return
    queue = []
    for chunk in chunks:
        queue.append(put(chunk))
        if len(queue) > depth:
            yield queue.pop(0)
    yield from queue


@functools.partial(jax.jit, donate_argnums=(0,))
def _degree_update(deg, chunk):
    """Chunk-incremental graph degrees ([n+1] accumulator, trash last)."""
    deg = deg.at[chunk[:, 0]].add(1)
    deg = deg.at[chunk[:, 1]].add(1)
    return deg.at[-1].set(0)


@functools.lru_cache(maxsize=None)
def _sharded_degree_update(mesh):
    """``_degree_update`` over the detect-pass placement ([n_blocks, bs, 2]
    sharded on the within-block axis): local scatter-add + integer psum."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import block_chunk_spec

    axes = tuple(mesh.axis_names)

    def body(deg, blocks):
        flat = blocks.reshape(-1, 2)
        inc = jnp.zeros_like(deg).at[flat[:, 0]].add(1).at[flat[:, 1]].add(1)
        return (deg + jax.lax.psum(inc, axes)).at[-1].set(0)

    mapped = shard_map_compat(
        body, mesh, in_specs=(P(), block_chunk_spec(mesh)), out_specs=P()
    )
    return jax.jit(mapped, donate_argnums=(0,))


def _detect_put(mesh, block_size: int):
    """Chunk placement for the sharded detect pass: view the [C, 2] host
    buffer as [n_blocks, block_size, 2] and shard the within-block axis
    (``block_chunk_spec``) so the SCoDA block scan runs in lockstep."""
    from jax.sharding import NamedSharding

    from repro.sharding.rules import block_chunk_spec

    sharding = NamedSharding(mesh, block_chunk_spec(mesh))

    def put(buf):
        blocks = np.asarray(buf).reshape(-1, block_size, 2)
        return device_put_copied(blocks, sharding)

    return put


def _row_put(mesh):
    """Chunk placement for the sharded supergraph pass: contiguous [C/D, 2]
    row shards per device (``row_chunk_spec`` — StreamRunner's placement)."""
    from jax.sharding import NamedSharding

    from repro.sharding.rules import row_chunk_spec

    sharding = NamedSharding(mesh, row_chunk_spec(mesh))

    def put(buf):
        return device_put_copied(np.asarray(buf), sharding)

    return put


def _chunk_edges(chunk) -> int:
    """Edge count of a device chunk in either layout ([C,2] or [B,bs,2])."""
    return int(np.prod(chunk.shape[:-1]))


def _effective_mesh(mesh, shard: bool, *divisible: int):
    """The mesh to shard on, or None: sharding must be requested, the mesh
    multi-device, and every gated extent divisible by the device count."""
    if mesh is None or not shard or mesh.size <= 1:
        return None
    if any(d % mesh.size != 0 for d in divisible):
        return None
    return mesh


def _account_pass_peaks(stats, stream, prefetch, *state_trees, devices: int = 1):
    state_b = tree_bytes(*state_trees)
    chunk_b = stream.chunk_bytes * stream.inflight_buffers(prefetch)
    stats.devices = max(stats.devices, devices)
    stats.peak_device_bytes = max(stats.peak_device_bytes, state_b + chunk_b)
    # Per-device analytic: state replicated, chunk buffers sharded 1/D.
    stats.peak_local_bytes = max(
        stats.peak_local_bytes, state_b + chunk_b // devices
    )
    stats.peak_host_bytes = max(
        stats.peak_host_bytes, stream.host_bytes(prefetch)
    )


def stream_detect(
    stream: EdgeChunkStream,
    n_nodes: int,
    cfg: ScodaConfig,
    *,
    put=None,
    prefetch: int = 1,
    stats: StreamStats | None = None,
    mesh=None,
    shard: bool = False,
    tracer=None,
    ckpt=None,
    resume: dict | None = None,
):
    """Multi-round SCoDA over the chunk stream; graph degrees are fused into
    the first pass. Returns (labels [n], scoda_deg [n], graph_deg [n]).

    With ``mesh`` + ``shard`` the per-chunk updates run device-sharded
    (bit-identical — core/scoda.py); the engine then owns chunk placement
    (the detect pass needs ``block_chunk_spec``, so any caller ``put`` is
    superseded). Falls back to the unsharded path unless ``block_size`` and
    the chunk size divide by the device count. ``tracer`` emits the
    ``detect``/``detect.round``/``detect.chunk`` span tree (None =
    process-global tracer).

    ``ckpt`` (a ``resilience.StreamCheckpointer``) is notified at every
    chunk boundary with the normalized resume cursor — the (round, chunk)
    of the next unprocessed chunk — and a lazy host-side payload of the
    full detect state (SCoDA com/deg + graph-degree accumulator), all
    unsharded host arrays. ``resume`` is that payload plus the cursor
    (``{"round", "chunk", "com", "deg", "gdeg"}``): the loops pick up
    exactly there, so a resumed run replays no chunk and skips none —
    bit-identical to uninterrupted, on any device count (replicated state
    is re-``device_put`` by the first update that consumes it).
    """
    tr = tracer if tracer is not None else get_tracer()
    m = _effective_mesh(mesh, shard, cfg.block_size, stream.chunk_size)
    if m is not None and stream.chunk_size % cfg.block_size != 0:
        m = None  # chunk must hold whole blocks to reshape [B, bs, 2]
    if m is not None:
        put = _detect_put(m, cfg.block_size)
        upd = sharded_scoda_update(m, cfg)
        deg_upd = _sharded_degree_update(m)
    else:
        upd, deg_upd = None, _degree_update
    state = scoda_init(n_nodes)
    gdeg = jnp.zeros(n_nodes + 1, dtype=jnp.int32)
    start_round, start_chunk = 0, 0
    if resume is not None:
        start_round, start_chunk = int(resume["round"]), int(resume["chunk"])
        state = (jnp.asarray(resume["com"]), jnp.asarray(resume["deg"]))
        gdeg = jnp.asarray(resume["gdeg"])
    with tr.span(
        "detect", rounds=cfg.rounds, chunk_size=stream.chunk_size,
        devices=m.size if m is not None else 1,
    ):
        for r in range(start_round, cfg.rounds):
            thr = jnp.int32(round_threshold(cfg, r))
            c0 = start_chunk if r == start_round else 0
            with tr.span("detect.round", round=r):
                for i, chunk in enumerate(
                    stream.device_chunks(put, prefetch, stats, start=c0),
                    start=c0,
                ):
                    with tr.span("detect.chunk", round=r, chunk=i):
                        if r == 0:
                            gdeg = deg_upd(gdeg, chunk)
                        if m is not None:
                            state = upd(state, chunk, thr)
                        else:
                            state = scoda_update(state, chunk, thr, cfg)
                    if stats is not None:
                        stats.chunks += 1
                        stats.edges_streamed += _chunk_edges(chunk)
                    if ckpt is not None:
                        last = i + 1 == stream.n_chunks
                        nr, nc = (r + 1, 0) if last else (r, i + 1)
                        ckpt.boundary(
                            "detect", nr, nc, last,
                            # Bind current values: np.asarray blocks until
                            # the update is done, before the next donation.
                            lambda s=state, g=gdeg: {
                                "com": np.asarray(s[0]),
                                "deg": np.asarray(s[1]),
                                "gdeg": np.asarray(g),
                            },
                        )
    if stats is not None:
        stats.passes += cfg.rounds - start_round
        _account_pass_peaks(
            stats, stream, prefetch, state, gdeg,
            devices=m.size if m is not None else 1,
        )
    labels, scoda_deg = scoda_finalize(state, n_nodes, cfg)
    return labels, scoda_deg, gdeg[:n_nodes]


def stream_supergraph(
    stream: EdgeChunkStream,
    labels: jnp.ndarray,
    node_deg: jnp.ndarray,
    n_nodes: int,
    s_cap: int,
    max_super_edges: int,
    cms_cfg: cms_lib.CMSConfig,
    *,
    put=None,
    prefetch: int = 1,
    stats: StreamStats | None = None,
    with_modularity: bool = True,
    agg_backend: str = "merge",
    time_agg: bool = False,
    mesh=None,
    shard: bool = False,
    tracer=None,
    ckpt=None,
    resume: dict | None = None,
):
    """One fused pass: superedge aggregation + modularity accumulation.

    ``ckpt``/``resume`` follow ``stream_detect``'s contract. The supergraph
    payload carries the aggregation + modularity accumulators *and* the
    detect outputs (labels, graph degrees) so a run killed in this phase
    resumes without re-running detect; dense labels and the CMS community
    sizes are deterministic functions of the labels and are recomputed on
    resume rather than checkpointed.

    CMS community sizing is node-keyed (one sketch update per node, weight =
    graph degree) and so needs no edge pass. Returns (Supergraph, Q) with Q
    None when ``with_modularity`` is false. ``agg_backend``/``time_agg``
    are the ``StreamConfig`` aggregation knobs (see its docstring).

    With ``mesh`` + ``shard`` the aggregation/modularity chunk updates and
    the node-keyed CMS sizing run device-sharded (bit-identical —
    core/supergraph.py, core/modularity.py, core/cms.py); chunks are placed
    row-sharded by the engine. Falls back to unsharded when the chunk size
    doesn't divide by the device count.
    """
    tr = tracer if tracer is not None else get_tracer()
    m = _effective_mesh(mesh, shard, stream.chunk_size)
    with tr.span(
        "supergraph", chunk_size=stream.chunk_size, s_cap=s_cap,
        agg_backend=agg_backend, devices=m.size if m is not None else 1,
    ):
        labels_dense, n_supernodes = dense_labels(labels, n_nodes)
        with tr.span("supergraph.sizes"):
            sizes = community_sizes(
                labels_dense, node_deg, n_supernodes, s_cap, cms_cfg, mesh=m
            )

        if m is not None:
            put = _row_put(m)
            one_agg = sharded_agg_update(m, s_cap, max_super_edges, agg_backend)
            mod_upd = sharded_modularity_update(m) if with_modularity else None
        else:
            def one_agg(st, chunk, ext):
                return agg_update(st, chunk, ext, s_cap, max_super_edges, agg_backend)

            mod_upd = modularity_update

        agg_ext = jnp.concatenate([labels_dense, jnp.array([s_cap], jnp.int32)])
        mod_ext = jnp.concatenate([labels_dense, jnp.array([-1], jnp.int32)])
        agg = agg_init(s_cap, max_super_edges)
        mod = modularity_init(n_nodes) if with_modularity else None
        start_chunk = 0
        if resume is not None:
            start_chunk = int(resume["chunk"])
            agg = tuple(
                jnp.asarray(resume[k])
                for k in ("agg_a", "agg_b", "agg_w", "agg_n")
            )
            if with_modularity:
                mod = tuple(
                    jnp.asarray(resume[k])
                    for k in ("mod_m", "mod_intra", "mod_dcom")
                )

        def payload(a, md):
            out = {
                "labels": np.asarray(labels),
                "deg": np.asarray(node_deg),
                "agg_a": np.asarray(a[0]),
                "agg_b": np.asarray(a[1]),
                "agg_w": np.asarray(a[2]),
                "agg_n": np.asarray(a[3]),
            }
            if md is not None:
                out["mod_m"] = np.asarray(md[0])
                out["mod_intra"] = np.asarray(md[1])
                out["mod_dcom"] = np.asarray(md[2])
            return out

        for i, chunk in enumerate(
            stream.device_chunks(put, prefetch, stats, start=start_chunk),
            start=start_chunk,
        ):
            with tr.span("supergraph.chunk", chunk=i):
                if time_agg and stats is not None:
                    t0 = time.perf_counter()
                    agg = one_agg(agg, chunk, agg_ext)
                    jax.block_until_ready(agg)
                    stats.agg_update_s += time.perf_counter() - t0
                    stats.agg_chunks += 1
                else:
                    agg = one_agg(agg, chunk, agg_ext)
                if with_modularity:
                    mod = mod_upd(mod, chunk, mod_ext)
            if stats is not None:
                stats.chunks += 1
                stats.edges_streamed += _chunk_edges(chunk)
            if ckpt is not None:
                last = i + 1 == stream.n_chunks
                ckpt.boundary(
                    "supergraph", 0, i + 1, last,
                    functools.partial(payload, agg, mod),
                )
    if stats is not None:
        stats.passes += 1
        _account_pass_peaks(
            stats, stream, prefetch, agg, mod, labels_dense, sizes, node_deg,
            devices=m.size if m is not None else 1,
        )
    sedges, sweights, n_superedges = agg_finalize(agg)
    q = modularity_finalize(mod) if with_modularity else None
    sg = Supergraph(
        edges=sedges,
        weights=sweights,
        sizes=sizes,
        n_supernodes=n_supernodes,
        n_superedges=n_superedges,
        labels=labels_dense,
    )
    return sg, q


def stream_pipeline(
    source,
    n_nodes: int,
    scoda_cfg: ScodaConfig,
    cms_cfg: cms_lib.CMSConfig,
    s_cap: int,
    max_super_edges: int,
    stream_cfg: StreamConfig | None = None,
    *,
    put=None,
    with_modularity: bool = True,
    tracer=None,
    checkpoint=None,
    resume=False,
):
    """Edge source → (labels, graph degrees, Supergraph, Q, StreamStats).

    ``source`` is anything ``repro.data.edge_store.as_edge_store`` accepts:
    a host NumPy array, an ``EdgeStore``, a path to a ``.npy``/``.bin``
    edge file or shard directory, or a list of shard paths. The engine's
    full edge-consuming pipeline; layout/coloring operate on the (small,
    device-resident) supergraph and stay with the caller.

    Fault tolerance (ISSUE 10): ``checkpoint`` is a
    ``resilience.StreamCheckpointer`` — the run then persists its full
    streaming state at the checkpointer's cadence, stamped with this
    config's fingerprint. ``resume`` is False, True (restore the newest
    valid checkpoint from ``checkpoint.ckpt_dir``), or a directory path to
    restore from; a fingerprint mismatch raises
    ``CheckpointMismatchError``, and a resume with no checkpoint on disk
    starts fresh. Resumed runs are bit-identical to uninterrupted ones on
    any device count (tests/test_resilience.py).
    """
    store = as_edge_store(source)
    cfg = stream_cfg or StreamConfig(chunk_size=max(1, store.n_edges))
    tr = tracer if tracer is not None else (
        cfg.obs if cfg.obs is not None else get_tracer()
    )
    stream = EdgeChunkStream(
        store, n_nodes, cfg.chunk_size, block_size=scoda_cfg.block_size,
        policy=cfg.validation,
    )
    stats = StreamStats(chunk_size=stream.chunk_size)

    fingerprint = config_fingerprint(
        n_nodes=n_nodes, n_edges=store.n_edges, chunk_size=stream.chunk_size,
        scoda=scoda_cfg, cms=cms_cfg, s_cap=s_cap,
        max_super_edges=max_super_edges, agg_backend=cfg.agg_backend,
        with_modularity=with_modularity,
    )
    if checkpoint is not None:
        checkpoint.fingerprint = fingerprint
    resume_detect = resume_sg = None
    labels = gdeg = None
    if resume:
        ckpt_dir = resume if isinstance(resume, (str,)) else (
            checkpoint.ckpt_dir if checkpoint is not None else None
        )
        # A checkpoint without the resume cursor (meta lost to a crash)
        # is invalid — walk back to the previous one instead of crashing.
        found = (
            restore_latest_valid(ckpt_dir, valid=lambda a, m: "chunk" in m)
            if ckpt_dir else None
        )
        if found is not None:
            arrays, meta = found
            if meta.get("fingerprint") and meta["fingerprint"] != fingerprint:
                raise CheckpointMismatchError(
                    f"checkpoint {ckpt_dir} was written by a run with "
                    f"fingerprint {meta['fingerprint']}, this run is "
                    f"{fingerprint} — resuming would not be bit-identical"
                )
            if checkpoint is not None:
                checkpoint.seed(meta)
            phase = meta.get("phase", "detect")
            cursor = {"round": meta.get("round", 0), "chunk": meta["chunk"]}
            if phase == "detect":
                resume_detect = {**cursor, **arrays}
            else:
                resume_sg = {**cursor, **arrays}
                labels = jnp.asarray(arrays["labels"])
                gdeg = jnp.asarray(arrays["deg"])
            stats.resumed_at = (
                f"{phase}:r{cursor['round']}:c{cursor['chunk']}"
            )

    with tr.span(
        "stream_pipeline", n_nodes=n_nodes, n_edges=store.n_edges,
        chunk_size=stream.chunk_size,
    ):
        # Resuming past detect skips the stage; keep the timing key so
        # downstream consumers (pipeline timings) never miss it.
        stats.stage_seconds["detect_s"] = 0.0
        if resume_sg is None:
            t0 = time.perf_counter()
            labels, _scoda_deg, gdeg = stream_detect(
                stream, n_nodes, scoda_cfg, put=put, prefetch=cfg.prefetch,
                stats=stats, mesh=cfg.mesh, shard=cfg.shard_detect, tracer=tr,
                ckpt=checkpoint, resume=resume_detect,
            )
            jax.block_until_ready(labels)
            stats.stage_seconds["detect_s"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        sg, q = stream_supergraph(
            stream, labels, gdeg, n_nodes, s_cap, max_super_edges, cms_cfg,
            put=put, prefetch=cfg.prefetch, stats=stats,
            with_modularity=with_modularity,
            agg_backend=cfg.agg_backend, time_agg=cfg.time_agg,
            mesh=cfg.mesh, shard=cfg.shard_detect, tracer=tr,
            ckpt=checkpoint, resume=resume_sg,
        )
        jax.block_until_ready(sg.edges)
        stats.stage_seconds["supergraph_s"] = time.perf_counter() - t0
    stats.seconds = sum(stats.stage_seconds.values())
    stats.retries = stream.acct.retries
    # acct.quarantined is per-occurrence (a bad chunk is hit once per pass);
    # the stats mirror reports distinct chunks.
    qids = sorted(set(stream.acct.quarantined))
    stats.quarantined_chunks = len(qids)
    stats.quarantined_chunk_ids = qids
    stats.dropped_edges = stream.acct.dropped_edges
    stats.publish()
    return labels, gdeg, sg, q, stats


def oneshot_device_bytes(n_edges: int, n_nodes: int) -> int:
    """Resident bytes the one-shot path pins just to hold the inputs: the
    full padded edge list + node-sized state. The streaming engine's
    ``peak_device_bytes`` replaces the |E| term with one chunk buffer."""
    return n_edges * 2 * 4 + 2 * (n_nodes + 1) * 4
