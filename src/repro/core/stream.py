"""Streaming chunked-edge execution engine (paper §3: community information
extracted "in a few passes on the edge list").

The one-shot pipeline materializes the whole padded edge list on device
before any stage runs, capping the reproduction at device-memory scale.
This engine instead keeps the edge list on the host and drives every
edge-consuming stage over fixed-size chunks:

    host NumPy edge list ──► EdgeChunkStream (padded chunk buffers)
        ──► per-chunk jitted update steps, state donated
            (SCoDA labels+degrees · graph degrees · superedge aggregation
             · modularity accumulators · CMS sketch)
        ──► finalize: Supergraph + labels, device-resident node-sized state

Device residency is O(n_nodes + chunk_size + max_super_edges + sketch) —
independent of |E| — so edge lists larger than device memory process in
``rounds + 1`` passes: rounds SCoDA passes (graph degrees fused into the
first) plus one fused supergraph-aggregation / modularity pass.

Bit-exactness: every stage's one-shot function is a thin wrapper over the
same chunk-update body (single chunk = whole list), and the SCoDA block
partition is preserved because chunk sizes are rounded up to a multiple of
``ScodaConfig.block_size`` — so chunked and one-shot runs produce identical
labels, supergraphs, and modularity (see tests/test_stream.py).

This is the single-device engine; ``launch/stream_runner.py`` adds device
placement/sharding and host prefetch, and is the substrate for the
multi-device edge-sharded form promised in core/pipeline.py's docstring.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cms as cms_lib
from repro.core.modularity import modularity_finalize, modularity_init, modularity_update
from repro.core.scoda import (
    ScodaConfig,
    dense_labels,
    round_threshold,
    scoda_finalize,
    scoda_init,
    scoda_update,
)
from repro.core.supergraph import (
    Supergraph,
    agg_finalize,
    agg_init,
    agg_update,
    community_sizes,
)


@dataclass(frozen=True)
class StreamConfig:
    """Engine knobs. ``chunk_size`` is rounded up to a multiple of the SCoDA
    block size so the chunked block partition matches the one-shot one."""

    chunk_size: int = 1 << 16  # edges resident on device per chunk
    prefetch: int = 1  # host→device copies dispatched ahead of compute


@dataclass
class StreamStats:
    """Per-run accounting; ``peak_device_bytes`` is the analytic resident
    footprint of the streaming state (chunk buffer + node/sketch/agg state),
    the number the one-shot path's full edge materialization is compared to."""

    passes: int = 0
    chunks: int = 0
    edges_streamed: int = 0
    seconds: float = 0.0
    chunk_size: int = 0
    peak_device_bytes: int = 0
    stage_seconds: dict = field(default_factory=dict)

    @property
    def edges_per_s(self) -> float:
        return self.edges_streamed / self.seconds if self.seconds > 0 else 0.0


def tree_bytes(*trees) -> int:
    """Total bytes of every array leaf across the given pytrees."""
    total = 0
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "dtype"):
                total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total


class EdgeChunkStream:
    """Host-side chunked view over a NumPy edge list.

    Yields [chunk_size, 2] int32 chunks; the tail chunk is padded with the
    trash node ``n_nodes`` (a no-op for every chunk-update body). The padded
    tail buffer is allocated once and reused across passes — the host-side
    analog of a pinned staging buffer. Iterating counts one pass.
    """

    def __init__(self, edges: np.ndarray, n_nodes: int, chunk_size: int,
                 block_size: int = 1):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        self.edges = np.ascontiguousarray(edges, dtype=np.int32)
        self.n_nodes = n_nodes
        # Round up so chunk boundaries align with SCoDA block boundaries,
        # and clamp to the padded edge list — a chunk larger than |E| would
        # only buy a bigger trash-padded buffer.
        bs = max(1, block_size)
        self.n_edges = len(self.edges)
        cap = max(bs, ((self.n_edges + bs - 1) // bs) * bs)
        self.chunk_size = min(((chunk_size + bs - 1) // bs) * bs, cap)
        self.n_chunks = max(1, -(-self.n_edges // self.chunk_size))
        self.passes = 0
        # The tail chunk is identical every pass, so its padded buffer is
        # filled once and never mutated — safe even when the host→device
        # transfer aliases host memory (zero-copy device_put).
        start = (self.n_chunks - 1) * self.chunk_size
        self._tail_buf = np.full((self.chunk_size, 2), n_nodes, dtype=np.int32)
        self._tail_buf[: self.n_edges - start] = self.edges[start:]

    def __len__(self) -> int:
        return self.n_chunks

    @property
    def chunk_bytes(self) -> int:
        return self.chunk_size * 2 * 4

    def __iter__(self):
        self.passes += 1
        cs = self.chunk_size
        for i in range(self.n_chunks - 1):
            yield self.edges[i * cs:(i + 1) * cs]
        yield self._tail_buf


def _prefetched(stream: EdgeChunkStream, put, depth: int):
    """Host→device copy dispatched ``depth`` chunks ahead of compute."""
    if depth <= 0:
        for chunk in stream:
            yield put(chunk)
        return
    queue = []
    it = iter(stream)
    for chunk in it:
        queue.append(put(chunk))
        if len(queue) > depth:
            yield queue.pop(0)
    yield from queue


@functools.partial(jax.jit, donate_argnums=(0,))
def _degree_update(deg, chunk):
    """Chunk-incremental graph degrees ([n+1] accumulator, trash last)."""
    deg = deg.at[chunk[:, 0]].add(1)
    deg = deg.at[chunk[:, 1]].add(1)
    return deg.at[-1].set(0)


def stream_detect(
    stream: EdgeChunkStream,
    n_nodes: int,
    cfg: ScodaConfig,
    *,
    put=jnp.asarray,
    prefetch: int = 1,
    stats: StreamStats | None = None,
):
    """Multi-round SCoDA over the chunk stream; graph degrees are fused into
    the first pass. Returns (labels [n], scoda_deg [n], graph_deg [n])."""
    state = scoda_init(n_nodes)
    gdeg = jnp.zeros(n_nodes + 1, dtype=jnp.int32)
    for r in range(cfg.rounds):
        thr = jnp.int32(round_threshold(cfg, r))
        for chunk in _prefetched(stream, put, prefetch):
            if r == 0:
                gdeg = _degree_update(gdeg, chunk)
            state = scoda_update(state, chunk, thr, cfg)
            if stats is not None:
                stats.chunks += 1
                stats.edges_streamed += chunk.shape[0]
    if stats is not None:
        stats.passes += cfg.rounds
        stats.peak_device_bytes = max(
            stats.peak_device_bytes,
            tree_bytes(state, gdeg)
            + stream.chunk_bytes * min(stream.n_chunks, 1 + max(0, prefetch)),
        )
    labels, scoda_deg = scoda_finalize(state, n_nodes, cfg)
    return labels, scoda_deg, gdeg[:n_nodes]


def stream_supergraph(
    stream: EdgeChunkStream,
    labels: jnp.ndarray,
    node_deg: jnp.ndarray,
    n_nodes: int,
    s_cap: int,
    max_super_edges: int,
    cms_cfg: cms_lib.CMSConfig,
    *,
    put=jnp.asarray,
    prefetch: int = 1,
    stats: StreamStats | None = None,
    with_modularity: bool = True,
):
    """One fused pass: superedge aggregation + modularity accumulation.

    CMS community sizing is node-keyed (one sketch update per node, weight =
    graph degree) and so needs no edge pass. Returns (Supergraph, Q) with Q
    None when ``with_modularity`` is false.
    """
    labels_dense, n_supernodes = dense_labels(labels, n_nodes)
    sizes = community_sizes(labels_dense, node_deg, n_supernodes, s_cap, cms_cfg)

    agg_ext = jnp.concatenate([labels_dense, jnp.array([s_cap], jnp.int32)])
    mod_ext = jnp.concatenate([labels_dense, jnp.array([-1], jnp.int32)])
    agg = agg_init(s_cap, max_super_edges)
    mod = modularity_init(n_nodes) if with_modularity else None
    for chunk in _prefetched(stream, put, prefetch):
        agg = agg_update(agg, chunk, agg_ext, s_cap, max_super_edges)
        if with_modularity:
            mod = modularity_update(mod, chunk, mod_ext)
        if stats is not None:
            stats.chunks += 1
            stats.edges_streamed += chunk.shape[0]
    if stats is not None:
        stats.passes += 1
        stats.peak_device_bytes = max(
            stats.peak_device_bytes,
            tree_bytes(agg, mod, labels_dense, sizes, node_deg)
            + stream.chunk_bytes * min(stream.n_chunks, 1 + max(0, prefetch)),
        )
    sedges, sweights, n_superedges = agg_finalize(agg)
    q = modularity_finalize(mod) if with_modularity else None
    sg = Supergraph(
        edges=sedges,
        weights=sweights,
        sizes=sizes,
        n_supernodes=n_supernodes,
        n_superedges=n_superedges,
        labels=labels_dense,
    )
    return sg, q


def stream_pipeline(
    edges_np: np.ndarray,
    n_nodes: int,
    scoda_cfg: ScodaConfig,
    cms_cfg: cms_lib.CMSConfig,
    s_cap: int,
    max_super_edges: int,
    stream_cfg: StreamConfig | None = None,
    *,
    put=jnp.asarray,
    with_modularity: bool = True,
):
    """Edge stream → (labels, graph degrees, Supergraph, Q, StreamStats).

    The engine's full edge-consuming pipeline; layout/coloring operate on
    the (small, device-resident) supergraph and stay with the caller.
    """
    cfg = stream_cfg or StreamConfig(chunk_size=max(1, len(edges_np)))
    stream = EdgeChunkStream(
        edges_np, n_nodes, cfg.chunk_size, block_size=scoda_cfg.block_size
    )
    stats = StreamStats(chunk_size=stream.chunk_size)
    t0 = time.perf_counter()
    labels, _scoda_deg, gdeg = stream_detect(
        stream, n_nodes, scoda_cfg, put=put, prefetch=cfg.prefetch, stats=stats
    )
    jax.block_until_ready(labels)
    stats.stage_seconds["detect_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    sg, q = stream_supergraph(
        stream, labels, gdeg, n_nodes, s_cap, max_super_edges, cms_cfg,
        put=put, prefetch=cfg.prefetch, stats=stats,
        with_modularity=with_modularity,
    )
    jax.block_until_ready(sg.edges)
    stats.stage_seconds["supergraph_s"] = time.perf_counter() - t0
    stats.seconds = sum(stats.stage_seconds.values())
    return labels, gdeg, sg, q, stats


def oneshot_device_bytes(n_edges: int, n_nodes: int) -> int:
    """Resident bytes the one-shot path pins just to hold the inputs: the
    full padded edge list + node-sized state. The streaming engine's
    ``peak_device_bytes`` replaces the |E| term with one chunk buffer."""
    return n_edges * 2 * 4 + 2 * (n_nodes + 1) * 4
