"""ForceAtlas2 (Jacomy et al. 2014) in JAX — paper §3.1 / Algorithm 1.

Faithful force model:
  * gravity            f_g(i)  = kg · m_i · (towards origin)
  * attraction         f_a(e)  = w_e · (x_v − x_u)            (linear FA2)
  * repulsion          f_r(i,j)= kr · m_i · m_j / d(i,j)       (along unit vec)
  * adaptive speed     swing/traction + global & local speeds  (Algorithm 1 l.23)

with mass m_i = deg_i + 1 for plain graphs and m_i = community size for
supernodes (paper §4.1: radius ∝ √size; repulsion distance shifted by
radii so big supernodes get the space they need).

Repulsion backends (``repulsion=``):

  * "exact"       — tiled O(n²) pairwise (Pallas kernel on TPU, chunked jnp
                    on CPU; kernels/repulsion). The right choice for
                    supergraphs (n ≤ ~2·10⁵), where n² elementwise beats
                    tree codes on a systolic machine, and the only backend
                    honoring ``use_radii``.
  * "grid"        — uniform-grid monopole far field + banded same-cell
                    near field (kernels/grid), auto-dispatched: Pallas
                    tiles on TPU, the chunked/shifted XLA path elsewhere.
                    O(n·(G² + W)) work with an O(tile·G²) live set — the
                    full-graph fast path (n ≳ 10⁵, up to paper scale).
  * "grid_pallas" — same math, Pallas kernels forced (interpret mode off
                    TPU; for validation and kernel benchmarking).
  * "grid_dense"  — the legacy dense formulation materializing an
                    [n, G², 2] far-field tensor per iteration (≈100 GB at
                    the paper's 3M nodes with G=64). Kept only as the
                    baseline ``benchmarks/fa2_bench.py`` measures the tiled
                    backends against — do not use at scale.

``layout`` hoists everything reusable out of the iteration scan: positions,
weights and mass live in ``cfg.dtype``; radii √mass are computed once per
call; attraction edges are pre-sorted once into a directed segment layout
and accumulated per iteration with one sorted ``kernels/segment``
segment-sum (``indices_are_sorted`` fast path) instead of two unsorted
scatter-adds; and the grid backends carry (cell ids, cell-sorted order)
through the scan, rebuilding them every ``grid_rebuild`` iterations
(default 1 = rebuild each step, the exact legacy semantics; larger values
amortize the per-iteration argsort against slightly stale binning —
monopole masses/centroids always track the current positions).

Iterations run under ``lax.scan``; 100 iterations suffice for supergraphs
(paper §4.2.3) vs 500 for full graphs.

Convergence engineering (BatchLayout, PAPERS.md): the fixed iteration
count is an upper bound, not a schedule. With ``stop_tolerance`` > 0 the
scan carries a ``converged`` flag and freezes the body via ``lax.cond``
once the controller's global swing falls to ``stop_tolerance`` × global
traction (after ``min_iterations``) — same compiled shape, near-zero cost
for frozen steps, and ``layout`` reports ``iterations_run``. The
per-iteration trace is (g_swing, g_traction, global_speed); rows past
``iterations_run`` are zero. ``init`` picks the starting positions:
"random" (legacy uniform), "degree" (golden-angle sunflower spiral, heavy
nodes at the center), or "bfs" (hop-distance rings from the heaviest
node) — structured inits start closer to equilibrium so the stop
criterion triggers earlier.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.grid import ops as grid_ops
from repro.kernels.repulsion import ops as repulsion_ops
from repro.kernels.segment import ops as segment_ops

_GOLDEN_ANGLE = 2.3999632297286533  # π(3 − √5)


@dataclass(frozen=True)
class FA2Config:
    iterations: int = 100
    gravity: float = 1.0
    repulsion_k: float = 80.0  # paper §5.1: kr = 80, kg = 1 for all networks
    strong_gravity: bool = False
    jitter_tolerance: float = 1.0  # τ in the FA2 speed controller
    repulsion: str = "exact"  # "exact" | "grid" | "grid_pallas" | "grid_dense"
    grid_size: int = 64
    grid_window: int = 32  # near-field band half-width of grid repulsion
    grid_rebuild: int = 1  # re-bin/re-sort cells every k iterations
    use_radii: bool = True  # supernode radii shift repulsion distances
    seed: int = 0
    dtype: str = "float32"  # position/force dtype of the layout loop
    # Adaptive stopping: freeze the scan body once
    # g_swing <= stop_tolerance * g_traction (0.0 = fixed iterations).
    stop_tolerance: float = 0.0
    min_iterations: int = 0  # never stop before this many iterations
    init: str = "random"  # "random" | "degree" | "bfs"
    init_bfs_rounds: int = 32  # BFS depth-propagation rounds for init="bfs"
    # Divergence sentinel (resilience, ISSUE 10): when on, an iteration
    # whose forces contain a non-finite value is rolled back (positions and
    # speed-controller memory kept) with the global speed halved, instead
    # of NaN-poisoning every later position. Recovered iterations trace as
    # [-1, -1, damped_speed] rows — ``recovery_count`` tallies them. Off by
    # default: the guard-off graph is bit-identical to pre-sentinel code.
    nan_guard: bool = False


def init_positions(
    n: int, key: jax.Array, scale: float = 1000.0, dtype: str = "float32"
) -> jnp.ndarray:
    return jax.random.uniform(
        key, (n, 2), minval=-scale, maxval=scale, dtype=jnp.dtype(dtype)
    )


def init_positions_degree(
    n: int, mass: jnp.ndarray, scale: float = 1000.0, dtype: str = "float32"
) -> jnp.ndarray:
    """Degree-greedy sunflower init: nodes placed on a golden-angle spiral
    in descending-mass order, so hubs start at the center — where FA2's
    equilibrium puts them — and leaves at the rim. Deterministic (argsort
    ties break by index) and collision-free (every radius is distinct)."""
    rank = jnp.zeros(n, jnp.int32).at[jnp.argsort(-mass)].set(
        jnp.arange(n, dtype=jnp.int32)
    )
    rf = rank.astype(jnp.float32)
    r = scale * jnp.sqrt((rf + 0.5) / n)
    theta = rf * _GOLDEN_ANGLE
    pos = jnp.stack([r * jnp.cos(theta), r * jnp.sin(theta)], axis=1)
    return pos.astype(jnp.dtype(dtype))


def init_positions_bfs(
    edges: jnp.ndarray,
    mass: jnp.ndarray,
    n: int,
    key: jax.Array,
    rounds: int = 32,
    smooth_rounds: int = 10,
    scale: float = 1000.0,
    dtype: str = "float32",
) -> jnp.ndarray:
    """BFS-ring + neighbor-smoothing init (the parallel analog of
    BatchLayout's greedy "place next to your placed neighbors").

    Scaffold: hop depths from the heaviest node via ``rounds`` scatter-min
    relaxations (jit-friendly fixed trip count; unreached nodes land one
    ring past the deepest reached one), radius ∝ depth, golden-angle
    azimuth + a small keyed radial jitter to break exact ring degeneracy.
    Then ``smooth_rounds`` Laplacian sweeps pull each node halfway to its
    neighbors' centroid (rescaled to the scaffold's RMS radius each sweep
    so the cloud doesn't collapse): graph-adjacent nodes — hence
    communities — start co-located, which is what lets the adaptive stop
    reach fixed-500-iteration quality in a fraction of the iterations
    (benchmarks/quality_bench.py gates exactly this). Padded edge slots
    (endpoint == n) write to trash rows that are dropped or reset."""
    u, v = edges[:, 0], edges[:, 1]
    seed_node = jnp.argmax(mass).astype(jnp.int32)
    unreached = jnp.int32(rounds + 1)
    depth = jnp.full(n + 1, unreached, jnp.int32).at[seed_node].set(0)

    def body(depth, _):
        new = depth.at[v].min(depth[u] + 1).at[u].min(depth[v] + 1)
        return new.at[n].set(unreached), None

    depth, _ = jax.lax.scan(body, depth, None, length=rounds)
    depth = depth[:n]
    deepest = jnp.max(jnp.where(depth >= unreached, 0, depth))
    d = jnp.where(depth >= unreached, deepest + 1, depth).astype(jnp.float32)
    r = scale * (d + 0.5) / (deepest.astype(jnp.float32) + 1.5)
    jitter = jax.random.uniform(key, (n,), dtype=jnp.float32)
    r = r * (0.9 + 0.2 * jitter)
    theta = jnp.arange(n, dtype=jnp.float32) * _GOLDEN_ANGLE
    pos = jnp.stack([r * jnp.cos(theta), r * jnp.sin(theta)], axis=1)

    deg = jnp.zeros(n + 1, jnp.float32).at[u].add(1.0).at[v].add(1.0)
    degn = jnp.maximum(deg[:n], 1.0)
    has_nbr = (deg[:n] > 0.0)[:, None]
    rms0 = jnp.sqrt(jnp.mean(jnp.sum(pos * pos, axis=1)))

    def smooth(pos, _):
        ext = jnp.concatenate([pos, jnp.zeros((1, 2), jnp.float32)])
        s = jnp.zeros((n + 1, 2), jnp.float32).at[u].add(ext[v]).at[v].add(ext[u])
        mean = s[:n] / degn[:, None]
        new = jnp.where(has_nbr, 0.5 * pos + 0.5 * mean, pos)
        rms = jnp.sqrt(jnp.mean(jnp.sum(new * new, axis=1)))
        return new * (rms0 / jnp.maximum(rms, 1e-9)), None

    pos, _ = jax.lax.scan(smooth, pos, None, length=smooth_rounds)
    return pos.astype(jnp.dtype(dtype))


def initial_positions(
    edges: jnp.ndarray, mass: jnp.ndarray, n: int, cfg: FA2Config
) -> jnp.ndarray:
    """Dispatch ``cfg.init``.

    ``layout`` and ``layout_sharded`` both take their default starting
    positions from the SAME compiled instance of this function
    (``_initial_positions_jit``) rather than tracing it inline: op-by-op
    eager execution and fused jit compilation round differently (e.g. FMA
    contraction in the spiral radii), and the sharded bit-identity
    contract needs the two entry points to start from bitwise-equal
    positions."""
    if cfg.init == "random":
        return init_positions(n, jax.random.PRNGKey(cfg.seed), dtype=cfg.dtype)
    if cfg.init == "degree":
        return init_positions_degree(n, jnp.asarray(mass), dtype=cfg.dtype)
    if cfg.init == "bfs":
        return init_positions_bfs(
            jnp.asarray(edges), jnp.asarray(mass), n,
            jax.random.PRNGKey(cfg.seed), rounds=cfg.init_bfs_rounds,
            dtype=cfg.dtype,
        )
    raise ValueError(
        f"unknown init {cfg.init!r}: expected 'random', 'degree', or 'bfs'"
    )


@functools.partial(jax.jit, static_argnames=("n", "cfg"))
def _initial_positions_jit(edges, mass, n: int, cfg: FA2Config):
    return initial_positions(edges, mass, n, cfg)


def _gravity(pos, mass, cfg: FA2Config):
    d = jnp.linalg.norm(pos, axis=-1, keepdims=True)
    unit = pos / jnp.maximum(d, 1e-9)
    if cfg.strong_gravity:
        return -cfg.gravity * mass[:, None] * pos
    return -cfg.gravity * mass[:, None] * unit


def _attraction(pos, edges, weights, n: int):
    """Σ over incident edges of w·(x_other − x_self); padded slots hit trash.

    Unsorted two-scatter form — the single-``step`` path. ``layout``
    pre-sorts the edges once and uses ``_attraction_sorted`` instead.
    """
    u, v = edges[:, 0], edges[:, 1]
    pos_ext = jnp.concatenate([pos, jnp.zeros((1, 2), pos.dtype)])
    delta = pos_ext[v] - pos_ext[u]  # force on u toward v
    f = weights[:, None] * delta
    force = jnp.zeros((n + 1, 2), pos.dtype)
    force = force.at[u].add(f)
    force = force.at[v].add(-f)
    return force[:n]


def _attraction_edge_layout(edges, weights):
    """Directed segment layout, built once per ``layout`` call: both edge
    directions concatenated and sorted by source node, so each iteration's
    accumulation is one sorted segment-sum. Padded slots (trash endpoints
    == n) sort last and are dropped by the segment-sum's range check."""
    u, v = edges[:, 0], edges[:, 1]
    src = jnp.concatenate([u, v])
    dst = jnp.concatenate([v, u])
    w2 = jnp.concatenate([weights, weights])
    order = jnp.argsort(src)
    return src[order], dst[order], w2[order]


def _attraction_sorted(pos, src, dst, w, n: int):
    """Σ over directed incident edges of w·(x_dst − x_src), src-sorted —
    the kernels/segment ``indices_are_sorted`` fast path.

    Pinned to the XLA ref backend: this sum has *n* segments, and the
    one-hot-matmul Pallas kernel streams every edge block once per node
    tile — O(n/tn · E) at full-graph n, where the sorted scatter is O(E).
    That kernel is for small-segment-count sums (supergraph aggregation,
    grid cell stats), not node-sized ones.
    """
    pos_ext = jnp.concatenate([pos, jnp.zeros((1, 2), pos.dtype)])
    f = w[:, None] * (pos_ext[dst] - pos_ext[src])
    return segment_ops.segment_sum(
        f, src, n, backend="ref", indices_are_sorted=True
    )


def _pair_force(dpos, mi, mj, kr):
    """kr·mi·mj/d along the unit vector, for a [..., 2] displacement."""
    d2 = jnp.sum(dpos * dpos, axis=-1)
    mag = kr * mi * mj / jnp.maximum(d2, 1e-4)  # (1/d along unit) = 1/d²·vec
    return mag[..., None] * dpos


def _grid_repulsion(pos, mass, cfg: FA2Config):
    """Dense uniform-grid repulsion — the ``grid_dense`` baseline.

    Same monopole-far-field + banded-near-field math as kernels/grid, in
    the original fully-materialized form: an [n, G², 2] far-field tensor
    plus an [n, 2W+1] near-field gather per call. Superseded by the tiled
    backends ("grid"/"grid_pallas"); retained as the benchmark baseline
    (benchmarks/fa2_bench.py) and as a semantics oracle in tests.
    """
    g = cfg.grid_size
    window = cfg.grid_window
    n = pos.shape[0]
    kr = cfg.repulsion_k
    lo = jnp.min(pos, axis=0)
    hi = jnp.max(pos, axis=0)
    extent = jnp.maximum(hi - lo, 1e-6)
    cell2d = jnp.clip(((pos - lo) / extent * g).astype(jnp.int32), 0, g - 1)
    cell = cell2d[:, 0] * g + cell2d[:, 1]
    n_cells = g * g
    cmass = jnp.zeros(n_cells, pos.dtype).at[cell].add(mass)
    cpos = jnp.zeros((n_cells, 2), pos.dtype).at[cell].add(pos * mass[:, None])
    ccent = cpos / jnp.maximum(cmass, 1e-9)[:, None]

    # Far field: node → every cell monopole.
    diff = pos[:, None, :] - ccent[None, :, :]  # [n, G², 2]
    force = jnp.sum(_pair_force(diff, mass[:, None], cmass[None, :], kr), axis=1)

    # Subtract the own-cell monopole (it badly approximates near field + self).
    own_diff = pos - ccent[cell]
    own_f = _pair_force(own_diff, mass, cmass[cell], kr)
    force = force - own_f

    # Exact near field: same-cell neighbors are contiguous after sorting.
    order = jnp.argsort(cell)
    inv = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    pos_s, mass_s, cell_s = pos[order], mass[order], cell[order]
    p = jnp.arange(n)
    offs = jnp.arange(-window, window + 1)
    raw = p[:, None] + offs[None, :]  # [n, 2W+1]
    in_range = (raw >= 0) & (raw < n)  # clipping would duplicate endpoints
    nbr = jnp.clip(raw, 0, n - 1)
    same = in_range & (cell_s[nbr] == cell_s[:, None]) & (nbr != p[:, None])
    dn = pos_s[:, None, :] - pos_s[nbr]
    fn = _pair_force(dn, mass_s[:, None], jnp.where(same, mass_s[nbr], 0.0), kr)
    near = jnp.sum(fn, axis=1)
    force = force + near[inv]
    return force


def _repulsion_forces(pos, mass, radii, cfg: FA2Config, cell=None, order=None):
    """Dispatch one iteration's repulsion to the configured backend."""
    if cfg.repulsion == "grid_dense":
        return _grid_repulsion(pos, mass, cfg)
    if cfg.repulsion in ("grid", "grid_pallas"):
        backend = "auto" if cfg.repulsion == "grid" else "pallas"
        return grid_ops.grid_repulsion(
            pos, mass, cfg.repulsion_k, cfg.grid_size, cfg.grid_window,
            cell=cell, order=order, backend=backend,
        )
    r = radii if cfg.use_radii else None
    return repulsion_ops.repulsion(pos, mass, cfg.repulsion_k, radii=r)


def _apply_speed(state, f, mass, cfg: FA2Config):
    """FA2 speed controller (Algorithm 1): swing/traction → displacement.

    Returns the updated ``(pos, f, global_speed)`` state and the trace row
    ``[g_swing, g_traction, global_speed]`` — the quantities the adaptive
    stop criterion (and the convergence trace) are built from.
    """
    pos, prev_force, global_speed = state
    swing = jnp.linalg.norm(f - prev_force, axis=-1)
    traction = 0.5 * jnp.linalg.norm(f + prev_force, axis=-1)
    g_swing = jnp.sum(mass * swing) + 1e-9
    g_traction = jnp.sum(mass * traction)
    new_gs = cfg.jitter_tolerance * g_traction / g_swing
    global_speed = jnp.minimum(new_gs, 1.5 * global_speed + 1e-3)

    fmag = jnp.linalg.norm(f, axis=-1)
    local_speed = global_speed / (1.0 + global_speed * jnp.sqrt(swing))
    # FA2 caps node displacement: speed ≤ 10 / |f|.
    local_speed = jnp.minimum(local_speed, 10.0 / jnp.maximum(fmag, 1e-9))
    pos = pos + local_speed[:, None] * f
    row = jnp.stack([g_swing, g_traction, global_speed])
    return (pos, f, global_speed), row


def _apply_speed_guarded(state, f, mass, cfg: FA2Config):
    """``_apply_speed`` behind the divergence sentinel.

    With ``cfg.nan_guard`` off this IS ``_apply_speed`` (same jaxpr, so
    guard-off layouts stay bit-identical). With it on, a non-finite force
    array skips the update entirely — positions and speed-controller
    memory are kept, the global speed is halved (so a diverging step size
    shrinks until forces are finite again) — and the trace row is
    ``[-1, -1, damped_speed]``: g_swing is otherwise ≥ 1e-9, so negative
    rows unambiguously mark recoveries (``recovery_count``) and are
    excluded from the adaptive stop test.
    """
    if not cfg.nan_guard:
        return _apply_speed(state, f, mass, cfg)
    pos, prev_force, global_speed = state

    def recover():
        damped = 0.5 * global_speed
        neg = -jnp.ones((), damped.dtype)
        return (pos, prev_force, damped), jnp.stack([neg, neg, damped])

    return jax.lax.cond(
        jnp.all(jnp.isfinite(f)),
        lambda: _apply_speed(state, f, mass, cfg),
        recover,
    )


def recovery_count(trace) -> int:
    """Number of iterations the ``nan_guard`` sentinel rolled back in a
    ``layout``/``step`` trace (negative-g_swing rows)."""
    return int((np.asarray(trace)[:, 0] < 0).sum())


@functools.partial(jax.jit, static_argnames=("cfg", "n"))
def step(
    state, edges, weights, mass, radii, cfg: FA2Config, n: int,
    cell=None, order=None,
):
    """One FA2 iteration (Algorithm 1 body): forces → speeds → displacement.

    Single-step public API (launch/steps.py builds the distributed layout
    cell on it): edge scatter runs inside the call. For the grid backends,
    pass precomputed ``(cell, order)`` from ``kernels/grid.bin_and_sort``
    to skip the per-call re-bin + argsort — repeated-step callers refresh
    them every ``cfg.grid_rebuild`` steps, mirroring ``layout``'s scan
    carry. ``layout`` also hoists the edge sort — prefer it for full runs.

    Returns ``(state, trace_row)`` with the same ``[g_swing, g_traction,
    global_speed]`` row ``layout`` traces per iteration.
    """
    pos, _, _ = state
    f = _gravity(pos, mass, cfg)
    f = f + _attraction(pos, edges, weights, n)
    f = f + _repulsion_forces(pos, mass, radii, cfg, cell=cell, order=order)
    return _apply_speed_guarded(state, f, mass, cfg)


def layout(
    edges: jnp.ndarray,
    weights: jnp.ndarray,
    mass: jnp.ndarray,
    n: int,
    cfg: FA2Config,
    pos0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Run up to ``cfg.iterations`` FA2 steps.

    Returns ``(positions [n,2], trace [iterations,3], iterations_run)``.
    Trace rows are (g_swing, g_traction, global_speed) per iteration. With
    ``cfg.stop_tolerance`` > 0 the scan body freezes (via ``lax.cond``)
    once g_swing ≤ stop_tolerance · g_traction after ``min_iterations``;
    frozen iterations cost almost nothing and trace as zero rows, and
    ``iterations_run`` reports the live count (it is ``cfg.iterations``
    exactly when the tolerance never triggered or adaptivity is off).
    """
    from repro.obs.trace import get_tracer

    # Host-side span: brackets init + dispatch of the jitted scan (compile
    # time on first call). Never forces a device sync.
    with get_tracer().span(
        "fa2.layout", n=n, iterations=cfg.iterations,
        repulsion=cfg.repulsion, adaptive=cfg.stop_tolerance > 0.0,
    ):
        if pos0 is None:
            pos0 = _initial_positions_jit(edges, mass, n, cfg)
        return _layout_jit(edges, weights, mass, n, cfg, pos0)


@functools.partial(jax.jit, static_argnames=("cfg", "n"))
def _layout_jit(edges, weights, mass, n: int, cfg: FA2Config, pos0):
    dtype = jnp.dtype(cfg.dtype)
    pos = pos0.astype(dtype)
    weights = weights.astype(dtype)
    mass = mass.astype(dtype)
    # Hoisted per-call prep (once per layout, not once per iteration):
    radii = jnp.sqrt(jnp.maximum(mass, 0.0))  # paper: radius ∝ √size
    src, dst, w2 = _attraction_edge_layout(edges, weights)

    grid_state = cfg.repulsion in ("grid", "grid_pallas")
    # Carry (cell, order) through the scan only when a rebuild cadence > 1
    # actually reuses them; iteration 0 always rebuilds (0 % k == 0), so
    # the seed is never read and can be zeros.
    carry_grid = grid_state and cfg.grid_rebuild > 1
    adaptive = cfg.stop_tolerance > 0.0
    state = (pos, jnp.zeros_like(pos), jnp.asarray(1.0, dtype))
    if carry_grid:
        z = jnp.zeros(n, jnp.int32)
        state = state + (z, z)
    if adaptive:
        state = state + (jnp.asarray(0, jnp.int32), jnp.asarray(False))

    def live(core, cell, order, it):
        pos = core[0]
        if carry_grid:
            cell, order = jax.lax.cond(
                it % cfg.grid_rebuild == 0,
                lambda: grid_ops.bin_and_sort(pos, cfg.grid_size),
                lambda: (cell, order),
            )
        elif grid_state:
            cell, order = grid_ops.bin_and_sort(pos, cfg.grid_size)
        f = _gravity(pos, mass, cfg)
        f = f + _attraction_sorted(pos, src, dst, w2, n)
        f = f + _repulsion_forces(pos, mass, radii, cfg, cell=cell, order=order)
        core, row = _apply_speed_guarded(core, f, mass, cfg)
        return core, cell, order, row

    def body(state, it):
        core = state[:3]
        cell = order = None
        if carry_grid:
            cell, order = state[3], state[4]
        if not adaptive:
            core, cell, order, row = live(core, cell, order, it)
            return core + ((cell, order) if carry_grid else ()), row

        it_run, converged = state[-2], state[-1]

        def live_branch():
            c, cell2, order2, row = live(core, cell, order, it)
            # row[0] < 0 marks a nan_guard recovery — never "converged".
            done = (it + 1 >= cfg.min_iterations) & (row[0] >= 0) & (
                row[0] <= cfg.stop_tolerance * row[1]
            )
            out = c + ((cell2, order2) if carry_grid else ())
            return out + (it_run + 1, done), row

        def frozen_branch():
            return state, jnp.zeros(3, dtype)

        return jax.lax.cond(converged, frozen_branch, live_branch)

    state, trace = jax.lax.scan(body, state, jnp.arange(cfg.iterations))
    iterations_run = (
        state[-2] if adaptive else jnp.asarray(cfg.iterations, jnp.int32)
    )
    return state[0], trace, iterations_run


# --------------------------------------------------------------------------
# Node-partitioned multi-device layout (ROADMAP item 1, Arleo et al. in
# PAPERS.md): each device owns n/D consecutive nodes and computes only their
# forces; one tiled all_gather per iteration reassembles the force array for
# the (replicated) speed controller. Per-force-term placement:
#
#   gravity     — elementwise on the owned rows.
#   attraction  — full-size sorted segment-sum with non-owned sources
#                 weight-masked, owned rows sliced: owned segments receive
#                 exactly the single-device terms in the same order.
#   exact rep.  — n ≤ 2048: replicated dense ref, rows sliced (the CPU auto
#                 dispatch); n > 2048: ``repulsion_chunked_rows`` — the
#                 j-chunk scan math on the owned rows only (rows are
#                 independent, so bitwise equal at 1/D the work+memory).
#   grid rep.   — bin/sort/monopole stats replicated (O(n + G²)); far field
#                 row-sliced through ``far_field_ref`` (per-node cell sums);
#                 near field via the psum-free ``near_field_rows`` halo;
#                 sorted rows gathered, then the unsort scatter replicated.
#
# Every cross-device step is a concatenation (all_gather) — never a float
# reduction — so D-device layouts are bit-identical to the single-device
# CPU dispatch ("exact"/"grid" backends; tests/test_sharded_pipeline.py).
# The adaptive stop composes with this for free: the gathered force array
# (hence swing/traction, hence the converged flag) is replicated, so every
# device freezes on the same iteration.
# --------------------------------------------------------------------------


_FALLBACK_WARNED: set[str] = set()


def _warn_fallback(reason: str) -> None:
    """Warn once per distinct reason that a configured mesh disengaged."""
    if reason not in _FALLBACK_WARNED:
        _FALLBACK_WARNED.add(reason)
        warnings.warn(
            f"layout_sharded: falling back to single-device layout ({reason})",
            UserWarning,
            stacklevel=3,
        )


def _sharded_fallback_reason(n: int, cfg: FA2Config, mesh) -> str | None:
    """Why a non-None mesh cannot engage, or None if it can."""
    if mesh.size <= 1:
        return "mesh is trivial (1 device)"
    if n % mesh.size != 0:
        return f"n={n} does not divide evenly over {mesh.size} devices"
    if cfg.repulsion in ("grid_pallas", "grid_dense"):
        return f"repulsion={cfg.repulsion!r} has no sharded form"
    if cfg.repulsion == "grid" and cfg.dtype != "float32":
        return (
            f"the sharded grid path runs in float32 (kernels/grid is "
            f"float32-pinned) and has no dtype={cfg.dtype!r} form"
        )
    return None


@functools.lru_cache(maxsize=None)
def _sharded_layout_fn(mesh, cfg: FA2Config, n: int):
    from jax.sharding import PartitionSpec as P

    from repro.kernels.compat import shard_map_compat
    from repro.sharding.rules import linear_axis_index

    axes = tuple(mesh.axis_names)
    sizes = tuple(mesh.shape[a] for a in axes)
    nl = n // mesh.size
    dtype = jnp.dtype(cfg.dtype)
    grid_state = cfg.repulsion == "grid"
    carry_grid = grid_state and cfg.grid_rebuild > 1
    adaptive = cfg.stop_tolerance > 0.0
    kr = cfg.repulsion_k

    def sharded_body(pos0, mass, radii, src, dst, w2):
        i0 = nl * linear_axis_index(axes, sizes)

        def rows(x):
            return jax.lax.dynamic_slice_in_dim(x, i0, nl)

        state = (pos0, jnp.zeros_like(pos0), jnp.asarray(1.0, dtype))
        if carry_grid:
            z = jnp.zeros(n, jnp.int32)
            state = state + (z, z)
        if adaptive:
            state = state + (jnp.asarray(0, jnp.int32), jnp.asarray(False))

        def live(core, cell, order, it):
            pos = core[0]
            if carry_grid:
                cell, order = jax.lax.cond(
                    it % cfg.grid_rebuild == 0,
                    lambda: grid_ops.bin_and_sort(pos, cfg.grid_size),
                    lambda: (cell, order),
                )
            elif grid_state:
                cell, order = grid_ops.bin_and_sort(pos, cfg.grid_size)

            f_r = _gravity(rows(pos), rows(mass), cfg)

            pos_ext = jnp.concatenate([pos, jnp.zeros((1, 2), pos.dtype)])
            own = (src >= i0) & (src < i0 + nl)
            fe = jnp.where(own, w2, 0.0)[:, None] * (pos_ext[dst] - pos_ext[src])
            att = segment_ops.segment_sum(
                fe, src, n, backend="ref", indices_are_sorted=True
            )
            f_r = f_r + rows(att)

            if grid_state:
                # This path only engages for cfg.dtype == "float32"
                # (layout_sharded falls back otherwise): the kernels/grid
                # helpers are float32-pinned, so pos/mass are used as-is.
                pos_s, mass_s, cell_s = pos[order], mass[order], cell[order]
                ccent, cmass = grid_ops.cell_stats(
                    pos_s, mass_s, cell_s, cfg.grid_size * cfg.grid_size,
                    backend="ref",
                )
                force_sr = grid_ops.far_field_ref(
                    rows(pos_s), rows(mass_s), rows(cell_s), ccent, cmass, kr
                )
                force_sr = force_sr + grid_ops.near_field_rows(
                    pos_s, mass_s, cell_s, kr, cfg.grid_window, i0, nl
                )
                force_s = jax.lax.all_gather(force_sr, axes, axis=0, tiled=True)
                rep = jnp.zeros_like(force_s).at[order].set(force_s)
                f_r = f_r + rows(rep.astype(pos.dtype))
            else:
                r = radii if cfg.use_radii else None
                if n <= 2048:
                    f_r = f_r + rows(
                        repulsion_ops.repulsion(pos, mass, kr, radii=r,
                                                backend="ref")
                    )
                else:
                    f_r = f_r + repulsion_ops.repulsion_chunked_rows(
                        pos, mass, i0, nl, kr, radii=r,
                        use_radii=cfg.use_radii,
                    )

            f = jax.lax.all_gather(f_r, axes, axis=0, tiled=True)
            core, row = _apply_speed_guarded(core, f, mass, cfg)
            return core, cell, order, row

        def body(state, it):
            core = state[:3]
            cell = order = None
            if carry_grid:
                cell, order = state[3], state[4]
            if not adaptive:
                core, cell, order, row = live(core, cell, order, it)
                return core + ((cell, order) if carry_grid else ()), row

            it_run, converged = state[-2], state[-1]

            def live_branch():
                c, cell2, order2, row = live(core, cell, order, it)
                # row[0] < 0 marks a nan_guard recovery — never "converged".
                done = (it + 1 >= cfg.min_iterations) & (row[0] >= 0) & (
                    row[0] <= cfg.stop_tolerance * row[1]
                )
                out = c + ((cell2, order2) if carry_grid else ())
                return out + (it_run + 1, done), row

            def frozen_branch():
                return state, jnp.zeros(3, dtype)

            return jax.lax.cond(converged, frozen_branch, live_branch)

        state, trace = jax.lax.scan(body, state, jnp.arange(cfg.iterations))
        iterations_run = (
            state[-2] if adaptive else jnp.asarray(cfg.iterations, jnp.int32)
        )
        return state[0], trace, iterations_run

    mapped = shard_map_compat(
        sharded_body,
        mesh,
        in_specs=(P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P(), P()),
    )

    def run(edges, weights, mass, pos0):
        weights = weights.astype(dtype)
        mass = mass.astype(dtype)
        radii = jnp.sqrt(jnp.maximum(mass, 0.0))
        src, dst, w2 = _attraction_edge_layout(edges, weights)
        return mapped(pos0, mass, radii, src, dst, w2)

    return jax.jit(run)


def layout_sharded(
    edges: jnp.ndarray,
    weights: jnp.ndarray,
    mass: jnp.ndarray,
    n: int,
    cfg: FA2Config,
    mesh,
    pos0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """``layout`` with the force pass node-partitioned over ``mesh``.

    Falls back to ``layout`` — with a warn-once ``UserWarning`` naming the
    reason — when the mesh is trivial, ``n`` doesn't divide by the device
    count, the backend has no sharded form ("grid_pallas", "grid_dense"),
    or the grid backend is asked for a non-float32 dtype (kernels/grid is
    float32-pinned, so honoring ``cfg.dtype`` sharded is impossible; the
    single-device path keeps its cast-in/cast-out semantics). ``mesh=None``
    falls back silently — that is the caller opting out, not a surprise.
    Bit-identical to the single-device *CPU* dispatch of "exact"/"grid"
    (on TPU, ``layout``'s auto-dispatch picks Pallas kernels this path
    does not mirror), including the adaptive stop: the converged flag is
    computed from the replicated gathered forces, so the sharded run
    freezes on exactly the same iteration.
    """
    if mesh is None:
        return layout(edges, weights, mass, n, cfg, pos0)
    reason = _sharded_fallback_reason(n, cfg, mesh)
    if reason is not None:
        _warn_fallback(reason)
        return layout(edges, weights, mass, n, cfg, pos0)
    from repro.obs.trace import get_tracer

    with get_tracer().span(
        "fa2.layout_sharded", n=n, iterations=cfg.iterations,
        repulsion=cfg.repulsion, devices=mesh.size,
    ):
        dtype = jnp.dtype(cfg.dtype)
        pos = (
            _initial_positions_jit(edges, mass, n, cfg)
            if pos0 is None
            else pos0.astype(dtype)
        )
        return _sharded_layout_fn(mesh, cfg, n)(edges, weights, mass, pos)
