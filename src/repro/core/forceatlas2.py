"""ForceAtlas2 (Jacomy et al. 2014) in JAX — paper §3.1 / Algorithm 1.

Faithful force model:
  * gravity            f_g(i)  = kg · m_i · (towards origin)
  * attraction         f_a(e)  = w_e · (x_v − x_u)            (linear FA2)
  * repulsion          f_r(i,j)= kr · m_i · m_j / d(i,j)       (along unit vec)
  * adaptive speed     swing/traction + global & local speeds  (Algorithm 1 l.23)

with mass m_i = deg_i + 1 for plain graphs and m_i = community size for
supernodes (paper §4.1: radius ∝ √size; repulsion distance shifted by
radii so big supernodes get the space they need).

Repulsion backends (``repulsion=``):
  * "exact"  — tiled O(n²) pairwise (Pallas kernel on TPU, chunked jnp on
               CPU) — the right choice for supergraphs (n ≤ ~2·10⁵), where
               n² elementwise beats tree codes on a systolic machine;
  * "grid"   — uniform-grid monopole far-field: the TPU-native analogue of
               Barnes–Hut (DESIGN.md §2) for full-graph layouts.

Iterations run under ``lax.scan``; 100 iterations suffice for supergraphs
(paper §4.2.3) vs 500 for full graphs.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.repulsion import ops as repulsion_ops


@dataclass(frozen=True)
class FA2Config:
    iterations: int = 100
    gravity: float = 1.0
    repulsion_k: float = 80.0  # paper §5.1: kr = 80, kg = 1 for all networks
    strong_gravity: bool = False
    jitter_tolerance: float = 1.0  # τ in the FA2 speed controller
    repulsion: str = "exact"  # "exact" | "grid"
    grid_size: int = 64
    grid_window: int = 32  # near-field band half-width of "grid" repulsion
    use_radii: bool = True  # supernode radii shift repulsion distances
    seed: int = 0
    dtype: str = "float32"


def init_positions(n: int, key: jax.Array, scale: float = 1000.0) -> jnp.ndarray:
    return jax.random.uniform(key, (n, 2), minval=-scale, maxval=scale)


def _gravity(pos, mass, cfg: FA2Config):
    d = jnp.linalg.norm(pos, axis=-1, keepdims=True)
    unit = pos / jnp.maximum(d, 1e-9)
    if cfg.strong_gravity:
        return -cfg.gravity * mass[:, None] * pos
    return -cfg.gravity * mass[:, None] * unit


def _attraction(pos, edges, weights, n: int):
    """Σ over incident edges of w·(x_other − x_self); padded slots hit trash."""
    u, v = edges[:, 0], edges[:, 1]
    pos_ext = jnp.concatenate([pos, jnp.zeros((1, 2), pos.dtype)])
    delta = pos_ext[v] - pos_ext[u]  # force on u toward v
    f = weights[:, None] * delta
    force = jnp.zeros((n + 1, 2), pos.dtype)
    force = force.at[u].add(f)
    force = force.at[v].add(-f)
    return force[:n]

def _pair_force(dpos, mi, mj, kr):
    """kr·mi·mj/d along the unit vector, for a [..., 2] displacement."""
    d2 = jnp.sum(dpos * dpos, axis=-1)
    mag = kr * mi * mj / jnp.maximum(d2, 1e-4)  # (1/d along unit) = 1/d²·vec
    return mag[..., None] * dpos


def _grid_repulsion(pos, mass, cfg: FA2Config):
    """Uniform-grid repulsion — the TPU-native Barnes–Hut analogue.

    Far field: bin nodes into G×G cells (segment-sum centroids/masses —
    structured, gatherable) and let every node interact with every cell
    *monopole*; this mirrors BH's θ-acceptance of coarse cells. Near field:
    BH recurses inside the node's own region, so we subtract the own-cell
    monopole and replace it with *exact* pairwise interaction against
    same-cell nodes, found contiguously after a sort-by-cell (a
    ±``cfg.grid_window`` band — exact for cells with ≤ grid_window
    members). O(n·(G² + grid_window)), fully dense ops, no pointer chasing.
    """
    g = cfg.grid_size
    window = cfg.grid_window
    n = pos.shape[0]
    kr = cfg.repulsion_k
    lo = jnp.min(pos, axis=0)
    hi = jnp.max(pos, axis=0)
    extent = jnp.maximum(hi - lo, 1e-6)
    cell2d = jnp.clip(((pos - lo) / extent * g).astype(jnp.int32), 0, g - 1)
    cell = cell2d[:, 0] * g + cell2d[:, 1]
    n_cells = g * g
    cmass = jnp.zeros(n_cells, pos.dtype).at[cell].add(mass)
    cpos = jnp.zeros((n_cells, 2), pos.dtype).at[cell].add(pos * mass[:, None])
    ccent = cpos / jnp.maximum(cmass, 1e-9)[:, None]

    # Far field: node → every cell monopole.
    diff = pos[:, None, :] - ccent[None, :, :]  # [n, G², 2]
    force = jnp.sum(_pair_force(diff, mass[:, None], cmass[None, :], kr), axis=1)

    # Subtract the own-cell monopole (it badly approximates near field + self).
    own_diff = pos - ccent[cell]
    own_f = _pair_force(own_diff, mass, cmass[cell], kr)
    force = force - own_f

    # Exact near field: same-cell neighbors are contiguous after sorting.
    order = jnp.argsort(cell)
    inv = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    pos_s, mass_s, cell_s = pos[order], mass[order], cell[order]
    p = jnp.arange(n)
    offs = jnp.arange(-window, window + 1)
    raw = p[:, None] + offs[None, :]  # [n, 2W+1]
    in_range = (raw >= 0) & (raw < n)  # clipping would duplicate endpoints
    nbr = jnp.clip(raw, 0, n - 1)
    same = in_range & (cell_s[nbr] == cell_s[:, None]) & (nbr != p[:, None])
    dn = pos_s[:, None, :] - pos_s[nbr]
    fn = _pair_force(dn, mass_s[:, None], jnp.where(same, mass_s[nbr], 0.0), kr)
    near = jnp.sum(fn, axis=1)
    force = force + near[inv]
    return force


@functools.partial(jax.jit, static_argnames=("cfg", "n"))
def step(state, edges, weights, mass, radii, cfg: FA2Config, n: int):
    """One FA2 iteration (Algorithm 1 body): forces → speeds → displacement."""
    pos, prev_force, global_speed = state
    f = _gravity(pos, mass, cfg)
    f = f + _attraction(pos, edges, weights, n)
    if cfg.repulsion == "grid":
        f = f + _grid_repulsion(pos, mass, cfg)
    else:
        r = radii if cfg.use_radii else None
        f = f + repulsion_ops.repulsion(pos, mass, cfg.repulsion_k, radii=r)

    # Swing / traction (FA2 §"speed optimization").
    swing = jnp.linalg.norm(f - prev_force, axis=-1)
    traction = 0.5 * jnp.linalg.norm(f + prev_force, axis=-1)
    g_swing = jnp.sum(mass * swing) + 1e-9
    g_traction = jnp.sum(mass * traction)
    new_gs = cfg.jitter_tolerance * g_traction / g_swing
    global_speed = jnp.minimum(new_gs, 1.5 * global_speed + 1e-3)

    fmag = jnp.linalg.norm(f, axis=-1)
    local_speed = global_speed / (1.0 + global_speed * jnp.sqrt(swing))
    # FA2 caps node displacement: speed ≤ 10 / |f|.
    local_speed = jnp.minimum(local_speed, 10.0 / jnp.maximum(fmag, 1e-9))
    pos = pos + local_speed[:, None] * f
    return (pos, f, global_speed), fmag


@functools.partial(jax.jit, static_argnames=("cfg", "n"))
def layout(
    edges: jnp.ndarray,
    weights: jnp.ndarray,
    mass: jnp.ndarray,
    n: int,
    cfg: FA2Config,
    pos0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run ``cfg.iterations`` FA2 steps. Returns (positions [n,2], trace)."""
    key = jax.random.PRNGKey(cfg.seed)
    pos = init_positions(n, key) if pos0 is None else pos0
    radii = jnp.sqrt(jnp.maximum(mass, 0.0))  # paper: radius ∝ √size
    state = (pos, jnp.zeros_like(pos), jnp.asarray(1.0, pos.dtype))

    def body(state, _):
        state, fmag = step(state, edges, weights, mass, radii, cfg, n)
        return state, jnp.max(fmag)

    state, trace = jax.lax.scan(body, state, None, length=cfg.iterations)
    return state[0], trace
