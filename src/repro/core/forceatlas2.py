"""ForceAtlas2 (Jacomy et al. 2014) in JAX — paper §3.1 / Algorithm 1.

Faithful force model:
  * gravity            f_g(i)  = kg · m_i · (towards origin)
  * attraction         f_a(e)  = w_e · (x_v − x_u)            (linear FA2)
  * repulsion          f_r(i,j)= kr · m_i · m_j / d(i,j)       (along unit vec)
  * adaptive speed     swing/traction + global & local speeds  (Algorithm 1 l.23)

with mass m_i = deg_i + 1 for plain graphs and m_i = community size for
supernodes (paper §4.1: radius ∝ √size; repulsion distance shifted by
radii so big supernodes get the space they need).

Repulsion backends (``repulsion=``):

  * "exact"       — tiled O(n²) pairwise (Pallas kernel on TPU, chunked jnp
                    on CPU; kernels/repulsion). The right choice for
                    supergraphs (n ≤ ~2·10⁵), where n² elementwise beats
                    tree codes on a systolic machine, and the only backend
                    honoring ``use_radii``.
  * "grid"        — uniform-grid monopole far field + banded same-cell
                    near field (kernels/grid), auto-dispatched: Pallas
                    tiles on TPU, the chunked/shifted XLA path elsewhere.
                    O(n·(G² + W)) work with an O(tile·G²) live set — the
                    full-graph fast path (n ≳ 10⁵, up to paper scale).
  * "grid_pallas" — same math, Pallas kernels forced (interpret mode off
                    TPU; for validation and kernel benchmarking).
  * "grid_dense"  — the legacy dense formulation materializing an
                    [n, G², 2] far-field tensor per iteration (≈100 GB at
                    the paper's 3M nodes with G=64). Kept only as the
                    baseline ``benchmarks/fa2_bench.py`` measures the tiled
                    backends against — do not use at scale.

``layout`` hoists everything reusable out of the iteration scan: positions,
weights and mass live in ``cfg.dtype``; radii √mass are computed once per
call; attraction edges are pre-sorted once into a directed segment layout
and accumulated per iteration with one sorted ``kernels/segment``
segment-sum (``indices_are_sorted`` fast path) instead of two unsorted
scatter-adds; and the grid backends carry (cell ids, cell-sorted order)
through the scan, rebuilding them every ``grid_rebuild`` iterations
(default 1 = rebuild each step, the exact legacy semantics; larger values
amortize the per-iteration argsort against slightly stale binning —
monopole masses/centroids always track the current positions).

Iterations run under ``lax.scan``; 100 iterations suffice for supergraphs
(paper §4.2.3) vs 500 for full graphs.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.grid import ops as grid_ops
from repro.kernels.repulsion import ops as repulsion_ops
from repro.kernels.segment import ops as segment_ops


@dataclass(frozen=True)
class FA2Config:
    iterations: int = 100
    gravity: float = 1.0
    repulsion_k: float = 80.0  # paper §5.1: kr = 80, kg = 1 for all networks
    strong_gravity: bool = False
    jitter_tolerance: float = 1.0  # τ in the FA2 speed controller
    repulsion: str = "exact"  # "exact" | "grid" | "grid_pallas" | "grid_dense"
    grid_size: int = 64
    grid_window: int = 32  # near-field band half-width of grid repulsion
    grid_rebuild: int = 1  # re-bin/re-sort cells every k iterations
    use_radii: bool = True  # supernode radii shift repulsion distances
    seed: int = 0
    dtype: str = "float32"  # position/force dtype of the layout loop


def init_positions(
    n: int, key: jax.Array, scale: float = 1000.0, dtype: str = "float32"
) -> jnp.ndarray:
    return jax.random.uniform(
        key, (n, 2), minval=-scale, maxval=scale, dtype=jnp.dtype(dtype)
    )


def _gravity(pos, mass, cfg: FA2Config):
    d = jnp.linalg.norm(pos, axis=-1, keepdims=True)
    unit = pos / jnp.maximum(d, 1e-9)
    if cfg.strong_gravity:
        return -cfg.gravity * mass[:, None] * pos
    return -cfg.gravity * mass[:, None] * unit


def _attraction(pos, edges, weights, n: int):
    """Σ over incident edges of w·(x_other − x_self); padded slots hit trash.

    Unsorted two-scatter form — the single-``step`` path. ``layout``
    pre-sorts the edges once and uses ``_attraction_sorted`` instead.
    """
    u, v = edges[:, 0], edges[:, 1]
    pos_ext = jnp.concatenate([pos, jnp.zeros((1, 2), pos.dtype)])
    delta = pos_ext[v] - pos_ext[u]  # force on u toward v
    f = weights[:, None] * delta
    force = jnp.zeros((n + 1, 2), pos.dtype)
    force = force.at[u].add(f)
    force = force.at[v].add(-f)
    return force[:n]


def _attraction_edge_layout(edges, weights):
    """Directed segment layout, built once per ``layout`` call: both edge
    directions concatenated and sorted by source node, so each iteration's
    accumulation is one sorted segment-sum. Padded slots (trash endpoints
    == n) sort last and are dropped by the segment-sum's range check."""
    u, v = edges[:, 0], edges[:, 1]
    src = jnp.concatenate([u, v])
    dst = jnp.concatenate([v, u])
    w2 = jnp.concatenate([weights, weights])
    order = jnp.argsort(src)
    return src[order], dst[order], w2[order]


def _attraction_sorted(pos, src, dst, w, n: int):
    """Σ over directed incident edges of w·(x_dst − x_src), src-sorted —
    the kernels/segment ``indices_are_sorted`` fast path.

    Pinned to the XLA ref backend: this sum has *n* segments, and the
    one-hot-matmul Pallas kernel streams every edge block once per node
    tile — O(n/tn · E) at full-graph n, where the sorted scatter is O(E).
    That kernel is for small-segment-count sums (supergraph aggregation,
    grid cell stats), not node-sized ones.
    """
    pos_ext = jnp.concatenate([pos, jnp.zeros((1, 2), pos.dtype)])
    f = w[:, None] * (pos_ext[dst] - pos_ext[src])
    return segment_ops.segment_sum(
        f, src, n, backend="ref", indices_are_sorted=True
    )


def _pair_force(dpos, mi, mj, kr):
    """kr·mi·mj/d along the unit vector, for a [..., 2] displacement."""
    d2 = jnp.sum(dpos * dpos, axis=-1)
    mag = kr * mi * mj / jnp.maximum(d2, 1e-4)  # (1/d along unit) = 1/d²·vec
    return mag[..., None] * dpos


def _grid_repulsion(pos, mass, cfg: FA2Config):
    """Dense uniform-grid repulsion — the ``grid_dense`` baseline.

    Same monopole-far-field + banded-near-field math as kernels/grid, in
    the original fully-materialized form: an [n, G², 2] far-field tensor
    plus an [n, 2W+1] near-field gather per call. Superseded by the tiled
    backends ("grid"/"grid_pallas"); retained as the benchmark baseline
    (benchmarks/fa2_bench.py) and as a semantics oracle in tests.
    """
    g = cfg.grid_size
    window = cfg.grid_window
    n = pos.shape[0]
    kr = cfg.repulsion_k
    lo = jnp.min(pos, axis=0)
    hi = jnp.max(pos, axis=0)
    extent = jnp.maximum(hi - lo, 1e-6)
    cell2d = jnp.clip(((pos - lo) / extent * g).astype(jnp.int32), 0, g - 1)
    cell = cell2d[:, 0] * g + cell2d[:, 1]
    n_cells = g * g
    cmass = jnp.zeros(n_cells, pos.dtype).at[cell].add(mass)
    cpos = jnp.zeros((n_cells, 2), pos.dtype).at[cell].add(pos * mass[:, None])
    ccent = cpos / jnp.maximum(cmass, 1e-9)[:, None]

    # Far field: node → every cell monopole.
    diff = pos[:, None, :] - ccent[None, :, :]  # [n, G², 2]
    force = jnp.sum(_pair_force(diff, mass[:, None], cmass[None, :], kr), axis=1)

    # Subtract the own-cell monopole (it badly approximates near field + self).
    own_diff = pos - ccent[cell]
    own_f = _pair_force(own_diff, mass, cmass[cell], kr)
    force = force - own_f

    # Exact near field: same-cell neighbors are contiguous after sorting.
    order = jnp.argsort(cell)
    inv = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    pos_s, mass_s, cell_s = pos[order], mass[order], cell[order]
    p = jnp.arange(n)
    offs = jnp.arange(-window, window + 1)
    raw = p[:, None] + offs[None, :]  # [n, 2W+1]
    in_range = (raw >= 0) & (raw < n)  # clipping would duplicate endpoints
    nbr = jnp.clip(raw, 0, n - 1)
    same = in_range & (cell_s[nbr] == cell_s[:, None]) & (nbr != p[:, None])
    dn = pos_s[:, None, :] - pos_s[nbr]
    fn = _pair_force(dn, mass_s[:, None], jnp.where(same, mass_s[nbr], 0.0), kr)
    near = jnp.sum(fn, axis=1)
    force = force + near[inv]
    return force


def _repulsion_forces(pos, mass, radii, cfg: FA2Config, cell=None, order=None):
    """Dispatch one iteration's repulsion to the configured backend."""
    if cfg.repulsion == "grid_dense":
        return _grid_repulsion(pos, mass, cfg)
    if cfg.repulsion in ("grid", "grid_pallas"):
        backend = "auto" if cfg.repulsion == "grid" else "pallas"
        return grid_ops.grid_repulsion(
            pos, mass, cfg.repulsion_k, cfg.grid_size, cfg.grid_window,
            cell=cell, order=order, backend=backend,
        )
    r = radii if cfg.use_radii else None
    return repulsion_ops.repulsion(pos, mass, cfg.repulsion_k, radii=r)


def _apply_speed(state, f, mass, cfg: FA2Config):
    """FA2 speed controller (Algorithm 1): swing/traction → displacement."""
    pos, prev_force, global_speed = state
    swing = jnp.linalg.norm(f - prev_force, axis=-1)
    traction = 0.5 * jnp.linalg.norm(f + prev_force, axis=-1)
    g_swing = jnp.sum(mass * swing) + 1e-9
    g_traction = jnp.sum(mass * traction)
    new_gs = cfg.jitter_tolerance * g_traction / g_swing
    global_speed = jnp.minimum(new_gs, 1.5 * global_speed + 1e-3)

    fmag = jnp.linalg.norm(f, axis=-1)
    local_speed = global_speed / (1.0 + global_speed * jnp.sqrt(swing))
    # FA2 caps node displacement: speed ≤ 10 / |f|.
    local_speed = jnp.minimum(local_speed, 10.0 / jnp.maximum(fmag, 1e-9))
    pos = pos + local_speed[:, None] * f
    return (pos, f, global_speed), fmag


@functools.partial(jax.jit, static_argnames=("cfg", "n"))
def step(state, edges, weights, mass, radii, cfg: FA2Config, n: int):
    """One FA2 iteration (Algorithm 1 body): forces → speeds → displacement.

    Single-step public API (launch/steps.py builds the distributed layout
    cell on it): edge scatter and grid binning run inside the call.
    ``layout`` hoists both out of its scan — prefer it for full runs.
    """
    pos, _, _ = state
    f = _gravity(pos, mass, cfg)
    f = f + _attraction(pos, edges, weights, n)
    f = f + _repulsion_forces(pos, mass, radii, cfg)
    return _apply_speed(state, f, mass, cfg)


@functools.partial(jax.jit, static_argnames=("cfg", "n"))
def layout(
    edges: jnp.ndarray,
    weights: jnp.ndarray,
    mass: jnp.ndarray,
    n: int,
    cfg: FA2Config,
    pos0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run ``cfg.iterations`` FA2 steps. Returns (positions [n,2], trace)."""
    dtype = jnp.dtype(cfg.dtype)
    key = jax.random.PRNGKey(cfg.seed)
    pos = (
        init_positions(n, key, dtype=cfg.dtype)
        if pos0 is None
        else pos0.astype(dtype)
    )
    weights = weights.astype(dtype)
    mass = mass.astype(dtype)
    # Hoisted per-call prep (once per layout, not once per iteration):
    radii = jnp.sqrt(jnp.maximum(mass, 0.0))  # paper: radius ∝ √size
    src, dst, w2 = _attraction_edge_layout(edges, weights)

    grid_state = cfg.repulsion in ("grid", "grid_pallas")
    # Carry (cell, order) through the scan only when a rebuild cadence > 1
    # actually reuses them; iteration 0 always rebuilds (0 % k == 0), so
    # the seed is never read and can be zeros.
    carry_grid = grid_state and cfg.grid_rebuild > 1
    state = (pos, jnp.zeros_like(pos), jnp.asarray(1.0, dtype))
    if carry_grid:
        z = jnp.zeros(n, jnp.int32)
        state = state + (z, z)

    def body(state, it):
        if carry_grid:
            pos, prev_f, gs, cell, order = state
            cell, order = jax.lax.cond(
                it % cfg.grid_rebuild == 0,
                lambda: grid_ops.bin_and_sort(pos, cfg.grid_size),
                lambda: (cell, order),
            )
            core = (pos, prev_f, gs)
        else:
            core = state
            pos = core[0]
            if grid_state:
                cell, order = grid_ops.bin_and_sort(pos, cfg.grid_size)
            else:
                cell = order = None
        f = _gravity(pos, mass, cfg)
        f = f + _attraction_sorted(pos, src, dst, w2, n)
        f = f + _repulsion_forces(pos, mass, radii, cfg, cell=cell, order=order)
        core, fmag = _apply_speed(core, f, mass, cfg)
        if carry_grid:
            return core + (cell, order), jnp.max(fmag)
        return core, jnp.max(fmag)

    state, trace = jax.lax.scan(body, state, jnp.arange(cfg.iterations))
    return state[0], trace


# --------------------------------------------------------------------------
# Node-partitioned multi-device layout (ROADMAP item 1, Arleo et al. in
# PAPERS.md): each device owns n/D consecutive nodes and computes only their
# forces; one tiled all_gather per iteration reassembles the force array for
# the (replicated) speed controller. Per-force-term placement:
#
#   gravity     — elementwise on the owned rows.
#   attraction  — full-size sorted segment-sum with non-owned sources
#                 weight-masked, owned rows sliced: owned segments receive
#                 exactly the single-device terms in the same order.
#   exact rep.  — n ≤ 2048: replicated dense ref, rows sliced (the CPU auto
#                 dispatch); n > 2048: ``repulsion_chunked_rows`` — the
#                 j-chunk scan math on the owned rows only (rows are
#                 independent, so bitwise equal at 1/D the work+memory).
#   grid rep.   — bin/sort/monopole stats replicated (O(n + G²)); far field
#                 row-sliced through ``far_field_ref`` (per-node cell sums);
#                 near field via the psum-free ``near_field_rows`` halo;
#                 sorted rows gathered, then the unsort scatter replicated.
#
# Every cross-device step is a concatenation (all_gather) — never a float
# reduction — so D-device layouts are bit-identical to the single-device
# CPU dispatch ("exact"/"grid" backends; tests/test_sharded_pipeline.py).
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _sharded_layout_fn(mesh, cfg: FA2Config, n: int):
    from jax.sharding import PartitionSpec as P

    from repro.kernels.compat import shard_map_compat
    from repro.sharding.rules import linear_axis_index

    axes = tuple(mesh.axis_names)
    sizes = tuple(mesh.shape[a] for a in axes)
    nl = n // mesh.size
    dtype = jnp.dtype(cfg.dtype)
    grid_state = cfg.repulsion == "grid"
    carry_grid = grid_state and cfg.grid_rebuild > 1
    kr = cfg.repulsion_k

    def sharded_body(pos0, mass, radii, src, dst, w2):
        i0 = nl * linear_axis_index(axes, sizes)

        def rows(x):
            return jax.lax.dynamic_slice_in_dim(x, i0, nl)

        state = (pos0, jnp.zeros_like(pos0), jnp.asarray(1.0, dtype))
        if carry_grid:
            z = jnp.zeros(n, jnp.int32)
            state = state + (z, z)

        def body(state, it):
            if carry_grid:
                pos, prev_f, gs, cell, order = state
                cell, order = jax.lax.cond(
                    it % cfg.grid_rebuild == 0,
                    lambda: grid_ops.bin_and_sort(pos, cfg.grid_size),
                    lambda: (cell, order),
                )
                core = (pos, prev_f, gs)
            else:
                core = state
                pos = core[0]
                if grid_state:
                    cell, order = grid_ops.bin_and_sort(pos, cfg.grid_size)

            f_r = _gravity(rows(pos), rows(mass), cfg)

            pos_ext = jnp.concatenate([pos, jnp.zeros((1, 2), pos.dtype)])
            own = (src >= i0) & (src < i0 + nl)
            fe = jnp.where(own, w2, 0.0)[:, None] * (pos_ext[dst] - pos_ext[src])
            att = segment_ops.segment_sum(
                fe, src, n, backend="ref", indices_are_sorted=True
            )
            f_r = f_r + rows(att)

            if grid_state:
                pos32 = pos.astype(jnp.float32)
                mass32 = mass.astype(jnp.float32)
                pos_s, mass_s, cell_s = pos32[order], mass32[order], cell[order]
                ccent, cmass = grid_ops.cell_stats(
                    pos_s, mass_s, cell_s, cfg.grid_size * cfg.grid_size,
                    backend="ref",
                )
                force_sr = grid_ops.far_field_ref(
                    rows(pos_s), rows(mass_s), rows(cell_s), ccent, cmass, kr
                )
                force_sr = force_sr + grid_ops.near_field_rows(
                    pos_s, mass_s, cell_s, kr, cfg.grid_window, i0, nl
                )
                force_s = jax.lax.all_gather(force_sr, axes, axis=0, tiled=True)
                rep = jnp.zeros_like(force_s).at[order].set(force_s)
                f_r = f_r + rows(rep.astype(pos.dtype))
            else:
                r = radii if cfg.use_radii else None
                if n <= 2048:
                    f_r = f_r + rows(
                        repulsion_ops.repulsion(pos, mass, kr, radii=r,
                                                backend="ref")
                    )
                else:
                    f_r = f_r + repulsion_ops.repulsion_chunked_rows(
                        pos, mass, i0, nl, kr, radii=r,
                        use_radii=cfg.use_radii,
                    )

            f = jax.lax.all_gather(f_r, axes, axis=0, tiled=True)
            core, fmag = _apply_speed(core, f, mass, cfg)
            if carry_grid:
                return core + (cell, order), jnp.max(fmag)
            return core, jnp.max(fmag)

        state, trace = jax.lax.scan(body, state, jnp.arange(cfg.iterations))
        return state[0], trace

    mapped = shard_map_compat(
        sharded_body,
        mesh,
        in_specs=(P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P()),
    )

    def run(edges, weights, mass, pos0):
        weights = weights.astype(dtype)
        mass = mass.astype(dtype)
        radii = jnp.sqrt(jnp.maximum(mass, 0.0))
        src, dst, w2 = _attraction_edge_layout(edges, weights)
        return mapped(pos0, mass, radii, src, dst, w2)

    return jax.jit(run)


def layout_sharded(
    edges: jnp.ndarray,
    weights: jnp.ndarray,
    mass: jnp.ndarray,
    n: int,
    cfg: FA2Config,
    mesh,
    pos0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``layout`` with the force pass node-partitioned over ``mesh``.

    Falls back to ``layout`` when the mesh is trivial, ``n`` doesn't divide
    by the device count, or the backend has no sharded form ("grid_pallas",
    "grid_dense"). Bit-identical to the single-device *CPU* dispatch of
    "exact"/"grid" (on TPU, ``layout``'s auto-dispatch picks Pallas kernels
    this path does not mirror).
    """
    if (
        mesh is None
        or mesh.size <= 1
        or n % mesh.size != 0
        or cfg.repulsion in ("grid_pallas", "grid_dense")
    ):
        return layout(edges, weights, mass, n, cfg, pos0)
    dtype = jnp.dtype(cfg.dtype)
    pos = (
        init_positions(n, jax.random.PRNGKey(cfg.seed), dtype=cfg.dtype)
        if pos0 is None
        else pos0.astype(dtype)
    )
    return _sharded_layout_fn(mesh, cfg, n)(edges, weights, mass, pos)
