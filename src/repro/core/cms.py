"""Count–min sketch (paper §3.3) — community sizing without atomic counters.

A CMS is a *linear* sketch: updates commute and shards merge by addition.
That is exactly what makes the paper's pipeline multi-pod scalable: each
device sketches its own edge shard and one all-reduce merges the sketches
(see core/pipeline.py and DESIGN.md §2).

Hashing: multiply-shift universal hashing in uint32 (wraps mod 2^32), then
mod ``cols``. The paper uses 4 hash rows and cols ≈ 1e-4 × |E|.

The hot update path has a Pallas TPU kernel (kernels/cms) that turns the
scatter-add into a one-hot × matmul on the MXU; this module is the
reference / small-scale path and the public API.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class CMSConfig:
    rows: int = 4
    cols: int = 5000
    seed: int = 0x5EED


def hash_params(cfg: CMSConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (a, b) multiply-shift constants (odd a)."""
    rng = np.random.default_rng(cfg.seed)
    a = rng.integers(1, 2**31, size=cfg.rows, dtype=np.uint32) * 2 + 1
    b = rng.integers(0, 2**31, size=cfg.rows, dtype=np.uint32)
    return jnp.asarray(a), jnp.asarray(b)


def hash_keys(keys: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, cols: int) -> jnp.ndarray:
    """[rows, n] bucket indices for int32 keys."""
    k = keys.astype(jnp.uint32)[None, :]
    h = (a[:, None] * k + b[:, None]) >> jnp.uint32(5)
    return (h % jnp.uint32(cols)).astype(jnp.int32)


def init_sketch(cfg: CMSConfig, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros((cfg.rows, cfg.cols), dtype=dtype)


@functools.partial(jax.jit, static_argnames=("cfg",))
def update(sketch: jnp.ndarray, keys: jnp.ndarray, weights: jnp.ndarray, cfg: CMSConfig):
    """Add ``weights`` at ``keys``. Negative-key slots are masked (padding)."""
    a, b = hash_params(cfg)
    h = hash_keys(keys, a, b, cfg.cols)
    w = jnp.where(keys >= 0, weights, 0).astype(sketch.dtype)
    rows = jnp.arange(cfg.rows, dtype=jnp.int32)[:, None]
    return sketch.at[rows, h].add(w[None, :])


@functools.partial(jax.jit, static_argnames=("cfg",))
def query(sketch: jnp.ndarray, keys: jnp.ndarray, cfg: CMSConfig) -> jnp.ndarray:
    """Point-query: min over hash rows (classic CMS estimate)."""
    a, b = hash_params(cfg)
    h = hash_keys(keys, a, b, cfg.cols)
    rows = jnp.arange(cfg.rows, dtype=jnp.int32)[:, None]
    return jnp.min(sketch[rows, h], axis=0)


def merge(*sketches: jnp.ndarray) -> jnp.ndarray:
    """CMS is linear: shard-local sketches merge by addition."""
    return functools.reduce(jnp.add, sketches)


@functools.lru_cache(maxsize=None)
def sharded_update(mesh, cfg: CMSConfig):
    """Compiled sharded ``update`` over ``mesh``: keys/weights arrive
    row-sharded (all mesh axes on dim 0), the sketch replicated; each device
    sketches its own key slice and one ``psum`` merges — the linearity the
    module docstring promises. Exact vs. single-device while the counts stay
    integer-valued below 2^24 (community sizes are degree sums, i.e. ints).
    Requires ``len(keys) % mesh.size == 0`` — callers pad with key=-1 (the
    masked padding slot) to the next multiple.
    """
    from jax.sharding import PartitionSpec as P

    from repro.kernels.compat import shard_map_compat
    from repro.sharding.rules import row_chunk_spec

    axes = tuple(mesh.axis_names)
    row1d = P(row_chunk_spec(mesh)[0])  # 1-D operands: drop the trailing None

    def body(sketch, keys, weights):
        local = update(jnp.zeros_like(sketch), keys, weights, cfg)
        return sketch + jax.lax.psum(local, axes)

    mapped = shard_map_compat(
        body, mesh, in_specs=(P(), row1d, row1d), out_specs=P()
    )
    return jax.jit(mapped, donate_argnums=(0,))


# --------------------------------------------------------------------------
# Chunk-incremental API (core/stream.py engine). The sketch is linear, so
# ``update`` already *is* the chunk step: init → update×chunks → finalize.
# ``finalize`` is the identity — it exists so every streamed stage exposes
# the same init/update/finalize contract.
# --------------------------------------------------------------------------

init = init_sketch


def finalize(sketch: jnp.ndarray) -> jnp.ndarray:
    return sketch
