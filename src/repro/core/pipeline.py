"""End-to-end BigGraphVis pipeline (paper Fig. 2 / Algorithm 3):

    edge stream ──► SCoDA communities ──► CMS sizing ──► supergraph
                ──► ForceAtlas2 layout ──► colored supernode drawing
                ──► rasterized image (repro/render, ``render_path=``)

plus the paper's second output mode: a *full-graph* ForceAtlas2 layout
recolored by the detected communities (§4.3).

Every edge-consuming stage runs through the streaming chunked-edge engine
(core/stream.py): ``biggraphvis()`` is the single-host driver, processing
the edge list as one chunk by default and as fixed-size chunks (device
residency independent of |E|) when given a ``StreamConfig``. The
multi-device form (edge shards streamed per device; CMS merged by
all-reduce, labels by all-reduce-min — DESIGN.md §4) is lowered and
compiled for the production meshes by ``launch/steps.build_bgv_step``
(the ``biggraphvis`` dry-run cells); ``launch/stream_runner.py`` drives
the chunked engine with device placement and host prefetch.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cms as cms_lib
from repro.core import forceatlas2 as fa2
from repro.core.coloring import color_groups
from repro.core.scoda import ScodaConfig, detect_communities
from repro.core.stream import StreamConfig, StreamStats, stream_pipeline
from repro.core.supergraph import Supergraph, build_supergraph
from repro.graph.utils import degrees, pad_edges
from repro.obs.metrics import REGISTRY
from repro.obs.trace import get_tracer


@dataclass(frozen=True)
class BGVConfig:
    scoda: ScodaConfig
    cms: cms_lib.CMSConfig
    layout: fa2.FA2Config
    s_cap: int = 65536  # supernode capacity
    max_super_edges: int = 262144
    # Optional repro.obs.Tracer for the whole pipeline (detect → supergraph
    # → layout → render). None falls back to StreamConfig.obs, then the
    # process-global tracer (repro.obs.get_tracer) — disabled by default.
    obs: object = None


@dataclass
class BGVResult:
    positions: np.ndarray  # [s_cap, 2]
    sizes: np.ndarray  # [s_cap]
    groups: np.ndarray  # [s_cap] color group
    labels: np.ndarray  # [n] node → dense community
    supergraph: Supergraph
    modularity: float
    n_supernodes: int
    n_superedges: int
    timings: dict = field(default_factory=dict)
    stream: StreamStats | None = None  # chunked-engine accounting
    obs: object = field(default=None, repr=False)  # Tracer from the run

    def render(self, path: str | None = None, cfg=None):
        """Rasterize this result's supergraph drawing (paper §4.3) through
        the streaming renderer — the one render entry point shared by the
        batch path and the tile service (repro/serve/tiles.py renders
        viewport-restricted tiles of the same scene).

        ``path`` additionally writes a PNG; ``cfg`` is an optional
        ``repro.render.RenderConfig``. Returns ``(image [H, W, 3] uint8,
        RenderStats)`` and records the wall time in
        ``timings["render_s"]``.
        """
        # Local import: repro.render consumes this module's BGVResult.
        import dataclasses

        from repro.render import render as render_result

        tr = self.obs if self.obs is not None else get_tracer()
        if self.obs is not None:
            # Thread the run's explicit tracer into the render config so the
            # raster spans nest under this render span.
            from repro.render import RenderConfig

            if cfg is None:
                cfg = RenderConfig(obs=tr)
            elif getattr(cfg, "obs", None) is None:
                cfg = dataclasses.replace(cfg, obs=tr)
        t0 = time.perf_counter()
        with tr.span("render", path=path or ""):
            out = render_result(self, path, cfg=cfg)
        self.timings["render_s"] = time.perf_counter() - t0
        return out


# The render_path=/render_cfg= shims warn once per process, not per call
# (a streaming driver may invoke biggraphvis in a loop).
_RENDER_KWARGS_WARNED = False


def _warn_render_kwargs() -> None:
    global _RENDER_KWARGS_WARNED
    if not _RENDER_KWARGS_WARNED:
        warnings.warn(
            "biggraphvis(render_path=, render_cfg=) is deprecated; call "
            ".render(path, cfg=...) on the returned BGVResult instead",
            DeprecationWarning,
            stacklevel=3,
        )
        _RENDER_KWARGS_WARNED = True


def default_cms_cols(n_edges: int) -> int:
    """Count-min-sketch width used by ``default_config``:
    ``max(256, |E| // 1000)`` — pinned by tests/test_api.py.

    This is denser than the seed docstring's claimed ``1e-4·|E|``: at the
    paper's 34M-edge ceiling 1e-4 gives a 3.4k-column sketch whose
    collision bias visibly inflates small-community sizes, and at the
    CPU-scale suite sizes it would pin every graph at the 256 floor. One
    column per ~1000 edges keeps the §4.2 size estimates reliable across
    both regimes for 4 hash rows.
    """
    return max(256, n_edges // 1000)


def default_config(
    n_nodes: int,
    n_edges: int,
    degree_threshold: int,
    rounds: int = 4,
    iterations: int = 100,
    s_cap: int | None = None,
    repulsion: str = "exact",
    grid_size: int = 64,
    grid_window: int = 32,
    grid_rebuild: int = 1,
    stop_tolerance: float = 0.0,
    min_iterations: int = 0,
    init: str = "random",
    nan_guard: bool = False,
) -> BGVConfig:
    """Paper-shaped defaults: 4 hash rows, CMS cols = max(256, |E| // 1000)
    (``default_cms_cols`` — see its docstring for why the sketch is denser
    than the 1e-4·|E| the seed docstring claimed), δ = mode degree.

    ``repulsion``/``grid_*`` select the FA2 backend for the supergraph
    layout and seed the grid parameters ``full_layout_colored`` reuses
    (see the backend matrix in core/forceatlas2.py): "exact" is right for
    supergraphs; "grid"/"grid_pallas" are the tiled full-graph fast path.
    ``stop_tolerance``/``min_iterations`` enable FA2's adaptive stop
    (``iterations`` becomes an upper bound) and ``init`` picks the
    starting positions ("random" | "degree" | "bfs") — both also seed the
    full-graph knobs ``full_layout_colored`` reuses. ``nan_guard`` turns
    on FA2's divergence sentinel (non-finite iterations rolled back and
    damped instead of NaN-poisoning the layout — core/forceatlas2.py).
    """
    cols = default_cms_cols(n_edges)
    return BGVConfig(
        scoda=ScodaConfig(degree_threshold=degree_threshold, rounds=rounds),
        cms=cms_lib.CMSConfig(rows=4, cols=cols),
        layout=fa2.FA2Config(
            iterations=iterations, repulsion=repulsion, grid_size=grid_size,
            grid_window=grid_window, grid_rebuild=grid_rebuild,
            stop_tolerance=stop_tolerance, min_iterations=min_iterations,
            init=init, nan_guard=nan_guard,
        ),
        s_cap=s_cap or min(n_nodes, 65536),
        max_super_edges=min(4 * n_edges, 262144),
    )


def _block(fn, *args):
    out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    return out


def layout_supergraph(
    sg: Supergraph, cfg: BGVConfig, mesh=None, shard_layout: bool = False,
    tracer=None,
) -> tuple[jnp.ndarray, int]:
    """ForceAtlas2 on the (small, device-resident) supergraph.

    Returns ``(positions [s_cap, 2], iterations_run)`` — the latter is
    ``cfg.layout.iterations`` unless the adaptive stop
    (``cfg.layout.stop_tolerance``) froze the scan earlier.

    The layout stage is sized to the LIVE supernode count (padded to a
    power of two for shape reuse): laying out the full s_cap padding
    would erase the paper's headline speedup — the whole point is that
    the supergraph is orders of magnitude smaller than the graph.

    With ``mesh`` + ``shard_layout`` the force pass is node-partitioned
    over the mesh (``fa2.layout_sharded`` — bit-identical, with its own
    fallbacks). ``s_layout`` is a power of two ≥ 64, so it divides by any
    power-of-two device count.
    """
    tr = tracer if tracer is not None else get_tracer()
    s_live = max(int(sg.n_supernodes), 2)
    s_layout = 1 << (s_live - 1).bit_length()
    s_layout = min(max(s_layout, 64), cfg.s_cap)
    e_live = max(int(sg.n_superedges), 1)
    e_layout = min(1 << (e_live - 1).bit_length(), sg.edges.shape[0])
    mass = jnp.maximum(sg.sizes[:s_layout], 0.0) + jnp.where(
        jnp.arange(s_layout) < sg.n_supernodes, 1.0, 0.0
    )
    mass = jnp.where(jnp.arange(s_layout) < sg.n_supernodes, mass, 0.0)
    sedges = jnp.minimum(sg.edges[:e_layout], s_layout)  # trash → s_layout
    if mesh is not None and shard_layout:
        def run(e, w, m):
            return fa2.layout_sharded(e, w, m, s_layout, cfg.layout, mesh)
    else:
        def run(e, w, m):
            return fa2.layout(e, w, m, s_layout, cfg.layout)
    with tr.span(
        "layout.supergraph", n=s_layout, edges=e_layout,
        sharded=bool(mesh is not None and shard_layout),
    ):
        pos_live, _trace, iters_run = _block(
            run, sedges, sg.weights[:e_layout], mass
        )
    if cfg.layout.nan_guard:
        # Host sync on the trace is only paid when the sentinel is armed.
        recovered = fa2.recovery_count(_trace)
        if recovered:
            REGISTRY.counter("errors.fa2_recoveries").inc(recovered)
    pos = jnp.zeros((cfg.s_cap, 2), pos_live.dtype).at[:s_layout].set(pos_live)
    return pos, int(iters_run)


def biggraphvis(
    source,
    n_nodes: int,
    cfg: BGVConfig,
    stream: StreamConfig | None = None,
    put=None,
    render_path: str | None = None,
    render_cfg=None,
    checkpoint=None,
    resume=False,
) -> BGVResult:
    """Single-host driver. ``source`` is any engine edge source: an [E,2]
    unpadded int32 host array, an ``EdgeStore``, or a path to a ``.npy`` /
    ``.bin`` edge file or shard directory (repro/data/edge_store.py) — the
    disk-backed forms stream graphs larger than host memory.

    ``stream=None`` feeds the whole edge list through the engine as a single
    chunk (the one-shot path); a ``StreamConfig`` streams it in fixed-size
    chunks so device residency is independent of |E|. Both paths produce
    identical results whatever the source (tests/test_stream.py,
    tests/test_edge_store.py) and whatever the superedge-aggregation
    backend (``StreamConfig.agg_backend``: two-level "merge" default vs
    "lexsort" baseline). ``put`` is the host→device transfer for
    chunk buffers (launch/stream_runner.py passes a sharded forced-copy
    device_put; None selects the engine default for the source).

    ``render_path``/``render_cfg`` are deprecated shims (one
    ``DeprecationWarning`` per process) forwarding to the render entry
    point, ``BGVResult.render(path, cfg=...)`` — call that instead.

    ``checkpoint`` (a ``resilience.StreamCheckpointer``) and ``resume``
    forward to the streaming engine: the edge-consuming stages persist
    their state at chunk boundaries and a killed run restarts
    bit-identically from the newest checkpoint — see
    ``core/stream.stream_pipeline``. The layout itself is deterministic
    given the supergraph and cheap relative to streaming, so it simply
    re-runs after a resume.
    """
    tr = cfg.obs
    if tr is None and stream is not None:
        tr = stream.obs
    if tr is None:
        tr = get_tracer()
    with tr.span("biggraphvis", n_nodes=n_nodes, s_cap=cfg.s_cap):
        labels, _gdeg, sg, q, stats = stream_pipeline(
            source, n_nodes, cfg.scoda, cfg.cms, cfg.s_cap,
            cfg.max_super_edges,
            stream, put=put, tracer=tr,
            checkpoint=checkpoint, resume=resume,
        )
        t = {
            "scoda_s": stats.stage_seconds["detect_s"],
            "supergraph_s": stats.stage_seconds["supergraph_s"],
        }

        t0 = time.perf_counter()
        with tr.span("layout", iterations=cfg.layout.iterations,
                     repulsion=cfg.layout.repulsion):
            pos, layout_iters = layout_supergraph(
                sg, cfg,
                mesh=stream.mesh if stream is not None else None,
                shard_layout=stream.shard_layout if stream is not None else False,
                tracer=tr,
            )
        t["layout_s"] = time.perf_counter() - t0
        t["layout_iterations"] = layout_iters
        REGISTRY.counter("layout.runs").inc()
        REGISTRY.gauge("layout.iterations_run").set(layout_iters)
        REGISTRY.gauge("layout.seconds").set(t["layout_s"])
        REGISTRY.gauge("layout.converged").set(
            int(layout_iters < cfg.layout.iterations)
        )

        groups = color_groups(sg.sizes)
    result = BGVResult(
        positions=np.asarray(pos),
        sizes=np.asarray(sg.sizes),
        groups=np.asarray(groups),
        labels=np.asarray(sg.labels),
        supergraph=sg,
        modularity=float(q),
        n_supernodes=int(sg.n_supernodes),
        n_superedges=int(sg.n_superedges),
        timings=t,
        stream=stats,
        # Only carry an *explicit* tracer; global-tracer users keep the
        # late-binding get_tracer() fallback in .render().
        obs=cfg.obs if cfg.obs is not None
        else (stream.obs if stream is not None else None),
    )
    if render_path is not None or render_cfg is not None:
        _warn_render_kwargs()
        result.render(render_path, cfg=render_cfg)
    return result


def full_layout_colored(
    edges_np: np.ndarray,
    n_nodes: int,
    cfg: BGVConfig,
    iterations: int = 500,
    stop_tolerance: float | None = None,
    min_iterations: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Paper's comparison/styling path: full-graph FA2 (grid repulsion for
    scale) + BigGraphVis community colors. Returns (pos [n,2], groups [n]).

    ``cfg.layout.repulsion == "exact"`` (the supergraph default) is treated
    as "unset" here and upgraded to the tiled "grid" backend above 4096
    nodes — an exact full-graph layout at larger n is a deliberate O(n²)
    choice; call ``fa2.layout`` directly for that.

    ``stop_tolerance``/``min_iterations`` override ``cfg.layout``'s
    adaptive-stop knobs for this call (the tile service caps drill-miss
    latency this way — serve/tiles.py ``drill_stop_tolerance``); None
    inherits the config. ``cfg.layout.init`` picks the initialization.
    """
    e_cap = len(edges_np)
    edges = jnp.asarray(pad_edges(edges_np, e_cap, n_nodes))
    deg = degrees(edges, n_nodes)
    labels, _ = detect_communities(edges, n_nodes, cfg.scoda)
    sg = build_supergraph(
        edges, labels, deg, n_nodes, cfg.s_cap, cfg.max_super_edges, cfg.cms
    )
    # Full-graph scale wants the tiled grid family; honor an explicit grid
    # backend choice from the config, defaulting to the auto-dispatched
    # "grid" (Pallas on TPU, chunked XLA elsewhere) above 4096 nodes.
    repulsion = (
        cfg.layout.repulsion
        if cfg.layout.repulsion != "exact"
        else ("grid" if n_nodes > 4096 else "exact")
    )
    lcfg = fa2.FA2Config(
        iterations=iterations,
        repulsion=repulsion,
        grid_size=cfg.layout.grid_size,
        grid_window=cfg.layout.grid_window,
        grid_rebuild=cfg.layout.grid_rebuild,
        use_radii=False,
        gravity=cfg.layout.gravity,
        repulsion_k=cfg.layout.repulsion_k,
        dtype=cfg.layout.dtype,
        stop_tolerance=(
            cfg.layout.stop_tolerance
            if stop_tolerance is None
            else stop_tolerance
        ),
        min_iterations=(
            cfg.layout.min_iterations
            if min_iterations is None
            else min_iterations
        ),
        init=cfg.layout.init,
        init_bfs_rounds=cfg.layout.init_bfs_rounds,
        nan_guard=cfg.layout.nan_guard,
    )
    mass = deg.astype(jnp.float32) + 1.0
    w = jnp.ones(edges.shape[0], jnp.float32)
    tr = cfg.obs if cfg.obs is not None else get_tracer()
    with tr.span("layout.full", n=n_nodes, repulsion=repulsion):
        pos, trace, iters_run = fa2.layout(edges, w, mass, n_nodes, lcfg)
    if lcfg.nan_guard:
        recovered = fa2.recovery_count(trace)
        if recovered:
            REGISTRY.counter("errors.fa2_recoveries").inc(recovered)
    REGISTRY.gauge("layout.full_iterations_run").set(int(iters_run))
    node_groups = color_groups(sg.sizes)[jnp.clip(sg.labels, 0, cfg.s_cap - 1)]
    return np.asarray(pos), np.asarray(node_groups)
