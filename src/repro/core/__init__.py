"""BigGraphVis core: streaming community detection + CMS + supergraph +
ForceAtlas2, per the paper. See DESIGN.md for the GPU→TPU adaptation."""
from repro.core.scoda import (
    ScodaConfig,
    detect_communities,
    dense_labels,
    scoda_finalize,
    scoda_init,
    scoda_update,
)
from repro.core.cms import CMSConfig, init_sketch, update, query, merge
from repro.core.supergraph import (
    Supergraph,
    agg_finalize,
    agg_init,
    agg_update,
    aggregate_edges,
    build_supergraph,
    community_sizes,
)
from repro.core.forceatlas2 import (
    FA2Config,
    init_positions,
    init_positions_bfs,
    init_positions_degree,
    initial_positions,
    layout,
    layout_sharded,
    step,
)
from repro.core.modularity import modularity
from repro.core.stream import (
    EdgeChunkStream,
    StreamConfig,
    StreamStats,
    stream_detect,
    stream_pipeline,
    stream_supergraph,
)
from repro.core.coloring import color_groups, node_colors, write_svg, PALETTE
from repro.core.pipeline import (
    BGVConfig,
    BGVResult,
    biggraphvis,
    default_cms_cols,
    default_config,
    full_layout_colored,
)
