"""Supergraph construction (paper §4.1): communities → weighted supernodes,
inter-community edges → weighted superedges.

Static-shape implementation: superedges are deduplicated by lexsorting the
canonicalized (min,max) community pairs and segment-summing multiplicities
into a fixed ``max_super_edges`` capacity. All jittable.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import cms as cms_lib
from repro.core.scoda import dense_labels

INT32_MAX = jnp.iinfo(jnp.int32).max


@dataclass
class Supergraph:
    """Padded supergraph. Padded superedge slots point at ``s_cap`` (trash)."""

    edges: jnp.ndarray  # [max_super_edges, 2] int32, dense community ids
    weights: jnp.ndarray  # [max_super_edges] float32 (edge multiplicity)
    sizes: jnp.ndarray  # [s_cap] float32 supernode weights (CMS estimate)
    n_supernodes: jnp.ndarray  # scalar int32
    n_superedges: jnp.ndarray  # scalar int32
    labels: jnp.ndarray  # [n_nodes] int32 node → dense community id


# --------------------------------------------------------------------------
# Chunk-incremental superedge aggregation (core/stream.py engine).
#
# State is the *partially aggregated* superedge set: three [cap] arrays
# (a, b, w) sorted by (a, b) with padded slots at (s_cap, s_cap, 0), plus the
# live count. Each update maps a chunk of node edges through the community
# labels, merges it with the state by one lexsort, and segment-sums the
# multiplicities back into the capacity — so after the final chunk the state
# IS the deduplicated superedge list, identical to a one-shot aggregation of
# the full edge list (aggregation is order-independent: a sorted multiset
# sum). ``aggregate_edges`` is the one-shot wrapper over a single chunk.
#
# Capacity overflow (> max_super_edges unique pairs) truncates the sorted
# tail in both paths; the truncation point then depends on chunk order, so
# chunked == one-shot is guaranteed only below capacity — same contract as
# the one-shot path, which also silently drops pairs past the capacity.
# --------------------------------------------------------------------------


def agg_init(s_cap: int, max_super_edges: int):
    """Empty aggregation state: (a [cap], b [cap], w [cap], n_superedges)."""
    return (
        jnp.full((max_super_edges,), s_cap, jnp.int32),
        jnp.full((max_super_edges,), s_cap, jnp.int32),
        jnp.zeros((max_super_edges,), jnp.float32),
        jnp.zeros((), jnp.int32),
    )


def _agg_update_body(state, chunk, labels_ext, s_cap: int, max_super_edges: int):
    """Merge one edge chunk into the aggregation state (jittable).

    ``chunk`` [C,2] int32 node edges (padded slots point at the trash node);
    ``labels_ext`` [n_nodes+1] dense community per node with the trash slot
    mapped to ``s_cap``.
    """
    pa, pb, pw, _ = state
    trash = labels_ext.shape[0] - 1
    cu = labels_ext[jnp.minimum(chunk[:, 0], trash)]
    cv = labels_ext[jnp.minimum(chunk[:, 1], trash)]
    a = jnp.minimum(cu, cv)
    b = jnp.maximum(cu, cv)
    valid = (a != b) & (a < s_cap) & (b < s_cap)
    a = jnp.where(valid, a, s_cap)
    b = jnp.where(valid, b, s_cap)
    w = jnp.where(valid, 1.0, 0.0).astype(jnp.float32)

    # Merge prior partial aggregation with the new chunk and re-dedupe.
    ca = jnp.concatenate([pa, a])
    cb = jnp.concatenate([pb, b])
    cw = jnp.concatenate([pw, w])

    # Lexsort by (a, b); invalid slots (s_cap, s_cap) sort last.
    order = jnp.lexsort((cb, ca))
    a_s, b_s, w_s = ca[order], cb[order], cw[order]
    new_pair = jnp.concatenate(
        [jnp.array([True]), (a_s[1:] != a_s[:-1]) | (b_s[1:] != b_s[:-1])]
    )
    new_pair = new_pair & (a_s != s_cap)
    seg = jnp.cumsum(new_pair) - 1  # dense superedge id per sorted slot (or -1 prefix)
    seg = jnp.where(a_s != s_cap, seg, max_super_edges)

    sw = jnp.zeros(max_super_edges + 1, jnp.float32).at[seg].add(w_s)
    sa = jnp.full((max_super_edges + 1,), s_cap, jnp.int32).at[seg].set(a_s)
    sb = jnp.full((max_super_edges + 1,), s_cap, jnp.int32).at[seg].set(b_s)
    n_superedges = jnp.sum(new_pair).astype(jnp.int32)
    return (
        sa[:max_super_edges],
        sb[:max_super_edges],
        sw[:max_super_edges],
        n_superedges,
    )


agg_update = functools.partial(
    jax.jit, static_argnames=("s_cap", "max_super_edges"), donate_argnums=(0,)
)(_agg_update_body)


def agg_finalize(state):
    """(sedges [cap,2], sweights [cap], n_superedges) from aggregation state."""
    a, b, w, n = state
    return jnp.stack([a, b], axis=1), w, n


@functools.partial(jax.jit, static_argnames=("s_cap", "max_super_edges"))
def aggregate_edges(
    edges: jnp.ndarray,
    labels_dense: jnp.ndarray,
    s_cap: int,
    max_super_edges: int,
):
    """Map node edges through community labels, drop intra edges, dedupe
    (one-shot wrapper: the whole edge list as a single chunk).

    Returns (sedges [cap,2], sweights [cap], n_superedges).
    """
    labels_ext = jnp.concatenate([labels_dense, jnp.array([s_cap], jnp.int32)])
    state = agg_init(s_cap, max_super_edges)
    state = _agg_update_body(state, edges, labels_ext, s_cap, max_super_edges)
    return agg_finalize(state)


def community_sizes(
    labels_dense: jnp.ndarray,
    node_deg: jnp.ndarray,
    n_supernodes: jnp.ndarray,
    s_cap: int,
    cms_cfg: cms_lib.CMSConfig,
) -> jnp.ndarray:
    """CMS-estimated community sizes (paper §4.1): one sketch update per node,
    weight = its true graph degree; queries beyond the live count are masked."""
    sketch = cms_lib.init(cms_cfg)
    sketch = cms_lib.update(sketch, labels_dense, node_deg.astype(jnp.float32), cms_cfg)
    sizes = cms_lib.query(cms_lib.finalize(sketch), jnp.arange(s_cap, dtype=jnp.int32), cms_cfg)
    return jnp.where(jnp.arange(s_cap) < n_supernodes, sizes, 0.0)


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "s_cap", "max_super_edges", "cms_cfg")
)
def build_supergraph(
    edges: jnp.ndarray,
    labels: jnp.ndarray,
    node_deg: jnp.ndarray,
    n_nodes: int,
    s_cap: int,
    max_super_edges: int,
    cms_cfg: cms_lib.CMSConfig,
) -> Supergraph:
    """Full paper path: dense-relabel communities, CMS-size them, dedupe edges.

    Community size (paper §4.1): sum of *graph* degrees of member nodes
    (≈ 2×intra edges), accumulated through the count–min sketch keyed by
    community id — never an exact counter.
    """
    labels_dense, n_supernodes = dense_labels(labels, n_nodes)
    sizes = community_sizes(labels_dense, node_deg, n_supernodes, s_cap, cms_cfg)

    sedges, sweights, n_superedges = aggregate_edges(
        edges, labels_dense, s_cap, max_super_edges
    )
    return Supergraph(
        edges=sedges,
        weights=sweights,
        sizes=sizes,
        n_supernodes=n_supernodes,
        n_superedges=n_superedges,
        labels=labels_dense,
    )


jax.tree_util.register_dataclass(
    Supergraph,
    data_fields=["edges", "weights", "sizes", "n_supernodes", "n_superedges", "labels"],
    meta_fields=[],
)
