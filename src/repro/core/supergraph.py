"""Supergraph construction (paper §4.1): communities → weighted supernodes,
inter-community edges → weighted superedges.

Static-shape implementation: superedges are deduplicated into a fixed
``max_super_edges`` capacity, kept sorted by canonicalized (min, max)
community pair. Two jittable aggregation backends share the contract
(``agg_backend``): the original ``"lexsort"`` full re-sort, and the
default ``"merge"`` two-level scheme built on ``kernels/merge``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import cms as cms_lib
from repro.core.scoda import dense_labels
from repro.kernels.merge import ops as merge_ops
from repro.kernels.merge.ref import SENTINEL, pack_keys, unpack_keys


@dataclass
class Supergraph:
    """Padded supergraph. Padded superedge slots point at ``s_cap`` (trash)."""

    edges: jnp.ndarray  # [max_super_edges, 2] int32, dense community ids
    weights: jnp.ndarray  # [max_super_edges] float32 (edge multiplicity)
    sizes: jnp.ndarray  # [s_cap] float32 supernode weights (CMS estimate)
    n_supernodes: jnp.ndarray  # scalar int32
    n_superedges: jnp.ndarray  # scalar int32
    labels: jnp.ndarray  # [n_nodes] int32 node → dense community id


# --------------------------------------------------------------------------
# Chunk-incremental superedge aggregation (core/stream.py engine).
#
# State is the *partially aggregated* superedge set: three [cap] arrays
# (a, b, w) sorted by (a, b) with padded slots at (s_cap, s_cap, 0), plus
# the live count. Each update maps a chunk of node edges through the
# community labels and combines it into the state through one of two
# backends that keep the sorted-state invariant (``agg_backend``):
#
#   * "merge" (default) — two-level scheme: (1) the persistent state stays
#     sorted by (a, b); (2) the incoming chunk is deduped *locally*, one
#     sort of only the C chunk entries; (3) the deduped run merges into the
#     state by the ``kernels/merge`` sorted-merge-and-combine kernel, whose
#     ranks are binary searches because both runs are already sorted —
#     O(C log C + cap + C) per chunk.
#   * "lexsort" — the original baseline: concatenate state + chunk, one
#     full lexsort, segment-sum back into capacity — O((cap + C)·
#     log(cap + C)) per chunk.
#
# Both are bit-for-bit identical below capacity (weights are edge counts,
# exactly representable, and both keep the same sorted layout), and both
# skip all-invalid chunks (every edge intra-community or trash-padded)
# without touching the state. After the final chunk the state IS the
# deduplicated superedge list, identical to a one-shot aggregation of the
# full edge list (aggregation is order-independent: a sorted multiset
# sum). ``aggregate_edges`` is the one-shot wrapper over a single chunk.
#
# Capacity overflow (> max_super_edges unique pairs) truncates the sorted
# tail in both backends — every update keeps the lexicographically
# smallest ``cap`` pairs and drops the weight of the rest, while
# ``n_superedges`` still counts every unique pair of the latest update's
# union. The truncation point then depends on chunk order, so chunked ==
# one-shot is guaranteed only below capacity (the backends still agree
# with *each other* for any fixed chunk sequence; see
# tests/test_supergraph.py overflow-contract tests).
# --------------------------------------------------------------------------


def agg_init(s_cap: int, max_super_edges: int):
    """Empty aggregation state: (a [cap], b [cap], w [cap], n_superedges)."""
    return (
        jnp.full((max_super_edges,), s_cap, jnp.int32),
        jnp.full((max_super_edges,), s_cap, jnp.int32),
        jnp.zeros((max_super_edges,), jnp.float32),
        jnp.zeros((), jnp.int32),
    )


def _chunk_pairs(chunk, labels_ext, s_cap: int):
    """Map node edges → canonical community pairs; invalid → (s_cap, s_cap, 0).

    ``chunk`` [C,2] int32 node edges (padded slots point at the trash node);
    ``labels_ext`` [n_nodes+1] dense community per node with the trash slot
    mapped to ``s_cap``.
    """
    trash = labels_ext.shape[0] - 1
    cu = labels_ext[jnp.minimum(chunk[:, 0], trash)]
    cv = labels_ext[jnp.minimum(chunk[:, 1], trash)]
    a = jnp.minimum(cu, cv)
    b = jnp.maximum(cu, cv)
    valid = (a != b) & (a < s_cap) & (b < s_cap)
    a = jnp.where(valid, a, s_cap)
    b = jnp.where(valid, b, s_cap)
    w = jnp.where(valid, 1.0, 0.0).astype(jnp.float32)
    return a, b, w


def _agg_update_lexsort(state, a, b, w, s_cap: int, max_super_edges: int):
    """Baseline: one full lexsort of state + chunk, segment-sum re-dedupe."""
    pa, pb, pw, _ = state
    ca = jnp.concatenate([pa, a])
    cb = jnp.concatenate([pb, b])
    cw = jnp.concatenate([pw, w])

    # Lexsort by (a, b); invalid slots (s_cap, s_cap) sort last.
    order = jnp.lexsort((cb, ca))
    a_s, b_s, w_s = ca[order], cb[order], cw[order]
    new_pair = jnp.concatenate(
        [jnp.array([True]), (a_s[1:] != a_s[:-1]) | (b_s[1:] != b_s[:-1])]
    )
    new_pair = new_pair & (a_s != s_cap)
    seg = jnp.cumsum(new_pair) - 1  # dense superedge id per sorted slot (or -1 prefix)
    seg = jnp.where(a_s != s_cap, seg, max_super_edges)

    sw = jnp.zeros(max_super_edges + 1, jnp.float32).at[seg].add(w_s)
    sa = jnp.full((max_super_edges + 1,), s_cap, jnp.int32).at[seg].set(a_s)
    sb = jnp.full((max_super_edges + 1,), s_cap, jnp.int32).at[seg].set(b_s)
    n_superedges = jnp.sum(new_pair).astype(jnp.int32)
    return (
        sa[:max_super_edges],
        sb[:max_super_edges],
        sw[:max_super_edges],
        n_superedges,
    )


def _dedupe_chunk(a, b, w, s_cap: int):
    """Level one of the merge scheme: sort + combine only the C chunk pairs.

    Returns (ca, cb, cw): a sorted run of the chunk's unique valid pairs
    with summed multiplicities, padded with (s_cap, s_cap, 0) slots.
    """
    c = a.shape[0]
    key = pack_keys(a, b, s_cap)
    order = jnp.argsort(key)
    k_s, w_s = key[order], w[order]
    first = jnp.concatenate([jnp.array([True]), k_s[1:] != k_s[:-1]])
    first = first & (k_s != SENTINEL)
    seg = jnp.cumsum(first) - 1  # dense local id per sorted slot (or -1 prefix)
    seg = jnp.where(k_s != SENTINEL, seg, c)
    cw = jnp.zeros((c + 1,), jnp.float32).at[seg].add(w_s)
    ck = jnp.full((c + 1,), SENTINEL, jnp.uint32).at[seg].set(k_s)
    ca, cb = unpack_keys(ck[:c], s_cap)
    return ca, cb, cw[:c]


def _agg_update_merge(state, a, b, w, s_cap: int, kernel_backend: str):
    """Two-level scheme: local chunk dedupe, then sorted-merge into state."""
    pa, pb, pw, _ = state
    ca, cb, cw = _dedupe_chunk(a, b, w, s_cap)
    return merge_ops.merge_combine(
        pa, pb, pw, ca, cb, cw, s_cap, backend=kernel_backend
    )


def _agg_update_body(
    state,
    chunk,
    labels_ext,
    s_cap: int,
    max_super_edges: int,
    agg_backend: str = "merge",
    kernel_backend: str = "auto",
):
    """Combine one edge chunk into the aggregation state (jittable).

    ``agg_backend`` selects the combine algorithm ("merge" default,
    "lexsort" baseline — bit-identical below capacity); ``kernel_backend``
    is forwarded to ``kernels/merge/ops.py`` on the merge path.
    """
    a, b, w = _chunk_pairs(chunk, labels_ext, s_cap)
    if agg_backend == "lexsort":
        def run(st):
            return _agg_update_lexsort(st, a, b, w, s_cap, max_super_edges)
    elif agg_backend == "merge":
        def run(st):
            return _agg_update_merge(st, a, b, w, s_cap, kernel_backend)
    else:
        raise ValueError(f"unknown agg_backend {agg_backend!r}")
    # An all-invalid chunk (every edge intra-community or trash-padded)
    # is a no-op for any backend: short-circuit it instead of paying a
    # full state rewrite.
    return jax.lax.cond(jnp.any(a != s_cap), run, lambda st: st, state)


agg_update = functools.partial(
    jax.jit,
    static_argnames=("s_cap", "max_super_edges", "agg_backend", "kernel_backend"),
    donate_argnums=(0,),
)(_agg_update_body)


@functools.lru_cache(maxsize=None)
def sharded_agg_update(mesh, s_cap: int, max_super_edges: int,
                       agg_backend: str = "merge",
                       kernel_backend: str = "auto"):
    """Compiled sharded ``agg_update`` over ``mesh``.

    The chunk arrives row-sharded (``row_chunk_spec``), state and labels
    replicated. Merge path: each shard maps + dedupes its own C/D rows (the
    sort is the expensive step, now D-way parallel), one ``all_gather``
    concatenates the local runs back in row order, and a second dedupe
    restores the single sorted run — bit-identical input to
    ``merge_combine`` even at capacity overflow, because the unique pair
    set and the (integer-valued float) summed weights match the one-device
    dedupe exactly. Lexsort path: the gather of contiguous row shards
    reproduces the original chunk arrays verbatim, then runs the baseline
    unchanged. Requires ``chunk_len % mesh.size == 0`` — callers gate.
    """
    from jax.sharding import PartitionSpec as P

    from repro.kernels.compat import shard_map_compat
    from repro.sharding.rules import row_chunk_spec

    axes = tuple(mesh.axis_names)

    def body(state, chunk, labels_ext):
        a, b, w = _chunk_pairs(chunk, labels_ext, s_cap)
        if agg_backend == "lexsort":
            ga = jax.lax.all_gather(a, axes, axis=0, tiled=True)
            gb = jax.lax.all_gather(b, axes, axis=0, tiled=True)
            gw = jax.lax.all_gather(w, axes, axis=0, tiled=True)

            def run(st):
                return _agg_update_lexsort(st, ga, gb, gw, s_cap, max_super_edges)
        elif agg_backend == "merge":
            la, lb, lw = _dedupe_chunk(a, b, w, s_cap)
            ga = jax.lax.all_gather(la, axes, axis=0, tiled=True)
            gb = jax.lax.all_gather(lb, axes, axis=0, tiled=True)
            gw = jax.lax.all_gather(lw, axes, axis=0, tiled=True)
            ca, cb, cw = _dedupe_chunk(ga, gb, gw, s_cap)

            def run(st):
                pa, pb, pw, _ = st
                return merge_ops.merge_combine(
                    pa, pb, pw, ca, cb, cw, s_cap, backend=kernel_backend
                )
        else:
            raise ValueError(f"unknown agg_backend {agg_backend!r}")
        # Same short-circuit as the single-device path; the predicate is
        # over the gathered (replicated) pairs, so every device agrees.
        return jax.lax.cond(jnp.any(ga != s_cap), run, lambda st: st, state)

    mapped = shard_map_compat(
        body,
        mesh,
        in_specs=((P(), P(), P(), P()), row_chunk_spec(mesh), P()),
        out_specs=(P(), P(), P(), P()),
    )
    return jax.jit(mapped, donate_argnums=(0,))


def agg_finalize(state):
    """(sedges [cap,2], sweights [cap], n_superedges) from aggregation state."""
    a, b, w, n = state
    return jnp.stack([a, b], axis=1), w, n


@functools.partial(
    jax.jit, static_argnames=("s_cap", "max_super_edges", "agg_backend")
)
def aggregate_edges(
    edges: jnp.ndarray,
    labels_dense: jnp.ndarray,
    s_cap: int,
    max_super_edges: int,
    agg_backend: str = "merge",
):
    """Map node edges through community labels, drop intra edges, dedupe
    (one-shot wrapper: the whole edge list as a single chunk).

    Returns (sedges [cap,2], sweights [cap], n_superedges).
    """
    labels_ext = jnp.concatenate([labels_dense, jnp.array([s_cap], jnp.int32)])
    state = agg_init(s_cap, max_super_edges)
    state = _agg_update_body(
        state, edges, labels_ext, s_cap, max_super_edges, agg_backend
    )
    return agg_finalize(state)


def community_sizes(
    labels_dense: jnp.ndarray,
    node_deg: jnp.ndarray,
    n_supernodes: jnp.ndarray,
    s_cap: int,
    cms_cfg: cms_lib.CMSConfig,
    mesh=None,
) -> jnp.ndarray:
    """CMS-estimated community sizes (paper §4.1): one sketch update per node,
    weight = its true graph degree; queries beyond the live count are masked.

    With ``mesh`` the node keys are sharded over devices (padded to a
    multiple of the device count with the masked key -1) and the sketch is
    merged by one ``psum`` — exact, since degrees are integer-valued.
    """
    sketch = cms_lib.init(cms_cfg)
    weights = node_deg.astype(jnp.float32)
    if mesh is not None and mesh.size > 1:
        pad = (-labels_dense.shape[0]) % mesh.size
        keys = jnp.concatenate(
            [labels_dense, jnp.full((pad,), -1, jnp.int32)]
        )
        weights = jnp.concatenate([weights, jnp.zeros((pad,), jnp.float32)])
        sketch = cms_lib.sharded_update(mesh, cms_cfg)(sketch, keys, weights)
    else:
        sketch = cms_lib.update(sketch, labels_dense, weights, cms_cfg)
    sizes = cms_lib.query(cms_lib.finalize(sketch), jnp.arange(s_cap, dtype=jnp.int32), cms_cfg)
    return jnp.where(jnp.arange(s_cap) < n_supernodes, sizes, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "s_cap", "max_super_edges", "cms_cfg", "agg_backend"),
)
def build_supergraph(
    edges: jnp.ndarray,
    labels: jnp.ndarray,
    node_deg: jnp.ndarray,
    n_nodes: int,
    s_cap: int,
    max_super_edges: int,
    cms_cfg: cms_lib.CMSConfig,
    agg_backend: str = "merge",
) -> Supergraph:
    """Full paper path: dense-relabel communities, CMS-size them, dedupe edges.

    Community size (paper §4.1): sum of *graph* degrees of member nodes
    (≈ 2×intra edges), accumulated through the count–min sketch keyed by
    community id — never an exact counter.
    """
    labels_dense, n_supernodes = dense_labels(labels, n_nodes)
    sizes = community_sizes(labels_dense, node_deg, n_supernodes, s_cap, cms_cfg)

    sedges, sweights, n_superedges = aggregate_edges(
        edges, labels_dense, s_cap, max_super_edges, agg_backend
    )
    return Supergraph(
        edges=sedges,
        weights=sweights,
        sizes=sizes,
        n_supernodes=n_supernodes,
        n_superedges=n_superedges,
        labels=labels_dense,
    )


jax.tree_util.register_dataclass(
    Supergraph,
    data_fields=["edges", "weights", "sizes", "n_supernodes", "n_superedges", "labels"],
    meta_fields=[],
)
