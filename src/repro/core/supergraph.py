"""Supergraph construction (paper §4.1): communities → weighted supernodes,
inter-community edges → weighted superedges.

Static-shape implementation: superedges are deduplicated by lexsorting the
canonicalized (min,max) community pairs and segment-summing multiplicities
into a fixed ``max_super_edges`` capacity. All jittable.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import cms as cms_lib
from repro.core.scoda import dense_labels

INT32_MAX = jnp.iinfo(jnp.int32).max


@dataclass
class Supergraph:
    """Padded supergraph. Padded superedge slots point at ``s_cap`` (trash)."""

    edges: jnp.ndarray  # [max_super_edges, 2] int32, dense community ids
    weights: jnp.ndarray  # [max_super_edges] float32 (edge multiplicity)
    sizes: jnp.ndarray  # [s_cap] float32 supernode weights (CMS estimate)
    n_supernodes: jnp.ndarray  # scalar int32
    n_superedges: jnp.ndarray  # scalar int32
    labels: jnp.ndarray  # [n_nodes] int32 node → dense community id


@functools.partial(jax.jit, static_argnames=("s_cap", "max_super_edges"))
def aggregate_edges(
    edges: jnp.ndarray,
    labels_dense: jnp.ndarray,
    s_cap: int,
    max_super_edges: int,
):
    """Map node edges through community labels, drop intra edges, dedupe.

    Returns (sedges [cap,2], sweights [cap], n_superedges).
    """
    trash = labels_dense.shape[0]  # edges padded with n_nodes
    labels_ext = jnp.concatenate([labels_dense, jnp.array([s_cap], jnp.int32)])
    cu = labels_ext[jnp.minimum(edges[:, 0], trash)]
    cv = labels_ext[jnp.minimum(edges[:, 1], trash)]
    a = jnp.minimum(cu, cv)
    b = jnp.maximum(cu, cv)
    valid = (a != b) & (a < s_cap) & (b < s_cap)
    a = jnp.where(valid, a, s_cap)
    b = jnp.where(valid, b, s_cap)

    # Lexsort by (a, b); invalid slots (s_cap, s_cap) sort last.
    order = jnp.lexsort((b, a))
    a_s, b_s = a[order], b[order]
    new_pair = jnp.concatenate(
        [jnp.array([True]), (a_s[1:] != a_s[:-1]) | (b_s[1:] != b_s[:-1])]
    )
    new_pair = new_pair & (a_s != s_cap)
    seg = jnp.cumsum(new_pair) - 1  # dense superedge id per sorted slot (or -1 prefix)
    seg = jnp.where(a_s != s_cap, seg, max_super_edges)

    sw = jnp.zeros(max_super_edges + 1, jnp.float32).at[seg].add(1.0)
    se = jnp.full((max_super_edges + 1, 2), s_cap, jnp.int32)
    se = se.at[seg, 0].set(a_s)  # duplicate writes carry identical values
    se = se.at[seg, 1].set(b_s)
    n_superedges = jnp.sum(new_pair).astype(jnp.int32)
    return se[:max_super_edges], sw[:max_super_edges], n_superedges


@functools.partial(
    jax.jit, static_argnames=("n_nodes", "s_cap", "max_super_edges", "cms_cfg")
)
def build_supergraph(
    edges: jnp.ndarray,
    labels: jnp.ndarray,
    node_deg: jnp.ndarray,
    n_nodes: int,
    s_cap: int,
    max_super_edges: int,
    cms_cfg: cms_lib.CMSConfig,
) -> Supergraph:
    """Full paper path: dense-relabel communities, CMS-size them, dedupe edges.

    Community size (paper §4.1): sum of *graph* degrees of member nodes
    (≈ 2×intra edges), accumulated through the count–min sketch keyed by
    community id — never an exact counter.
    """
    labels_dense, n_supernodes = dense_labels(labels, n_nodes)
    # CMS sizing: one update per node, weight = its true graph degree.
    sketch = cms_lib.init_sketch(cms_cfg)
    sketch = cms_lib.update(sketch, labels_dense, node_deg.astype(jnp.float32), cms_cfg)
    sizes = cms_lib.query(sketch, jnp.arange(s_cap, dtype=jnp.int32), cms_cfg)
    # Mask queries beyond the live community count.
    sizes = jnp.where(jnp.arange(s_cap) < n_supernodes, sizes, 0.0)

    sedges, sweights, n_superedges = aggregate_edges(
        edges, labels_dense, s_cap, max_super_edges
    )
    return Supergraph(
        edges=sedges,
        weights=sweights,
        sizes=sizes,
        n_supernodes=n_supernodes,
        n_superedges=n_superedges,
        labels=labels_dense,
    )


jax.tree_util.register_dataclass(
    Supergraph,
    data_fields=["edges", "weights", "sizes", "n_supernodes", "n_superedges", "labels"],
    meta_fields=[],
)
