"""Community-size coloring (paper §4.3).

11 qualitative buckets: the *smaller* communities that together account for
50% of total size α share the first color (brown); the remaining
communities are split into 10 equal-count groups colored small→big:
brown, light purple, purple, light orange, orange, light red, red,
light green, green, light blue, blue.
"""
from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

# ColorBrewer-flavoured qualitative scale, small → big (RGB, 0-255).
PALETTE = np.array(
    [
        [140, 86, 75],  # brown (bulk of small communities)
        [197, 176, 213],  # light purple
        [148, 103, 189],  # purple
        [255, 187, 120],  # light orange
        [255, 127, 14],  # orange
        [255, 152, 150],  # light red
        [214, 39, 40],  # red
        [152, 223, 138],  # light green
        [44, 160, 44],  # green
        [174, 199, 232],  # light blue
        [31, 119, 180],  # blue
    ],
    dtype=np.uint8,
)


@jax.jit
def color_groups(sizes: jnp.ndarray) -> jnp.ndarray:
    """[S] sizes → [S] color-group index in [0, 11). Zero-size slots → 0."""
    s = sizes.shape[0]
    order = jnp.argsort(sizes)  # ascending
    sorted_sizes = sizes[order]
    total = jnp.sum(sizes)
    csum = jnp.cumsum(sorted_sizes)
    # Communities in the lower 50% of cumulative mass → group 0 (brown).
    in_bulk = csum <= 0.5 * total
    n_bulk = jnp.sum(in_bulk)
    # Remaining communities → 10 equal-count groups by rank.
    rank = jnp.arange(s)
    rest_rank = rank - n_bulk
    n_rest = jnp.maximum(s - n_bulk, 1)
    group_rest = 1 + (rest_rank * 10) // n_rest
    group_sorted = jnp.where(in_bulk, 0, jnp.clip(group_rest, 1, 10))
    groups = jnp.zeros(s, jnp.int32).at[order].set(group_sorted.astype(jnp.int32))
    return jnp.where(sizes > 0, groups, 0)


def node_colors(groups: np.ndarray) -> np.ndarray:
    """Group indices → RGB."""
    return PALETTE[np.asarray(groups)]


def write_svg(path: str, pos: np.ndarray, radii: np.ndarray, groups: np.ndarray,
              edges: np.ndarray | None = None, max_nodes: int = 200_000) -> str:
    """Minimal SVG renderer (no display stack on TPU hosts — DESIGN.md §2).

    The per-element Python string loop only scales to small graphs; inputs
    beyond ``max_nodes`` delegate to the streaming rasterizer
    (repro/render) and write a PNG next to ``path`` instead. Returns the
    path actually written.
    """
    pos = np.asarray(pos)
    radii = np.asarray(radii)
    groups = np.asarray(groups)
    if len(pos) > max_nodes:
        # Local import: repro.render pulls PALETTE from this module.
        from repro.render import render_arrays
        from repro.render.png import write_png

        out = str(Path(path).with_suffix(".png"))
        image, _stats = render_arrays(pos, radii, groups, edges)
        return write_png(out, image)
    colors = node_colors(groups)
    lo = pos.min(axis=0)
    hi = pos.max(axis=0)
    span = np.maximum(hi - lo, 1e-6)
    size = 1024.0
    xy = (pos - lo) / span * size
    # SVG y grows downward; world y grows upward — flip so the drawing is
    # not mirrored about the horizontal axis.
    xy[:, 1] = size - xy[:, 1]
    rr = radii / span.max() * size
    rr = np.clip(rr, 0.5, size / 8)
    parts = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{int(size)}" height="{int(size)}">']
    if edges is not None:
        for u, v in np.asarray(edges):
            if u < len(xy) and v < len(xy):
                parts.append(
                    f'<line x1="{xy[u,0]:.1f}" y1="{xy[u,1]:.1f}" '
                    f'x2="{xy[v,0]:.1f}" y2="{xy[v,1]:.1f}" '
                    'stroke="#cccccc" stroke-width="0.3"/>'
                )
    for (x, y), r, (cr, cg, cb) in zip(xy, rr, colors):
        parts.append(
            f'<circle cx="{x:.1f}" cy="{y:.1f}" r="{r:.1f}" '
            f'fill="rgb({cr},{cg},{cb})" fill-opacity="0.8"/>'
        )
    parts.append("</svg>")
    with open(path, "w") as f:
        f.write("\n".join(parts))
    return str(path)
