"""Newman modularity (paper §5.3.2, Eq. 2) via segment sums.

    Q = Σ_c [ e_c / m  −  (d_c / 2m)² ]

where e_c = intra-community edges of c, d_c = total degree of c, m = |E|.
Equivalent to Eq. 2 and computable in O(E) with two scatter-adds — no
pairwise term needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# Chunk-incremental API (core/stream.py engine): the three accumulators
# (m, intra, dcom) are plain sums over edges, so chunked accumulation is
# exact. ``modularity`` is the one-shot wrapper over a single chunk.


def modularity_init(n_nodes: int):
    """Fresh accumulators: (m scalar, intra [n+1], dcom [n+1]) float32."""
    return (
        jnp.zeros((), jnp.float32),
        jnp.zeros(n_nodes + 1, jnp.float32),
        jnp.zeros(n_nodes + 1, jnp.float32),
    )


def _modularity_update_body(state, chunk, labels_ext):
    """Accumulate one edge chunk. ``labels_ext`` [n+1] with trash slot = -1."""
    m, intra, dcom = state
    trash = labels_ext.shape[0] - 1
    cu = labels_ext[jnp.minimum(chunk[:, 0], trash)]
    cv = labels_ext[jnp.minimum(chunk[:, 1], trash)]
    valid = (chunk[:, 0] != trash) & (chunk[:, 1] != trash)
    m = m + jnp.sum(valid).astype(jnp.float32)
    key = jnp.where(valid & (cu == cv), cu, trash)
    intra = intra.at[key].add(1.0)
    dcom = dcom.at[jnp.where(valid, cu, trash)].add(1.0)
    dcom = dcom.at[jnp.where(valid, cv, trash)].add(1.0)
    return m, intra, dcom


modularity_update = jax.jit(_modularity_update_body, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def sharded_modularity_update(mesh):
    """Compiled sharded ``modularity_update`` over ``mesh``: the chunk is
    row-sharded, labels/state replicated, and the three accumulators merge
    by one ``psum`` — exact, since every scatter adds 1.0 (integer-valued
    float32 sums). Requires ``chunk_len % mesh.size == 0``."""
    from jax.sharding import PartitionSpec as P

    from repro.kernels.compat import shard_map_compat
    from repro.sharding.rules import row_chunk_spec

    axes = tuple(mesh.axis_names)

    def body(state, chunk, labels_ext):
        zero = (
            jnp.zeros_like(state[0]),
            jnp.zeros_like(state[1]),
            jnp.zeros_like(state[2]),
        )
        inc = _modularity_update_body(zero, chunk, labels_ext)
        inc = jax.lax.psum(inc, axes)
        return tuple(s + d for s, d in zip(state, inc))

    mapped = shard_map_compat(
        body,
        mesh,
        in_specs=((P(), P(), P()), row_chunk_spec(mesh), P()),
        out_specs=(P(), P(), P()),
    )
    return jax.jit(mapped, donate_argnums=(0,))


def _modularity_finalize_body(state):
    m, intra, dcom = state
    return jnp.sum(intra[:-1] / m - (dcom[:-1] / (2.0 * m)) ** 2)


modularity_finalize = jax.jit(_modularity_finalize_body)


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def modularity(edges: jnp.ndarray, labels: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    """edges [E,2] int32 (padded slots = n_nodes), labels [n_nodes] int32."""
    labels_ext = jnp.concatenate([labels, jnp.array([-1], jnp.int32)])
    state = modularity_init(n_nodes)
    state = _modularity_update_body(state, edges, labels_ext)
    return _modularity_finalize_body(state)
