"""Newman modularity (paper §5.3.2, Eq. 2) via segment sums.

    Q = Σ_c [ e_c / m  −  (d_c / 2m)² ]

where e_c = intra-community edges of c, d_c = total degree of c, m = |E|.
Equivalent to Eq. 2 and computable in O(E) with two scatter-adds — no
pairwise term needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def modularity(edges: jnp.ndarray, labels: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    """edges [E,2] int32 (padded slots = n_nodes), labels [n_nodes] int32."""
    trash = n_nodes
    labels_ext = jnp.concatenate([labels, jnp.array([-1], jnp.int32)])
    cu = labels_ext[jnp.minimum(edges[:, 0], trash)]
    cv = labels_ext[jnp.minimum(edges[:, 1], trash)]
    valid = (edges[:, 0] != trash) & (edges[:, 1] != trash)
    m = jnp.sum(valid).astype(jnp.float32)

    # intra edges per community
    intra = jnp.zeros(n_nodes + 1, jnp.float32)
    key = jnp.where(valid & (cu == cv), cu, n_nodes)
    intra = intra.at[key].add(1.0)[:n_nodes]

    # degree per community
    dcom = jnp.zeros(n_nodes + 1, jnp.float32)
    dcom = dcom.at[jnp.where(valid, cu, n_nodes)].add(1.0)
    dcom = dcom.at[jnp.where(valid, cv, n_nodes)].add(1.0)
    dcom = dcom[:n_nodes]

    return jnp.sum(intra / m - (dcom / (2.0 * m)) ** 2)
