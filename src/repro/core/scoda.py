"""TPU-adapted parallel streaming community detection (SCoDA, paper §3.2.1).

The paper's GPU variant assigns one CUDA thread per edge and lets degree
updates / community writes race through atomics. TPUs have no such atomics;
the adaptation (documented in DESIGN.md §2) processes the edge stream in
fixed-size *blocks* via ``lax.scan``:

  * inside a block every edge is evaluated in parallel against the
    block-start degree/community snapshot (vectorized),
  * conflicting community writes to the same node are resolved by a
    deterministic min-reduction (``.at[].min``) — replacing the GPU's
    nondeterministic last-write-wins,
  * degree increments land via scatter-add (``.at[].add``), the TPU's
    native "atomic add".

``block_size`` is the parallelism/fidelity dial: block_size=1 is exactly
the sequential SCoDA; larger blocks = more parallelism, coarser snapshot —
mirroring the paper's GPU trade-off but deterministic and replayable.

Rounds follow the paper's Algorithm 3: each round re-streams the edge list
with persistent (community, degree) state and a threshold that grows
geometrically (δ^i) so larger communities can keep absorbing smaller ones
("hierarchical community detection").
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

INT32_MAX = jnp.iinfo(jnp.int32).max


@dataclass(frozen=True)
class ScodaConfig:
    degree_threshold: int  # δ — paper default: mode degree of the graph
    rounds: int = 4
    block_size: int = 4096
    threshold_growth: float = 2.0  # threshold at round i: δ * growth^(i-1) (δ^i capped)
    threshold_schedule: str = "paper"  # "paper": δ^i ; "geometric": δ·g^(i-1)
    tie_break: str = "skip"  # paper Algorithm 3 skips equal-degree edges
    # Paper Algorithm 3 as printed increments degrees only on adoption — but
    # then every degree stays 0 and no edge ever adopts (deadlock). Hollocou's
    # SCoDA increments BOTH endpoint degrees for every processed edge; that is
    # the only functional reading, so it is the default ("scoda").
    degree_update: str = "scoda"  # "scoda": both endpoints every edge; "paper": adoptee++ only
    compress_labels: bool = False  # beyond-paper: pointer-jump label compression
    # Beyond-paper fidelity recovery (DESIGN.md §2): with exact_block_degrees
    # each edge sees deg(snapshot) + (its endpoint's prior occurrences within
    # the block), computed by a vectorized cumulative count — the *exact*
    # sequential degree trajectory at full block parallelism (degrees only;
    # labels still come from the block snapshot).
    exact_block_degrees: bool = True
    # Conflict resolution among same-block donors: "min" = smallest community
    # id wins (simple, biased toward low ids); "max_degree" = highest-degree
    # donor wins (paper §3.2.1: big communities absorb small ones).
    conflict: str = "max_degree"
    # Beyond-paper fidelity recovery #2: sequential SCoDA propagates labels
    # transitively through the stream (w adopts com(u) AFTER u adopted
    # com(v)); a block snapshot loses those chains and fragments communities
    # into stars. ``propagate_jumps`` pointer-jumping passes at block end
    # collapse chains of length ≤ 2^jumps. Adoption points strictly up the
    # degree order under snapshot degrees, so chains are acyclic; rare cycles
    # under exact_block_degrees are bounded by the fixed jump count.
    # Default 0: measured against the sequential oracle, jumping over-merges
    # (chains cross community borders); see EXPERIMENTS.md §Reproduction.
    propagate_jumps: int = 0


def round_threshold(cfg: ScodaConfig, i: int) -> int:
    if cfg.threshold_schedule == "paper":
        t = float(cfg.degree_threshold) ** (i + 1)
    else:
        t = float(cfg.degree_threshold) * (cfg.threshold_growth ** i)
    return int(min(t, 2**30))


_round_threshold = round_threshold  # back-compat alias


def _cumcount_endpoints(u, v, valid):
    """Per-edge prior-occurrence counts of each endpoint within the block.

    Flattens endpoints in stream order [u0,v0,u1,v1,...] and counts, for each
    slot, how many earlier slots name the same node — a vectorized sort +
    rank-in-group. O(B log B), fully parallel.
    """
    bs = u.shape[0]
    flat = jnp.stack([u, v], axis=1).reshape(-1)  # [2B] stream order
    order = jnp.argsort(flat, stable=True)
    sorted_vals = flat[order]
    is_start = jnp.concatenate(
        [jnp.array([True]), sorted_vals[1:] != sorted_vals[:-1]]
    )
    idx = jnp.arange(2 * bs, dtype=jnp.int32)
    group_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    rank_sorted = idx - group_start
    rank = jnp.zeros(2 * bs, jnp.int32).at[order].set(rank_sorted)
    rank = jnp.where(valid.repeat(2), rank, 0)
    return rank[0::2], rank[1::2]


def _block_update(state, block, *, threshold, tie_break, degree_update,
                  exact_block_degrees, conflict, propagate_jumps,
                  mesh_axes=None, mesh_sizes=None):
    """Process one block of edges against the block-start snapshot.

    With ``mesh_axes`` set the body runs inside a ``shard_map``: ``block``
    is this device's slice of the block (``block_chunk_spec`` placement),
    state stays replicated, and every scatter reduction is completed by the
    matching integer all-reduce (min/max/sum are order-free, so the result
    is bitwise identical to the single-device block update).
    """
    com, deg = state
    u, v = block[:, 0], block[:, 1]
    trash = com.shape[0] - 1  # index n_nodes = trash slot
    valid = (u != trash) & (v != trash) & (u != v)

    if degree_update == "scoda":
        # Hollocou semantics: degrees bump for every processed edge, and the
        # join test sees the post-increment values. Under block-parallel
        # streaming the snapshot approximates this (DESIGN.md §2).
        if exact_block_degrees:
            if mesh_axes is None:
                cu, cv = _cumcount_endpoints(u, v, valid)
            else:
                # The cumulative occurrence count is a prefix over the FULL
                # block in stream order — gather the block (tiled order ==
                # the row order of the sharding) and slice back our rows.
                from repro.sharding.rules import linear_axis_index

                bsl = u.shape[0]
                full = jax.lax.all_gather(block, mesh_axes, axis=0, tiled=True)
                uf, vf = full[:, 0], full[:, 1]
                validf = (uf != trash) & (vf != trash) & (uf != vf)
                cuf, cvf = _cumcount_endpoints(uf, vf, validf)
                i0 = bsl * linear_axis_index(mesh_axes, mesh_sizes)
                cu = jax.lax.dynamic_slice_in_dim(cuf, i0, bsl)
                cv = jax.lax.dynamic_slice_in_dim(cvf, i0, bsl)
        else:
            cu = cv = 0
        du = deg[u] + 1 + cu
        dv = deg[v] + 1 + cv
    else:
        du = deg[u]
        dv = deg[v]
    elig = valid & (du <= threshold) & (dv <= threshold)

    adopt_v = elig & (du > dv)  # v adopts com[u]
    adopt_u = elig & (dv > du)  # u adopts com[v]
    if tie_break == "join":
        adopt_u = adopt_u | (elig & (du == dv))

    adoptee = jnp.where(adopt_v, v, jnp.where(adopt_u, u, trash))
    donor = jnp.where(adopt_v, u, v)
    donor_com = com[donor]
    any_adopt = adopt_u | adopt_v
    donor_com = jnp.where(any_adopt, donor_com, INT32_MAX)

    if conflict == "max_degree":
        # Highest-degree donor wins (big communities absorb small, §3.2.1);
        # ties broken toward the smaller community id. Two scatters:
        # 1) winning donor degree per adoptee, 2) min com among winners.
        donor_deg = jnp.where(any_adopt, jnp.where(adopt_v, du, dv), -1)
        win_deg = jnp.full_like(com, -1).at[adoptee].max(donor_deg)
        if mesh_axes is not None:  # winners are decided across ALL shards
            win_deg = jax.lax.pmax(win_deg, mesh_axes)
        is_winner = any_adopt & (donor_deg == win_deg[adoptee])
        cand_val = jnp.where(is_winner, donor_com, INT32_MAX)
        cand = jnp.full_like(com, INT32_MAX).at[adoptee].min(cand_val)
    else:  # "min": smallest donor community id wins
        cand = jnp.full_like(com, INT32_MAX).at[adoptee].min(donor_com)
    if mesh_axes is not None:
        cand = jax.lax.pmin(cand, mesh_axes)
    new_com = jnp.where(cand != INT32_MAX, cand, com)
    new_com = new_com.at[trash].set(trash)
    for _ in range(propagate_jumps):  # collapse intra-block adoption chains
        new_com = new_com[new_com]

    if degree_update == "paper":
        if mesh_axes is None:
            new_deg = deg.at[adoptee].add(jnp.where(any_adopt, 1, 0))
        else:
            inc = jnp.zeros_like(deg).at[adoptee].add(jnp.where(any_adopt, 1, 0))
            new_deg = deg + jax.lax.psum(inc, mesh_axes)
    else:  # original SCoDA: both endpoints bump on every processed edge
        if mesh_axes is None:
            new_deg = deg.at[u].add(jnp.where(valid, 1, 0)).at[v].add(jnp.where(valid, 1, 0))
        else:
            ones = jnp.where(valid, 1, 0)
            inc = jnp.zeros_like(deg).at[u].add(ones).at[v].add(ones)
            new_deg = deg + jax.lax.psum(inc, mesh_axes)
    new_deg = new_deg.at[trash].set(0)
    return (new_com, new_deg), None


# --------------------------------------------------------------------------
# Chunk-incremental API (core/stream.py engine): init → update×chunks → finalize.
# The one-shot ``detect_communities`` below is a thin wrapper that feeds the
# whole edge list through the same update body as a single chunk, so chunked
# and one-shot execution are bit-for-bit identical whenever the chunk size is
# a multiple of ``block_size`` (identical block partition of the stream).
# --------------------------------------------------------------------------


def scoda_init(n_nodes: int):
    """Fresh SCoDA state: (com, deg), each [n_nodes+1] (last slot = trash)."""
    com = jnp.arange(n_nodes + 1, dtype=jnp.int32)
    deg = jnp.zeros(n_nodes + 1, dtype=jnp.int32)
    return com, deg


def _scoda_update_body(state, chunk, threshold, cfg: ScodaConfig):
    """One pass of one round over a chunk of the edge stream (jittable).

    ``chunk`` [C,2] int32 with padded slots pointing at the trash node;
    ``threshold`` may be a python int or a traced int32 scalar. The chunk is
    scanned in blocks of ``cfg.block_size`` exactly like the one-shot path.
    """
    trash = state[0].shape[0] - 1
    e = chunk.shape[0]
    bs = min(cfg.block_size, e)
    n_blocks = (e + bs - 1) // bs
    pad = n_blocks * bs - e
    blocks = jnp.concatenate(
        [chunk, jnp.full((pad, 2), trash, dtype=chunk.dtype)], axis=0
    ).reshape(n_blocks, bs, 2)
    step = functools.partial(
        _block_update,
        threshold=threshold,
        tie_break=cfg.tie_break,
        degree_update=cfg.degree_update,
        exact_block_degrees=cfg.exact_block_degrees,
        conflict=cfg.conflict,
        propagate_jumps=cfg.propagate_jumps,
    )
    state, _ = jax.lax.scan(step, state, blocks)
    return state


# Threshold is a traced scalar so all rounds share one executable; state is
# donated — the engine holds exactly one (com, deg) copy on device.
scoda_update = functools.partial(jax.jit, static_argnames=("cfg",),
                                 donate_argnums=(0,))(_scoda_update_body)


@functools.lru_cache(maxsize=None)
def sharded_scoda_update(mesh, cfg: ScodaConfig):
    """Compiled sharded chunk update over ``mesh``.

    Takes (state, blocks [n_blocks, block_size, 2], threshold): blocks must
    arrive sharded per ``block_chunk_spec`` (every device owns the same
    within-block slice of every block), state/threshold replicated; returns
    the replicated updated state. Bit-identical to ``scoda_update`` on the
    equivalent flat chunk: the block scan runs in lockstep across devices
    and every cross-device reduction is an integer min/max/sum (order-free).
    Requires ``block_size % mesh.size == 0`` — callers gate on that.
    """
    from jax.sharding import PartitionSpec as P

    from repro.kernels.compat import shard_map_compat
    from repro.sharding.rules import block_chunk_spec

    axes = tuple(mesh.axis_names)
    sizes = tuple(mesh.shape[a] for a in axes)

    def body(state, blocks, threshold):
        step = functools.partial(
            _block_update,
            threshold=threshold,
            tie_break=cfg.tie_break,
            degree_update=cfg.degree_update,
            exact_block_degrees=cfg.exact_block_degrees,
            conflict=cfg.conflict,
            propagate_jumps=cfg.propagate_jumps,
            mesh_axes=axes,
            mesh_sizes=sizes,
        )
        state, _ = jax.lax.scan(step, state, blocks)
        return state

    mapped = shard_map_compat(
        body,
        mesh,
        in_specs=((P(), P()), block_chunk_spec(mesh), P()),
        out_specs=(P(), P()),
    )
    return jax.jit(mapped, donate_argnums=(0,))


def _scoda_finalize_body(state, n_nodes: int, cfg: ScodaConfig):
    com, deg = state
    if cfg.compress_labels:
        # Pointer jumping: compose the node→representative map to a fixpoint.
        def cond_fn(carry):
            c, it = carry
            return it < 32

        def body_fn(carry):
            c, it = carry
            return c[c], it + 1

        # log2(n) pointer jumps always reach the fixpoint; 32 covers any int32 n.
        com, _ = jax.lax.while_loop(cond_fn, body_fn, (com, 0))
    return com[:n_nodes], deg[:n_nodes]


scoda_finalize = functools.partial(
    jax.jit, static_argnames=("n_nodes", "cfg")
)(_scoda_finalize_body)


@functools.partial(jax.jit, static_argnames=("n_nodes", "cfg"))
def detect_communities(edges: jnp.ndarray, n_nodes: int, cfg: ScodaConfig):
    """Run multi-round block-streamed SCoDA (one-shot wrapper over the
    chunk-incremental API: the whole edge list is a single chunk per round).

    edges: [E, 2] int32 (padded slots = n_nodes).
    Returns (labels [n_nodes] int32 — community = representative node id,
             deg [n_nodes] int32 — SCoDA working degrees).
    """
    state = scoda_init(n_nodes)
    for i in range(cfg.rounds):
        state = _scoda_update_body(state, edges, round_threshold(cfg, i), cfg)
    return _scoda_finalize_body(state, n_nodes, cfg)


@functools.partial(jax.jit, static_argnames=("n_labels",))
def dense_labels(labels: jnp.ndarray, n_labels: int):
    """Relabel arbitrary int community ids to dense [0, S).

    Returns (dense [N] int32, n_communities scalar int32). Capacity =
    ``n_labels`` (≥ true community count; N always works).
    """
    uniq = jnp.unique(labels, size=n_labels, fill_value=INT32_MAX)
    dense = jnp.searchsorted(uniq, labels).astype(jnp.int32)
    n_communities = jnp.sum(uniq != INT32_MAX).astype(jnp.int32)
    return dense, n_communities
