"""Graph substrate: generators, IO, padding, degrees, neighbor sampling."""
from repro.graph.generators import (
    planted_partition,
    powerlaw_graph,
    grid_mesh,
    batched_molecules,
    erdos_renyi,
)
from repro.graph.utils import (
    degrees,
    mode_degree,
    pad_edges,
    pad_to_multiple,
    EDGE_SENTINEL,
)
from repro.graph.sampling import NeighborSampler
