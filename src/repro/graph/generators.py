"""Synthetic graph generators (host-side, numpy).

The paper evaluates on SNAP graphs (Wiki-Talk, as-Skitter, ...). Those are
not available offline, so benchmarks use synthetic stand-ins with the same
structural regimes: planted-partition graphs (strong community structure,
the regime where SCoDA is meaningful) and preferential-attachment graphs
(heavy-tailed degrees, the regime that stresses the degree threshold).

All generators return ``edges`` as an int32 ``[E, 2]`` array of undirected
edges (each edge listed once, u != v) plus metadata.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "planted_partition",
    "powerlaw_graph",
    "erdos_renyi",
    "grid_mesh",
    "batched_molecules",
]


def _sample_pairs_gnp(rng: np.random.Generator, n_pairs: int, p: float) -> np.ndarray:
    """Indices of successes among ``n_pairs`` Bernoulli(p) trials.

    Uses geometric skipping so cost is O(#successes), not O(n_pairs).
    """
    if p <= 0.0 or n_pairs <= 0:
        return np.empty(0, dtype=np.int64)
    if p >= 1.0:
        return np.arange(n_pairs, dtype=np.int64)
    # Expected successes + slack.
    exp = int(n_pairs * p)
    cap = exp + 10 + int(4 * np.sqrt(exp + 1))
    out = []
    idx = -1
    remaining = cap
    while True:
        # Draw a batch of geometric skips.
        k = max(remaining, 16)
        skips = rng.geometric(p, size=k)
        pos = idx + np.cumsum(skips)
        take = pos[pos < n_pairs]
        out.append(take)
        if len(take) < len(pos):
            break
        idx = int(pos[-1])
        remaining = max(16, remaining - len(take))
    if out:
        return np.concatenate(out)
    return np.empty(0, dtype=np.int64)


def _pair_from_index(idx: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
    """Map linear index over the strict upper triangle of an n×n matrix to (i, j)."""
    # Row i starts at offset i*n - i*(i+1)/2 - ... solve via quadratic formula.
    idx = idx.astype(np.float64)
    b = 2 * n - 1
    i = np.floor((b - np.sqrt(b * b - 8 * idx)) / 2).astype(np.int64)
    row_start = i * n - (i * (i + 1)) // 2 - i  # start of row i in strict upper tri
    # Recompute exactly in integer domain to fix fp error at boundaries.
    idx = idx.astype(np.int64)
    while True:
        row_start = i * (2 * n - i - 1) // 2
        bad_hi = idx >= (i + 1) * (2 * n - i - 2) // 2
        bad_lo = idx < row_start
        if not (bad_hi.any() or bad_lo.any()):
            break
        i = i + bad_hi.astype(np.int64) - bad_lo.astype(np.int64)
    j = idx - row_start + i + 1
    return i, j


def erdos_renyi(n: int, p: float, seed: int = 0) -> np.ndarray:
    """G(n, p) with edges sampled by geometric skipping."""
    rng = np.random.default_rng(seed)
    n_pairs = n * (n - 1) // 2
    idx = _sample_pairs_gnp(rng, n_pairs, p)
    i, j = _pair_from_index(idx, n)
    return np.stack([i, j], axis=1).astype(np.int32)


def planted_partition(
    n: int,
    n_communities: int,
    p_in: float,
    p_out: float,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Planted-partition (SBM) graph. Returns (edges [E,2] int32, labels [n] int32)."""
    rng = np.random.default_rng(seed)
    sizes = np.full(n_communities, n // n_communities, dtype=np.int64)
    sizes[: n % n_communities] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)])
    labels = np.repeat(np.arange(n_communities), sizes).astype(np.int32)

    chunks = []
    # Intra-community edges.
    for c in range(n_communities):
        s, nc = starts[c], int(sizes[c])
        n_pairs = nc * (nc - 1) // 2
        idx = _sample_pairs_gnp(rng, n_pairs, p_in)
        if len(idx):
            i, j = _pair_from_index(idx, nc)
            chunks.append(np.stack([i + s, j + s], axis=1))
    # Inter-community edges: sample per block pair (c1 < c2), a bipartite grid.
    for c1 in range(n_communities):
        for c2 in range(c1 + 1, n_communities):
            n1, n2 = int(sizes[c1]), int(sizes[c2])
            idx = _sample_pairs_gnp(rng, n1 * n2, p_out)
            if len(idx):
                i = idx // n2 + starts[c1]
                j = idx % n2 + starts[c2]
                chunks.append(np.stack([i, j], axis=1))
    if chunks:
        edges = np.concatenate(chunks).astype(np.int32)
    else:
        edges = np.empty((0, 2), dtype=np.int32)
    rng.shuffle(edges)  # streaming order matters for SCoDA; randomize like the paper
    return edges, labels


def powerlaw_graph(n: int, m: int = 4, seed: int = 0) -> np.ndarray:
    """Barabási–Albert preferential attachment; heavy-tailed degrees.

    Vectorized: new node t attaches to m targets sampled from the
    repeated-endpoints list (classic O(E) implementation).
    """
    rng = np.random.default_rng(seed)
    m = max(1, min(m, n - 1))
    # Seed clique on m+1 nodes.
    src0, dst0 = np.triu_indices(m + 1, k=1)
    repeated = list(np.concatenate([src0, dst0]))
    edges = [np.stack([src0, dst0], axis=1)]
    rep = np.array(repeated, dtype=np.int64)
    for t in range(m + 1, n):
        targets = rng.choice(rep, size=2 * m)
        targets = np.unique(targets)[:m]
        e = np.stack([np.full(len(targets), t, dtype=np.int64), targets], axis=1)
        edges.append(e)
        rep = np.concatenate([rep, targets, np.full(len(targets), t, dtype=np.int64)])
    out = np.concatenate(edges).astype(np.int32)
    rng.shuffle(out)
    return out


def grid_mesh(nx: int, ny: int) -> np.ndarray:
    """4-connected grid mesh (MeshGraphNet-style domain). Returns edges [E,2]."""
    idx = np.arange(nx * ny).reshape(nx, ny)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    return np.concatenate([right, down]).astype(np.int32)


def batched_molecules(
    batch: int, n_nodes: int, n_edges: int, d_feat: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """A batch of random small graphs packed into one disjoint union.

    Returns (edges [batch*n_edges, 2], feats [batch*n_nodes, d_feat],
    graph_ids [batch*n_nodes]).
    """
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=(batch, n_edges))
    dst = (src + 1 + rng.integers(0, n_nodes - 1, size=(batch, n_edges))) % n_nodes
    offset = (np.arange(batch) * n_nodes)[:, None]
    edges = np.stack([(src + offset).ravel(), (dst + offset).ravel()], axis=1)
    feats = rng.standard_normal((batch * n_nodes, d_feat)).astype(np.float32)
    graph_ids = np.repeat(np.arange(batch), n_nodes).astype(np.int32)
    return edges.astype(np.int32), feats, graph_ids
