"""Graph padding / degree utilities (jit-friendly, static shapes)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Padded edge slots point both endpoints at the trash node (index n_nodes).
EDGE_SENTINEL = -1


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_edges(edges: np.ndarray, capacity: int, n_nodes: int) -> np.ndarray:
    """Pad an [E,2] edge list to [capacity,2]; padded slots point at the trash
    node ``n_nodes`` (arrays indexed by nodes are sized n_nodes+1)."""
    e = len(edges)
    if e > capacity:
        raise ValueError(f"edge count {e} exceeds capacity {capacity}")
    out = np.full((capacity, 2), n_nodes, dtype=np.int32)
    out[:e] = edges
    return out


def degrees(edges: jnp.ndarray, n_nodes: int) -> jnp.ndarray:
    """Node degrees from an undirected padded edge list ([E,2], trash=n_nodes)."""
    deg = jnp.zeros(n_nodes + 1, dtype=jnp.int32)
    deg = deg.at[edges[:, 0]].add(1)
    deg = deg.at[edges[:, 1]].add(1)
    return deg[:n_nodes]


def mode_degree(edges: np.ndarray, n_nodes: int) -> int:
    """The paper's default degree threshold: the most common nonzero degree."""
    deg = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(deg, edges[:, 0], 1)
    np.add.at(deg, edges[:, 1], 1)
    deg = deg[:n_nodes]
    deg = deg[deg > 0]
    if len(deg) == 0:
        return 1
    counts = np.bincount(deg)
    counts[0] = 0
    return int(np.argmax(counts))


def to_csr(edges: np.ndarray, n_nodes: int) -> tuple[np.ndarray, np.ndarray]:
    """Symmetrized CSR (indptr, indices) from an undirected edge list."""
    src = np.concatenate([edges[:, 0], edges[:, 1]])
    dst = np.concatenate([edges[:, 1], edges[:, 0]])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, dst.astype(np.int32)
