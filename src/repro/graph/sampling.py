"""Host-side CSR fanout neighbor sampler (GraphSAGE-style).

``minibatch_lg`` (232k nodes / 114M edges, batch_nodes=1024, fanout 15-10)
requires a *real* neighbor sampler: this one samples k-hop neighborhoods
from CSR with per-hop fanouts and emits a padded, statically-shaped
subgraph ready for a jitted train step.

The sampler runs on host (numpy) — it is the data-pipeline stage; the
padded subgraph tensors are what the TPU step consumes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SampledSubgraph:
    """Padded, statically-shaped subgraph.

    nodes:    [max_nodes] int32 global node ids (padded with -1)
    edges:    [max_edges, 2] int32 *local* indices into ``nodes``
              (padded slots point at ``max_nodes`` trash slot)
    n_nodes:  int, valid node count
    n_edges:  int, valid edge count
    seed_mask:[max_nodes] bool, True for the seed (loss) nodes
    """

    nodes: np.ndarray
    edges: np.ndarray
    n_nodes: int
    n_edges: int
    seed_mask: np.ndarray


class NeighborSampler:
    def __init__(self, indptr: np.ndarray, indices: np.ndarray, fanouts: tuple[int, ...]):
        self.indptr = indptr
        self.indices = indices
        self.fanouts = tuple(fanouts)

    def max_capacity(self, batch_nodes: int) -> tuple[int, int]:
        """Static (max_nodes, max_edges) for a given seed-batch size."""
        n, e = batch_nodes, 0
        frontier = batch_nodes
        for f in self.fanouts:
            frontier = frontier * f
            n += frontier
            e += frontier
        return n, e

    def sample(self, seeds: np.ndarray, rng: np.random.Generator) -> SampledSubgraph:
        seeds = np.unique(seeds)  # duplicate seeds would corrupt the relabel
        batch = len(seeds)
        max_nodes, max_edges = self.max_capacity(batch)
        src_chunks, dst_chunks = [], []
        frontier = seeds.astype(np.int64)
        for f in self.fanouts:
            starts = self.indptr[frontier]
            degs = self.indptr[frontier + 1] - starts
            # Sample ``f`` neighbors with replacement per frontier node (skip deg-0).
            pick = rng.integers(0, np.maximum(degs, 1)[:, None], size=(len(frontier), f))
            neigh = self.indices[starts[:, None] + pick]
            valid = np.broadcast_to(degs[:, None] > 0, (len(frontier), f))
            src = np.repeat(frontier, f).reshape(len(frontier), f)
            src_chunks.append(src[valid])
            dst_chunks.append(neigh[valid])
            frontier = np.unique(neigh[valid])
        src = np.concatenate(src_chunks)
        dst = np.concatenate(dst_chunks)
        # Global → local relabel; seeds first so the loss mask is trivial.
        uniq, inverse = np.unique(np.concatenate([seeds, src, dst]), return_inverse=True)
        # Ensure seeds occupy the first slots: build a permutation.
        seed_pos = np.searchsorted(uniq, seeds)
        perm = np.full(len(uniq), -1, dtype=np.int64)
        perm[seed_pos] = np.arange(len(seeds))
        rest = np.setdiff1d(np.arange(len(uniq)), seed_pos, assume_unique=False)
        perm[rest] = np.arange(len(seeds), len(uniq))
        local = perm[inverse]
        lsrc = local[len(seeds) : len(seeds) + len(src)]
        ldst = local[len(seeds) + len(src) :]

        nodes = np.full(max_nodes, -1, dtype=np.int32)
        order = np.empty(len(uniq), dtype=np.int64)
        order[perm] = np.arange(len(uniq))
        nodes[: len(uniq)] = uniq[order]
        edges = np.full((max_edges, 2), max_nodes, dtype=np.int32)
        edges[: len(src), 0] = lsrc
        edges[: len(src), 1] = ldst
        seed_mask = np.zeros(max_nodes, dtype=bool)
        seed_mask[: len(seeds)] = True
        return SampledSubgraph(
            nodes=nodes,
            edges=edges,
            n_nodes=len(uniq),
            n_edges=len(src),
            seed_mask=seed_mask,
        )
