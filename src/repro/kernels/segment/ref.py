"""Pure-jnp oracle for the segment-sum matmul kernel: plain segment_sum.

Out-of-range segment ids (e.g. the edge-padding trash id == n_segments)
are dropped, matching ``jax.ops.segment_sum`` semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(data: jnp.ndarray, seg_ids: jnp.ndarray, n_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(data, seg_ids, num_segments=n_segments)
