"""Pure-jnp oracle for the segment-sum matmul kernel: plain segment_sum.

Out-of-range segment ids (e.g. the edge-padding trash id == n_segments)
are dropped, matching ``jax.ops.segment_sum`` semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(
    data: jnp.ndarray,
    seg_ids: jnp.ndarray,
    n_segments: int,
    indices_are_sorted: bool = False,
) -> jnp.ndarray:
    """``indices_are_sorted=True`` promises sorted ``seg_ids`` — XLA lowers
    the scatter without the dedup/ordering guards (the fast path the
    FA2 attraction and grid monopole stats ride)."""
    return jax.ops.segment_sum(
        data, seg_ids, num_segments=n_segments,
        indices_are_sorted=indices_are_sorted,
    )
