"""Jit'd public wrapper: segment-sum via Pallas on TPU, XLA scatter on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment.ref import segment_sum_ref
from repro.kernels.segment.seg_matmul import segment_sum_pallas


def segment_sum(
    data: jnp.ndarray,
    seg_ids: jnp.ndarray,
    n_segments: int,
    backend: str = "auto",
    indices_are_sorted: bool = False,
) -> jnp.ndarray:
    """``indices_are_sorted`` promises sorted ``seg_ids`` (same result,
    faster scatter lowering on the ref path; the one-hot-matmul Pallas
    kernel is insensitive to input order and ignores the hint)."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return segment_sum_ref(
            data, seg_ids, n_segments, indices_are_sorted=indices_are_sorted
        )
    interpret = backend == "interpret" or jax.default_backend() != "tpu"
    return segment_sum_pallas(data, seg_ids, n_segments, interpret=interpret)
