"""Pallas TPU kernel: segment-sum as one-hot matmul (message passing / GNN
scatter and supergraph aggregation share this primitive).

GPU graph frameworks scatter edge messages with atomicAdd; the TPU
adaptation reformulates a block of E edge messages aggregating into an
N-node tile as

    out[t] += onehot(seg)ᵀ @ msgs       ([TN, B]·[B, D] matmul → MXU)

Grid = (node_tiles, edge_blocks): node axis parallel, edge axis revisits
and accumulates the same output tile. Messages stream once per node tile;
the one-hot never leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams


def _kernel(seg_ref, data_ref, o_ref, *, tn: int, blk: int):
    t = pl.program_id(0)
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    seg = seg_ref[0, :]  # [blk]
    local = seg - t * tn  # position inside this node tile (or out of range)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (tn, blk), 0)
    onehot = jnp.where(row_ids == local[None, :], 1.0, 0.0)  # [tn, blk]
    # Accumulate in f32 regardless of input dtype (production practice);
    # the wrapper casts back once at the end.
    o_ref[...] += jnp.dot(
        onehot, data_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("n_segments", "tn", "blk", "interpret"))
def segment_sum_pallas(
    data: jnp.ndarray,  # [E, D]
    seg_ids: jnp.ndarray,  # [E] int32 (out of [0, n_segments) = dropped)
    n_segments: int,
    tn: int = 256,
    blk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    e, d = data.shape
    e_pad = ((e + blk - 1) // blk) * blk
    n_pad = ((n_segments + tn - 1) // tn) * tn
    data_p = jnp.pad(data, ((0, e_pad - e), (0, 0)))
    seg_p = jnp.pad(seg_ids, (0, e_pad - e), constant_values=-1)[None, :]
    grid = (n_pad // tn, e_pad // blk)
    out = pl.pallas_call(
        functools.partial(_kernel, tn=tn, blk=blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk), lambda t, b: (0, b)),
            pl.BlockSpec((blk, d), lambda t, b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((tn, d), lambda t, b: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(seg_p, data_p)
    return out[:n_segments].astype(data.dtype)
