"""Jit'd public wrapper for the CMS update kernel: hashes keys (same
multiply-shift family as core/cms.py) and dispatches to the Pallas kernel
on TPU or the scatter-add oracle on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cms as cms_lib
from repro.kernels.cms.cms_update import cms_update_pallas
from repro.kernels.cms.ref import cms_update_ref


def update(
    sketch: jnp.ndarray,
    keys: jnp.ndarray,
    weights: jnp.ndarray,
    cfg: cms_lib.CMSConfig,
    backend: str = "auto",
) -> jnp.ndarray:
    a, b = cms_lib.hash_params(cfg)
    h = cms_lib.hash_keys(keys, a, b, cfg.cols)
    h = jnp.where(keys[None, :] >= 0, h, -1)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return cms_update_ref(sketch, h, weights)
    interpret = backend == "interpret" or jax.default_backend() != "tpu"
    return cms_update_pallas(
        sketch, h, weights.astype(jnp.float32), cfg.cols, interpret=interpret
    )
