"""Pallas TPU kernel: count–min sketch update as one-hot × matmul.

GPU BigGraphVis updates the sketch with atomicAdd — random-access writes.
The TPU adaptation (DESIGN.md §2) converts a block of B hashed keys into a
one-hot [B, C] matrix per row and accumulates

    sketch[r] += wᵀ @ onehot(h[r])        (a [1,B]·[B,C] matmul → MXU)

The sketch ([R, C], C ≤ ~16k ⇒ ≤ 256 KB f32) stays resident in VMEM as a
revisited output block across the key-block grid; keys stream through VMEM
in blocks of ``blk``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams


def _kernel(h_ref, w_ref, o_ref, *, rows: int, cols: int, blk: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[0, :]  # [blk]
    wv = jnp.where(h_ref[0, :] >= 0, w, 0.0)  # padding mask (h<0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (blk, cols), 1)
    acc = o_ref[...]
    for r in range(rows):  # rows ≤ 4: unrolled
        h = h_ref[r, :]  # [blk]
        onehot = jnp.where(col_ids == h[:, None], 1.0, 0.0)  # [blk, cols]
        contrib = jnp.dot(
            wv[None, :], onehot, preferred_element_type=jnp.float32
        )  # [1, cols] on the MXU
        acc = acc.at[r, :].add(contrib[0])
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("cols", "blk", "interpret"))
def cms_update_pallas(
    sketch: jnp.ndarray,  # [rows, cols] f32
    h: jnp.ndarray,  # [rows, n] int32 bucket ids (negative = padding)
    w: jnp.ndarray,  # [n] f32
    cols: int,
    # blk=256 keeps the [blk, cols] one-hot under VMEM for cols ≤ 12k
    # (blk=1024 × cols=4096 already costs 16.9 MiB — caught by
    # benchmarks/kernels_bench.py's working-set accounting).
    blk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    rows, n = h.shape
    assert sketch.shape == (rows, cols)
    n_pad = ((n + blk - 1) // blk) * blk
    h_p = jnp.pad(h, ((0, 0), (0, n_pad - n)), constant_values=-1)
    w_p = jnp.pad(w, (0, n_pad - n))[None, :]  # [1, n_pad]
    grid = (n_pad // blk,)
    delta = pl.pallas_call(
        functools.partial(_kernel, rows=rows, cols=cols, blk=blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, blk), lambda i: (0, i)),
            pl.BlockSpec((1, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(h_p, w_p)
    return sketch + delta
