"""Pure-jnp oracle for the count–min sketch update kernel.

Semantics: given pre-hashed bucket indices ``h`` [rows, n] and weights
``w`` [n], add w[e] at sketch[r, h[r, e]] for every row r. Negative buckets
(padding) are skipped.
"""
from __future__ import annotations

import jax.numpy as jnp


def cms_update_ref(sketch: jnp.ndarray, h: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    rows = jnp.arange(sketch.shape[0], dtype=jnp.int32)[:, None]
    wv = jnp.where(h[0] >= 0, w, 0.0).astype(sketch.dtype)
    hh = jnp.maximum(h, 0)
    return sketch.at[rows, hh].add(wv[None, :])
