"""XLA reference path for the uniform-grid repulsion family.

The dense formulation (``core/forceatlas2._grid_repulsion``, kept as the
``grid_dense`` benchmark baseline) materializes a ``[n, G², 2]`` far-field
tensor every iteration — ≈100 GB at the paper's 3M-node scale with G=64 —
plus an ``[n, 2W+1]`` gathered near-field block. This module computes the
same forces from cache-sized pieces:

* ``far_field_ref`` — a ``lax.scan`` over node chunks: each chunk of ``nb``
  nodes interacts with every cell monopole as a dense ``[nb, G²]`` block,
  so the live set is O(nb·G²) — independent of n. The own-cell monopole is
  masked inside the pair block (fused), where the dense baseline adds it
  and then subtracts it again.
* ``near_field_ref`` — the exact same-cell band over the cell-sorted order
  expressed as 2W+1 shifted passes (``jnp.roll`` + mask), replacing the
  ``[n, 2W+1]`` gather: pure vector ops, O(n) live memory.

Binning helpers (``bin_nodes`` / ``bin_and_sort``) are shared by every
backend; the Pallas counterparts of the two field kernels live in
``tiled.py``, dispatch in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# d² clamp of the monopole/near force magnitude kr·mi·mj/d² (matches
# core/forceatlas2._pair_force — the grid family works on squared
# distances, unlike the exact kernels' d·(d − radii) denominator).
EPS2 = 1e-4


def bin_nodes(pos: jnp.ndarray, grid_size: int) -> jnp.ndarray:
    """Flat G×G cell id per node ([n] int32) from the positions' bbox."""
    g = grid_size
    pos = pos.astype(jnp.float32)
    lo = jnp.min(pos, axis=0)
    hi = jnp.max(pos, axis=0)
    extent = jnp.maximum(hi - lo, 1e-6)
    cell2d = jnp.clip(((pos - lo) / extent * g).astype(jnp.int32), 0, g - 1)
    return cell2d[:, 0] * g + cell2d[:, 1]


def bin_and_sort(pos: jnp.ndarray, grid_size: int):
    """(cell ids [n] int32, cell-sorted order [n] int32) for a layout.

    The pair is the grid state the FA2 scan carries and rebuilds every
    ``grid_rebuild`` iterations (core/forceatlas2.layout): the argsort is
    the amortizable cost, the per-iteration monopole stats are not.
    """
    cell = bin_nodes(pos, grid_size)
    return cell, jnp.argsort(cell).astype(jnp.int32)


def _pad_chunks(x, nb, fill=0.0):
    n = x.shape[0]
    n_pad = ((n + nb - 1) // nb) * nb
    pad = [(0, n_pad - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill).reshape(
        (n_pad // nb, nb) + x.shape[1:]
    )


@functools.partial(jax.jit, static_argnames=("kr", "nb"))
def far_field_ref(
    pos: jnp.ndarray,  # [n, 2] f32 (any order)
    mass: jnp.ndarray,  # [n] f32 (padding must carry mass 0)
    cell: jnp.ndarray,  # [n] int32 cell id per node
    ccent: jnp.ndarray,  # [C, 2] f32 cell centroids
    cmass: jnp.ndarray,  # [C] f32 cell masses (empty cell = 0 = force-dead)
    kr: float,
    nb: int = 1024,
) -> jnp.ndarray:
    """Monopole far field, own cell excluded → [n, 2]. O(nb·C) live set."""
    n = pos.shape[0]
    cx = ccent[:, 0][None, :]  # [1, C]
    cy = ccent[:, 1][None, :]
    cm = cmass[None, :]
    cells = jnp.arange(ccent.shape[0], dtype=jnp.int32)[None, :]

    def body(_, blk):
        p, m, ci = blk  # [nb, 2], [nb], [nb]
        dx = p[:, 0:1] - cx  # [nb, C]
        dy = p[:, 1:2] - cy
        d2 = dx * dx + dy * dy
        mag = kr * m[:, None] * cm / jnp.maximum(d2, EPS2)
        mag = jnp.where(ci[:, None] == cells, 0.0, mag)  # fused own-cell mask
        return None, jnp.stack(
            [jnp.sum(mag * dx, axis=1), jnp.sum(mag * dy, axis=1)], axis=1
        )

    _, out = jax.lax.scan(
        body,
        None,
        (
            _pad_chunks(pos, nb),
            _pad_chunks(mass, nb),
            _pad_chunks(cell, nb, fill=-1),
        ),
    )
    return out.reshape(-1, 2)[:n]


@functools.partial(jax.jit, static_argnames=("kr", "window"))
def near_field_ref(
    pos_s: jnp.ndarray,  # [n, 2] f32, cell-sorted order
    mass_s: jnp.ndarray,  # [n] f32, cell-sorted
    cell_s: jnp.ndarray,  # [n] int32, sorted (same-cell runs contiguous)
    kr: float,
    window: int,
) -> jnp.ndarray:
    """Exact same-cell pairwise forces over a ±window band of the sorted
    order → [n, 2] (sorted order). Exact for cells with ≤ window members."""
    n = pos_s.shape[0]
    idx = jnp.arange(n)
    x, y = pos_s[:, 0], pos_s[:, 1]

    def body(acc, k):
        # Neighbor j = i + k via a shifted view: rolled[i] = arr[(i+k) % n];
        # the in-range mask discards the wrapped entries.
        xs = jnp.roll(x, -k)
        ys = jnp.roll(y, -k)
        ms = jnp.roll(mass_s, -k)
        cs = jnp.roll(cell_s, -k)
        j = idx + k
        ok = (j >= 0) & (j < n) & (k != 0) & (cs == cell_s)
        dx = x - xs
        dy = y - ys
        d2 = dx * dx + dy * dy
        mag = jnp.where(ok, kr * mass_s * ms / jnp.maximum(d2, EPS2), 0.0)
        return (acc[0] + mag * dx, acc[1] + mag * dy), None

    init = (jnp.zeros_like(x), jnp.zeros_like(y))
    (fx, fy), _ = jax.lax.scan(body, init, jnp.arange(-window, window + 1))
    return jnp.stack([fx, fy], axis=1)


@functools.partial(jax.jit, static_argnames=("kr", "window", "nl"))
def near_field_rows(
    pos_s: jnp.ndarray,  # [n, 2] f32, cell-sorted order (full arrays)
    mass_s: jnp.ndarray,  # [n] f32, cell-sorted
    cell_s: jnp.ndarray,  # [n] int32, sorted
    kr: float,
    window: int,
    i0,
    nl: int,
) -> jnp.ndarray:
    """Rows [i0, i0+nl) of ``near_field_ref`` → [nl, 2] (sorted order).

    Same per-row math and k-scan accumulation order; neighbor values come
    from a ±window halo around the row block (window-padded arrays + one
    dynamic slice per shift) instead of rolling the full arrays, so the
    sharded FA2 layout needs no cross-device sum for the near field —
    ``psum``-free by construction. Out-of-range halo slots carry cell id -1
    and are discarded by the same in-range mask as the full version, which
    also zeroes their (finite) force terms — bitwise identical to slicing
    ``near_field_ref``'s rows. ``i0`` may be traced.
    """
    n = pos_s.shape[0]
    w = window
    xp = jnp.pad(pos_s[:, 0], (w, w))
    yp = jnp.pad(pos_s[:, 1], (w, w))
    mp = jnp.pad(mass_s, (w, w))
    cp = jnp.pad(cell_s, (w, w), constant_values=-1)
    x = jax.lax.dynamic_slice_in_dim(xp, i0 + w, nl)
    y = jax.lax.dynamic_slice_in_dim(yp, i0 + w, nl)
    m = jax.lax.dynamic_slice_in_dim(mp, i0 + w, nl)
    c = jax.lax.dynamic_slice_in_dim(cp, i0 + w, nl)
    gidx = i0 + jnp.arange(nl)

    def body(acc, k):
        xs = jax.lax.dynamic_slice_in_dim(xp, i0 + w + k, nl)
        ys = jax.lax.dynamic_slice_in_dim(yp, i0 + w + k, nl)
        ms = jax.lax.dynamic_slice_in_dim(mp, i0 + w + k, nl)
        cs = jax.lax.dynamic_slice_in_dim(cp, i0 + w + k, nl)
        j = gidx + k
        ok = (j >= 0) & (j < n) & (k != 0) & (cs == c)
        dx = x - xs
        dy = y - ys
        d2 = dx * dx + dy * dy
        mag = jnp.where(ok, kr * m * ms / jnp.maximum(d2, EPS2), 0.0)
        return (acc[0] + mag * dx, acc[1] + mag * dy), None

    init = (jnp.zeros_like(x), jnp.zeros_like(y))
    (fx, fy), _ = jax.lax.scan(body, init, jnp.arange(-w, w + 1))
    return jnp.stack([fx, fy], axis=1)
