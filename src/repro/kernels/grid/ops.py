"""Jit'd public wrappers for the uniform-grid repulsion family: Pallas on
TPU, the chunked/shifted XLA path elsewhere (auto/ref/pallas/interpret
dispatch mirrors kernels/repulsion, kernels/merge and kernels/raster).

``grid_repulsion`` is the whole stage — bin → sort → monopole stats →
far field + banded near field → unsort — with ``cell``/``order`` optionally
precomputed so the FA2 scan can rebuild them every ``grid_rebuild``
iterations instead of every step (core/forceatlas2.layout). The monopole
stats ride the sorted order through a ``kernels/segment`` segment-sum
(``indices_are_sorted`` fast path). All math runs in float32 regardless of
the caller's position dtype; the result is cast back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.grid.ref import (
    bin_and_sort,
    bin_nodes,  # noqa: F401  (re-exported: binning shared by every backend)
    far_field_ref,
    near_field_ref,
    near_field_rows,  # noqa: F401  (re-exported: sharded-layout halo path)
)
from repro.kernels.grid.tiled import far_field_pallas, near_field_pallas
from repro.kernels.segment import ops as segment_ops


def _resolve(backend: str) -> tuple[str, bool]:
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    interpret = backend == "interpret" or jax.default_backend() != "tpu"
    return backend, interpret


def cell_stats(
    pos_s: jnp.ndarray,
    mass_s: jnp.ndarray,
    cell_s: jnp.ndarray,
    n_cells: int,
    backend: str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(centroids [C, 2], masses [C]) per cell from cell-sorted nodes.

    One fused sorted segment-sum over [Σm·x, Σm·y, Σm]; empty cells get
    mass 0 (force-dead) and centroid 0.
    """
    backend, _ = _resolve(backend)
    data = jnp.concatenate(
        [pos_s * mass_s[:, None], mass_s[:, None]], axis=1)
    sums = segment_ops.segment_sum(
        data, cell_s, n_cells, backend=backend, indices_are_sorted=True)
    cmass = sums[:, 2]
    ccent = sums[:, :2] / jnp.maximum(cmass, 1e-9)[:, None]
    return ccent, cmass


def far_field(pos, mass, cell, ccent, cmass, kr: float, backend: str = "auto"):
    """Monopole far field (own cell excluded) → [n, 2]."""
    backend, interpret = _resolve(backend)
    if backend == "ref":
        return far_field_ref(pos, mass, cell, ccent, cmass, kr)
    return far_field_pallas(pos, mass, cell, ccent, cmass, kr,
                            interpret=interpret)


def near_field_sorted(pos_s, mass_s, cell_s, kr: float, window: int,
                      backend: str = "auto"):
    """Banded same-cell near field over the sorted order → [n, 2] (sorted)."""
    backend, interpret = _resolve(backend)
    if backend == "ref":
        return near_field_ref(pos_s, mass_s, cell_s, kr, window)
    return near_field_pallas(pos_s, mass_s, cell_s, kr, window,
                             interpret=interpret)


def grid_repulsion(
    pos: jnp.ndarray,  # [n, 2]
    mass: jnp.ndarray,  # [n] (padding must carry mass 0)
    kr: float,
    grid_size: int,
    window: int,
    cell: jnp.ndarray | None = None,
    order: jnp.ndarray | None = None,
    backend: str = "auto",
) -> jnp.ndarray:
    """Uniform-grid FA2 repulsion forces, pos [n,2] → [n,2].

    ``cell``/``order`` (from ``bin_and_sort``) may be stale by up to
    ``grid_rebuild`` iterations; monopole stats are always recomputed from
    the current positions, so staleness only blurs the cell *partition*,
    never the masses.
    """
    pos32 = pos.astype(jnp.float32)
    mass32 = mass.astype(jnp.float32)
    if cell is None or order is None:
        cell, order = bin_and_sort(pos32, grid_size)
    pos_s = pos32[order]
    mass_s = mass32[order]
    cell_s = cell[order]
    ccent, cmass = cell_stats(pos_s, mass_s, cell_s, grid_size * grid_size,
                              backend=backend)
    # Both fields run in sorted order → one unsorting scatter at the end.
    force_s = far_field(pos_s, mass_s, cell_s, ccent, cmass, kr,
                        backend=backend)
    force_s = force_s + near_field_sorted(pos_s, mass_s, cell_s, kr, window,
                                          backend=backend)
    out = jnp.zeros_like(force_s).at[order].set(force_s)
    return out.astype(pos.dtype)
