"""Pallas TPU kernels: tiled uniform-grid repulsion (far + near field).

``far_field_pallas`` — node-tile × cell-tile monopole accumulation,
FlashAttention-style: grid = (n/TI, C/TC) with the cell axis revisiting
and accumulating the same [TI, 2] output block
(``dimension_semantics=("parallel", "arbitrary")``), so no [n, C] pair
block ever exists outside VMEM — the dense baseline's [n, G², 2] HBM
tensor becomes a [TI, TC] register-resident tile. The own-cell monopole
is masked inside the pair block (fused subtraction — the dense baseline
adds it and subtracts it again afterwards).

``near_field_pallas`` — exact same-cell interaction over a ±W band of the
cell-sorted order. The band-skip idiom from ``kernels/merge`` /
``kernels/raster`` becomes *static* block overlap here: with W ≤ TI a
node tile's band only ever touches tiles (i−1, i, i+1), so the same
packed (x, y, mass, cell) array is passed three times with shifted index
maps and the kernel evaluates one masked [TI, 3·TI] pair block per tile,
entirely in VMEM. Working set per step ≈ 4·TI·4 B inputs + TI·3TI pair
blocks ≈ 2.5 MB at TI=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams
from repro.kernels.grid.ref import EPS2


def _pad_to(n: int, t: int) -> int:
    return ((n + t - 1) // t) * t


def _far_kernel(pos_ref, mass_ref, cell_ref, cent_ref, cmass_ref, out_ref,
                *, kr: float, ti: int, tc: int):
    j = pl.program_id(1)

    xi = pos_ref[:, 0:1]  # [TI, 1]
    yi = pos_ref[:, 1:2]
    cx = cent_ref[:, 0:1].T  # [1, TC]
    cy = cent_ref[:, 1:2].T
    dx = xi - cx  # [TI, TC]
    dy = yi - cy
    d2 = dx * dx + dy * dy

    mi = mass_ref[:, 0:1]
    mj = cmass_ref[:, 0:1].T
    # Own-cell monopole masked in place (empty/padded cells die via mj=0).
    gj = j * tc + jax.lax.broadcasted_iota(jnp.int32, (ti, tc), 1)
    own = cell_ref[:, 0:1] == gj
    mag = jnp.where(own, 0.0, kr * mi * mj / jnp.maximum(d2, EPS2))

    fx = jnp.sum(mag * dx, axis=1, keepdims=True)  # [TI, 1]
    fy = jnp.sum(mag * dy, axis=1, keepdims=True)
    partial = jnp.concatenate([fx, fy], axis=1)  # [TI, 2]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("kr", "ti", "tc", "interpret"))
def far_field_pallas(
    pos: jnp.ndarray,  # [n, 2] f32 (any order)
    mass: jnp.ndarray,  # [n] f32
    cell: jnp.ndarray,  # [n] int32 cell id per node
    ccent: jnp.ndarray,  # [C, 2] f32 cell centroids
    cmass: jnp.ndarray,  # [C] f32 cell masses
    kr: float,
    ti: int = 256,
    tc: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Monopole far field, own cell excluded → [n, 2]. Padded node slots
    carry mass 0 / cell −1, padded cell slots mass 0 — all force-neutral."""
    n = pos.shape[0]
    c = ccent.shape[0]
    n_pad = _pad_to(n, ti)
    c_pad = _pad_to(c, tc)
    npad = (0, n_pad - n)
    cpad = (0, c_pad - c)
    pos_p = jnp.pad(pos, (npad, (0, 0)))
    mass_p = jnp.pad(mass, npad)[:, None]
    cell_p = jnp.pad(cell, npad, constant_values=-1)[:, None]
    cent_p = jnp.pad(ccent, (cpad, (0, 0)))
    cmass_p = jnp.pad(cmass, cpad)[:, None]
    grid = (n_pad // ti, c_pad // tc)
    out = pl.pallas_call(
        functools.partial(_far_kernel, kr=kr, ti=ti, tc=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((ti, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((ti, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tc, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((tc, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ti, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 2), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(pos_p, mass_p, cell_p, cent_p, cmass_p)
    return out[:n]


def _near_kernel(prev_ref, cur_ref, next_ref, out_ref,
                 *, kr: float, ti: int, window: int, nt: int):
    i = pl.program_id(0)

    xi = cur_ref[:, 0:1]  # [TI, 1]
    yi = cur_ref[:, 1:2]
    mi = cur_ref[:, 2:3]
    ci = cur_ref[:, 3:4]
    # Row of the three neighbor tiles along lanes: [1, 3·TI].
    xj = jnp.concatenate(
        [prev_ref[:, 0:1].T, cur_ref[:, 0:1].T, next_ref[:, 0:1].T], axis=1)
    yj = jnp.concatenate(
        [prev_ref[:, 1:2].T, cur_ref[:, 1:2].T, next_ref[:, 1:2].T], axis=1)
    mj = jnp.concatenate(
        [prev_ref[:, 2:3].T, cur_ref[:, 2:3].T, next_ref[:, 2:3].T], axis=1)
    cj = jnp.concatenate(
        [prev_ref[:, 3:4].T, cur_ref[:, 3:4].T, next_ref[:, 3:4].T], axis=1)

    dx = xi - xj  # [TI, 3TI]
    dy = yi - yj
    d2 = dx * dx + dy * dy

    # Global sorted indices: rows live in tile i, columns span tiles
    # (i−1, i, i+1). Edge tiles load a clamped duplicate block; the seg
    # masks kill it (there is no tile −1 / nt).
    cols = jax.lax.broadcasted_iota(jnp.int32, (ti, 3 * ti), 1)
    gi = i * ti + jax.lax.broadcasted_iota(jnp.int32, (ti, 3 * ti), 0)
    gj = (i - 1) * ti + cols
    seg = cols // ti
    edge_ok = jnp.logical_not(
        ((i == 0) & (seg == 0)) | ((i == nt - 1) & (seg == 2))
    )
    band = (gj >= gi - window) & (gj <= gi + window) & (gj != gi)
    ok = edge_ok & band & (cj == ci) & (cj >= 0)  # cell −1 = padding
    mag = jnp.where(ok, kr * mi * mj / jnp.maximum(d2, EPS2), 0.0)

    out_ref[...] = jnp.concatenate(
        [jnp.sum(mag * dx, axis=1, keepdims=True),
         jnp.sum(mag * dy, axis=1, keepdims=True)], axis=1)


@functools.partial(jax.jit, static_argnames=("kr", "window", "ti", "interpret"))
def near_field_pallas(
    pos_s: jnp.ndarray,  # [n, 2] f32, cell-sorted order
    mass_s: jnp.ndarray,  # [n] f32, cell-sorted
    cell_s: jnp.ndarray,  # [n] int32, sorted
    kr: float,
    window: int,
    ti: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Banded same-cell near field over the sorted order → [n, 2] (sorted).

    Same band semantics as ``ref.near_field_ref``. The tile size is raised
    to cover the window when needed (the 3-tile overlap covers ±W only
    for W ≤ TI).
    """
    n = pos_s.shape[0]
    ti = max(ti, ((window + 7) // 8) * 8)
    n_pad = _pad_to(n, ti)
    nt = n_pad // ti
    npad = (0, n_pad - n)
    # Packed (x, y, mass, cell): cell ids are exact in f32 up to 2²⁴ —
    # far beyond any practical G². Padding: mass 0, cell −1.
    packed = jnp.concatenate(
        [
            jnp.pad(pos_s.astype(jnp.float32), (npad, (0, 0))),
            jnp.pad(mass_s.astype(jnp.float32), npad)[:, None],
            jnp.pad(cell_s.astype(jnp.float32), npad, constant_values=-1.0)[:, None],
        ],
        axis=1,
    )
    out = pl.pallas_call(
        functools.partial(_near_kernel, kr=kr, ti=ti, window=window, nt=nt),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((ti, 4), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((ti, 4), lambda i: (i, 0)),
            pl.BlockSpec((ti, 4), lambda i: (jnp.minimum(i + 1, nt - 1), 0)),
        ],
        out_specs=pl.BlockSpec((ti, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 2), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(packed, packed, packed)
    return out[:n]
