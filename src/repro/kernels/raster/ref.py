"""XLA reference path for the rasterization kernel family (render/).

Two primitives, both accumulating **int32 counts** — integer adds are
associative, so chunked accumulation is bit-identical to one-shot
whatever the chunk order (the renderer's streaming contract, mirroring
the engine's chunked==one-shot guarantee):

* ``count_scatter_ref`` — scatter-add per-sample increments into a flat
  accumulation buffer (edge splatting: every sampled line-segment point
  becomes one (channel·pixel, increment) row). Out-of-range positions
  (the renderer marks dropped samples INT32_MAX) fall off via scatter
  ``mode="drop"``.
* ``disk_accum_ref`` — dense per-pixel disk coverage: for every pixel and
  every node, test inside ``|p - c| ≤ r`` and accumulate into the node's
  color-group channel. Evaluated in row bands so the [n, band, w] mask is
  the only transient (never [n, h, w]); nodes with ``r ≤ 0`` (dead
  padding slots) and out-of-range groups contribute nothing.

The Pallas counterparts (splat.py) compute the same masks with the same
float32 ops, so parity is exact, not approximate (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("size",))
def count_scatter_ref(pos: jnp.ndarray, inc: jnp.ndarray, size: int) -> jnp.ndarray:
    """[N] int32 positions + [N] int32 increments → [size] int32 counts.

    Positions outside [0, size) are dropped (the splat path marks invalid
    samples INT32_MAX).
    """
    return count_scatter_into_ref(jnp.zeros(size, jnp.int32), pos, inc)


@jax.jit
def count_scatter_into_ref(
    acc: jnp.ndarray, pos: jnp.ndarray, inc: jnp.ndarray | None = None
) -> jnp.ndarray:
    """``acc.at[pos].add(inc)`` with out-of-range rows dropped — the
    accumulating form the renderer's chunk loop uses (no fresh buffer +
    add per chunk; with ``acc`` donated the scatter runs in place).

    ``inc=None`` means unit increments; that case pre-sorts the positions
    and flags ``indices_are_sorted`` — ~40% faster through XLA's CPU
    scatter, and with no increment vector to reorder the sort is a plain
    ``jnp.sort``. (A weighted sort would need sort_key_val, which costs
    more than the unsorted scatter saves.) Both orders sum identically —
    integer adds commute — so the chunked==one-shot contract is unmoved.
    """
    # Negative positions would wrap (NumPy indexing) before mode="drop"
    # sees them; remap onto the dropped slot just past the end.
    pos = jnp.where(pos < 0, acc.shape[0], pos)
    if inc is None:
        return acc.at[jnp.sort(pos)].add(1, mode="drop", indices_are_sorted=True)
    return acc.at[pos].add(inc, mode="drop")


@functools.partial(
    jax.jit, static_argnames=("n_groups", "h", "w", "band")
)
def disk_accum_ref(
    cx: jnp.ndarray,  # [n] float32 pixel-space centers
    cy: jnp.ndarray,  # [n] float32
    r: jnp.ndarray,  # [n] float32 pixel radii (≤ 0 = skip the node)
    group: jnp.ndarray,  # [n] int32 color group (out of range = skip)
    n_groups: int,
    h: int,
    w: int,
    band: int = 8,
) -> jnp.ndarray:
    """Per-pixel disk coverage counts, [n_groups, h, w] int32."""
    h_pad = ((h + band - 1) // band) * band
    xs = jnp.arange(w, dtype=jnp.float32)
    dx2 = (xs[None, :] - cx[:, None]) ** 2  # [n, w]
    r2 = (r * r)[:, None, None]
    alive = (r > 0)[:, None, None]
    # Negative groups would wrap (NumPy indexing) before mode="drop" sees
    # them; remap every out-of-range group onto the dropped slot n_groups.
    grp = jnp.where((group >= 0) & (group < n_groups), group, n_groups)

    def one_band(y0):
        ys = (y0 + jnp.arange(band)).astype(jnp.float32)  # [band]
        dy2 = (ys[None, :] - cy[:, None]) ** 2  # [n, band]
        inside = (dy2[:, :, None] + dx2[:, None, :]) <= r2  # [n, band, w]
        inside = inside & alive
        acc = jnp.zeros((n_groups, band, w), jnp.int32)
        return acc.at[grp].add(inside.astype(jnp.int32), mode="drop")

    bands = jax.lax.map(one_band, jnp.arange(h_pad // band) * band)
    return bands.transpose(1, 0, 2, 3).reshape(n_groups, h_pad, w)[:, :h]
