"""Pallas TPU kernels for the rasterization family (render/).

``count_scatter_pallas`` — the edge-splat scatter. XLA's gather/scatter is
the weak spot on TPU, so the wrapper sorts the sample positions once
(cheap, vectorized) and the kernel reuses the sorted-scatter idiom from
``kernels/merge``: grid = (output tiles × input blocks); a sorted block's
positions span one contiguous band of output tiles, so ``pl.when`` skips
every non-overlapping (tile, block) pair and the per-update work is
O(rows) mask-reductions instead of O(rows × tiles). Counts accumulate in
int32 — exact and order-independent, which is what makes the renderer's
chunked==one-shot contract bit-exact.

``disk_accum_pallas`` — per-pixel disk coverage as a one-hot matmul (the
``kernels/segment`` trick pointed at the image plane): for an image tile
of TP flattened pixels and a block of BLK nodes, the [BLK, TP] inside-disk
mask contracts with the [G, BLK] one-hot of color groups on the MXU,
accumulating [G, TP] per-channel coverage. Pixel coordinates are
precomputed host-side and streamed per tile, so the kernel does no
integer div/mod. Masks are the same float32 ops as the ref path — parity
is bit-exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams

_INT32_MAX = jnp.iinfo(jnp.int32).max


def _scatter_kernel(acc_ref, pos_ref, w_ref, o_ref, *, tn: int, blk: int):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        # Seed each output tile from the carried-in accumulator (aliased
        # to the output buffer, so the combine is in place in HBM).
        o_ref[...] = acc_ref[...]

    pos = pos_ref[0, :]  # [blk], sorted within the block
    base = pl.program_id(0) * tn
    # Sorted block ⇒ output span is [pos[0], pos[blk-1]]; skip tiles
    # outside it (same band-skip as kernels/merge).
    overlap = (pos[blk - 1] >= base) & (pos[0] < base + tn)

    @pl.when(overlap)
    def _scatter():
        local = pos - base
        rows = jax.lax.broadcasted_iota(jnp.int32, (tn, blk), 0)
        hit = rows == local[None, :]
        o_ref[0, :] += jnp.sum(
            jnp.where(hit, w_ref[0, :][None, :], 0), axis=1
        )


@functools.partial(jax.jit, static_argnames=("size", "tn", "blk", "interpret"))
def count_scatter_pallas(
    pos: jnp.ndarray,  # [N] int32 flat positions (out of range = dropped)
    inc: jnp.ndarray,  # [N] int32 increments
    size: int,
    acc: jnp.ndarray | None = None,  # [size] int32 to accumulate into
    tn: int = 2048,
    blk: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas counterpart of ``ref.count_scatter_ref`` (same contract).

    With ``acc`` the kernel accumulates into it in place (the buffer is
    aliased input→output), the counterpart of ``count_scatter_into_ref``
    — no second image-sized buffer or separate add on the streamed path.
    """
    # Negative positions would break the per-block band test after the
    # sort, so remap them onto the dropped marker before ordering.
    pos = jnp.where(pos < 0, _INT32_MAX, pos)
    order = jnp.argsort(pos)
    pos_s = pos[order]
    inc_s = inc[order]
    n = pos.shape[0]
    n_pad = ((n + blk - 1) // blk) * blk
    size_pad = ((size + tn - 1) // tn) * tn
    # INT32_MAX pad keeps the tail block sorted and outside every tile.
    pos_p = jnp.pad(pos_s, (0, n_pad - n), constant_values=_INT32_MAX)[None, :]
    inc_p = jnp.pad(inc_s, (0, n_pad - n))[None, :]
    if acc is None:
        acc2d = jnp.zeros((size_pad // tn, tn), jnp.int32)
    else:
        acc2d = jnp.pad(acc, (0, size_pad - size)).reshape(size_pad // tn, tn)
    grid = (size_pad // tn, n_pad // blk)
    out = pl.pallas_call(
        functools.partial(_scatter_kernel, tn=tn, blk=blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tn), lambda t, b: (t, 0)),
            pl.BlockSpec((1, blk), lambda t, b: (0, b)),
            pl.BlockSpec((1, blk), lambda t, b: (0, b)),
        ],
        out_specs=pl.BlockSpec((1, tn), lambda t, b: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((size_pad // tn, tn), jnp.int32),
        input_output_aliases={0: 0},
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(acc2d, pos_p, inc_p)
    return out.reshape(-1)[:size]


def _disk_kernel(px_ref, py_ref, cx_ref, cy_ref, r_ref, g_ref, o_ref, *, gp: int, blk: int):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref[...])

    px = px_ref[0, :]  # [tp] pixel x coords of this image tile
    py = py_ref[0, :]
    cx = cx_ref[0, :]  # [blk] node block
    cy = cy_ref[0, :]
    r = r_ref[0, :]
    g = g_ref[0, :]
    dx = px[None, :] - cx[:, None]  # [blk, tp]
    dy = py[None, :] - cy[:, None]
    inside = (dx * dx + dy * dy) <= (r * r)[:, None]
    inside = inside & (r[:, None] > 0)
    groups = jax.lax.broadcasted_iota(jnp.int32, (gp, blk), 0)
    onehot = jnp.where(groups == g[None, :], 1.0, 0.0)  # [gp, blk]
    o_ref[...] += jnp.dot(
        onehot, inside.astype(jnp.float32), preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("n_groups", "h", "w", "tp", "blk", "interpret")
)
def disk_accum_pallas(
    cx: jnp.ndarray,  # [n] float32 pixel-space centers
    cy: jnp.ndarray,  # [n] float32
    r: jnp.ndarray,  # [n] float32 pixel radii (≤ 0 = skip)
    group: jnp.ndarray,  # [n] int32 color group (out of range = skip)
    n_groups: int,
    h: int,
    w: int,
    tp: int = 1024,
    blk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas counterpart of ``ref.disk_accum_ref`` (same contract)."""
    n = cx.shape[0]
    n_pad = ((n + blk - 1) // blk) * blk
    p = h * w
    p_pad = ((p + tp - 1) // tp) * tp
    gp = max(8, ((n_groups + 7) // 8) * 8)  # sublane-aligned channel dim
    flat = jnp.arange(p_pad, dtype=jnp.int32)
    px = (flat % w).astype(jnp.float32)[None, :]
    py = (flat // w).astype(jnp.float32)[None, :]
    npad = (0, n_pad - n)
    cx_p = jnp.pad(cx, npad)[None, :]
    cy_p = jnp.pad(cy, npad)[None, :]
    r_p = jnp.pad(r, npad)[None, :]  # pad radius 0 ⇒ dead
    g_p = jnp.pad(group, npad, constant_values=-1)[None, :]
    grid = (p_pad // tp, n_pad // blk)
    out = pl.pallas_call(
        functools.partial(_disk_kernel, gp=gp, blk=blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tp), lambda t, b: (0, t)),
            pl.BlockSpec((1, tp), lambda t, b: (0, t)),
            pl.BlockSpec((1, blk), lambda t, b: (0, b)),
            pl.BlockSpec((1, blk), lambda t, b: (0, b)),
            pl.BlockSpec((1, blk), lambda t, b: (0, b)),
            pl.BlockSpec((1, blk), lambda t, b: (0, b)),
        ],
        out_specs=pl.BlockSpec((gp, tp), lambda t, b: (0, t)),
        out_shape=jax.ShapeDtypeStruct((gp, p_pad), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(px, py, cx_p, cy_p, r_p, g_p)
    # Coverage counts are small integers, exact in f32 — cast is lossless.
    return out.astype(jnp.int32)[:n_groups, :p].reshape(n_groups, h, w)
