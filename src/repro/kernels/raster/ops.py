"""Public wrappers for the raster primitives: Pallas on TPU, XLA scatter
elsewhere (dispatch mirrors kernels/segment and kernels/merge ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.raster.ref import (
    count_scatter_into_ref,
    count_scatter_ref,
    disk_accum_ref,
)
from repro.kernels.raster.splat import count_scatter_pallas, disk_accum_pallas


def _resolve(backend: str) -> tuple[str, bool]:
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    interpret = backend == "interpret" or jax.default_backend() != "tpu"
    return backend, interpret


def count_scatter(
    pos: jnp.ndarray,
    inc: jnp.ndarray,
    size: int,
    backend: str = "auto",
) -> jnp.ndarray:
    """[N] positions + [N] increments → [size] int32 counts (edge splat)."""
    backend, interpret = _resolve(backend)
    if backend == "ref":
        return count_scatter_ref(pos, inc, size)
    return count_scatter_pallas(pos, inc, size, interpret=interpret)


def count_scatter_into(
    acc: jnp.ndarray,
    pos: jnp.ndarray,
    inc: jnp.ndarray | None = None,
    backend: str = "auto",
) -> jnp.ndarray:
    """Accumulating ``count_scatter``: adds into ``acc`` instead of
    returning a fresh buffer (hot path of the renderer's chunk loop —
    in place when the caller donates ``acc``). ``inc=None`` = unit
    increments (takes the faster pre-sorted scatter on the ref path)."""
    backend, interpret = _resolve(backend)
    if backend == "ref":
        return count_scatter_into_ref(acc, pos, inc)
    if inc is None:
        inc = jnp.ones(pos.shape, jnp.int32)
    return count_scatter_pallas(
        pos, inc, acc.shape[0], acc=acc, interpret=interpret
    )


def disk_accum(
    cx: jnp.ndarray,
    cy: jnp.ndarray,
    r: jnp.ndarray,
    group: jnp.ndarray,
    n_groups: int,
    h: int,
    w: int,
    backend: str = "auto",
) -> jnp.ndarray:
    """Per-pixel disk coverage counts by color group, [n_groups, h, w]."""
    backend, interpret = _resolve(backend)
    if backend == "ref":
        return disk_accum_ref(cx, cy, r, group, n_groups, h, w)
    return disk_accum_pallas(cx, cy, r, group, n_groups, h, w, interpret=interpret)
