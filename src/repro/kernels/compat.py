"""API compatibility across JAX versions.

- jax ≥ 0.5 renamed ``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams``;
  kernels import the name from here so either version works.
- ``jax.device_put`` grew ``may_alias``/``donate`` keywords (~0.4.31);
  ``device_put_copied`` is the forced-copy transfer the double-buffered
  staging path needs (reused host staging buffers must never be aliased
  by the device array), degrading gracefully on older jax where CPU
  ``device_put`` always copies.
"""
import inspect

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# Signature probe only — executing a device_put here would initialize the
# JAX backend as an import side effect of the whole repro.core package.
_HAS_MAY_ALIAS = "may_alias" in inspect.signature(jax.device_put).parameters


def device_put_copied(x, sharding=None):
    """``jax.device_put`` that is guaranteed not to alias host memory."""
    if _HAS_MAY_ALIAS:
        return jax.device_put(x, sharding, may_alias=False, donate=False)
    return jax.device_put(x, sharding)
