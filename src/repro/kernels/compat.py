"""Pallas-TPU API compatibility across JAX versions.

jax ≥ 0.5 renamed ``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams``;
kernels import the name from here so either version works.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
