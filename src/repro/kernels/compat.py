"""API compatibility across JAX versions.

- jax ≥ 0.5 renamed ``pltpu.TPUCompilerParams`` → ``pltpu.CompilerParams``;
  kernels import the name from here so either version works.
- ``jax.device_put`` grew ``may_alias``/``donate`` keywords (~0.4.31);
  ``device_put_copied`` is the forced-copy transfer the double-buffered
  staging path needs (reused host staging buffers must never be aliased
  by the device array), degrading gracefully on older jax where CPU
  ``device_put`` always copies.
- ``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax`` and
  its replication-check kwarg was renamed (``check_rep`` → ``check_vma``);
  ``shard_map_compat`` papers over both so the sharded stream/layout paths
  run on every CI jax pin.
"""
import inspect

import jax
from jax.experimental.pallas import tpu as pltpu

try:  # jax ≥ ~0.6 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:  # the 0.4.x/0.5.x experimental home
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = inspect.signature(_shard_map).parameters
if "check_rep" in _SHARD_MAP_PARAMS:
    _NOCHECK = {"check_rep": False}
elif "check_vma" in _SHARD_MAP_PARAMS:
    _NOCHECK = {"check_vma": False}
else:  # pragma: no cover - future jax with the check removed entirely
    _NOCHECK = {}


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` with the static replication check disabled.

    The sharded stream/layout bodies return ``all_gather``-replicated
    values the checker cannot infer as replicated; disabling the check is
    the documented escape hatch and is bitwise-neutral.
    """
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **_NOCHECK
    )

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# Signature probe only — executing a device_put here would initialize the
# JAX backend as an import side effect of the whole repro.core package.
_HAS_MAY_ALIAS = "may_alias" in inspect.signature(jax.device_put).parameters


def device_put_copied(x, sharding=None):
    """``jax.device_put`` that is guaranteed not to alias host memory."""
    if _HAS_MAY_ALIAS:
        return jax.device_put(x, sharding, may_alias=False, donate=False)
    return jax.device_put(x, sharding)


def enable_persistent_compilation_cache(path) -> bool:
    """Point JAX's persistent compilation cache at ``path`` (created on
    first write) and drop the size/compile-time floors so every executable
    is cached. Restarted services then deserialize yesterday's
    executables instead of recompiling them — without it, cold-start
    compile dominates a tile server's first-request latency
    (``launch/serve.py`` wires this into its start path).

    Returns True when the cache engaged. The knob names have moved across
    jax versions (``jax.config`` flags ≥ ~0.4.26, the
    ``jax.experimental.compilation_cache`` module before), so this probes
    and degrades to False — callers treat a cold cache as a slow start,
    never an error.
    """
    try:
        jax.config.update("jax_compilation_cache_dir", str(path))
    except Exception:
        try:  # pre-flag API
            from jax.experimental.compilation_cache import compilation_cache

            compilation_cache.set_cache_dir(str(path))
        except Exception:
            return False
    for knob, value in (
        ("jax_persistent_cache_min_entry_size_bytes", -1),
        ("jax_persistent_cache_min_compile_time_secs", 0.0),
    ):
        try:
            jax.config.update(knob, value)
        except Exception:  # older jax without the floor knobs: still cached
            pass
    return True
