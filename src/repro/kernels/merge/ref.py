"""XLA path + pure-jnp oracle for sorted-merge-and-combine.

Merges two (a, b)-sorted deduplicated superedge runs — the persistent
aggregation state [cap] and a locally deduped chunk [C] — into one sorted
deduplicated run of the state's capacity, summing the weights of pairs
present in both. It exploits both inputs already being sorted: output
ranks come from vectorized binary searches (``jnp.searchsorted``) and the
rows land with two scatters, so a chunk update costs O(cap + C) moves plus
O((cap + C)·log) comparisons — never the lexsort baseline's full
O((cap + C)·log(cap + C)) re-sort of state + chunk.

Pairs compare as packed uint32 keys ``a·s_cap + b``: valid pairs satisfy
``a < b < s_cap ≤ 2¹⁶`` so the packing is collision-free and
order-preserving (identical to lexsorting by (a, b)); padded ``(s_cap,
s_cap)`` slots map to the uint32 max sentinel and sort last.
"""
from __future__ import annotations

import jax.numpy as jnp

SENTINEL = jnp.uint32(0xFFFFFFFF)
MAX_S_CAP = 1 << 16  # packing needs a, b < s_cap ≤ 2^16 to fit 32 bits


def pack_keys(a: jnp.ndarray, b: jnp.ndarray, s_cap: int) -> jnp.ndarray:
    """(a, b) int32 pairs → order-preserving uint32 keys (invalid → sentinel)."""
    if s_cap > MAX_S_CAP:
        raise ValueError(
            f"packed pair keys require s_cap ≤ {MAX_S_CAP}, got {s_cap}; "
            "use agg_backend='lexsort' beyond that"
        )
    key = a.astype(jnp.uint32) * jnp.uint32(s_cap) + b.astype(jnp.uint32)
    return jnp.where(a < s_cap, key, SENTINEL)


def unpack_keys(key: jnp.ndarray, s_cap: int):
    """uint32 keys → (a, b) int32 pairs; sentinel → the (s_cap, s_cap) pad."""
    a = (key // jnp.uint32(s_cap)).astype(jnp.int32)
    b = (key % jnp.uint32(s_cap)).astype(jnp.int32)
    pad = key == SENTINEL
    return jnp.where(pad, s_cap, a), jnp.where(pad, s_cap, b)


def merge_positions(sk: jnp.ndarray, ck: jnp.ndarray):
    """Merge-path ranks for the union of two sorted unique key runs.

    ``sk`` [cap] / ``ck`` [C] are uint32 keys, each sorted ascending with
    every valid key unique and sentinel padding last. Returns
    ``(pos_state [cap], pos_chunk [C], new_chunk [C])``: the rank of each
    row's key in the sorted union (a key's rank = valid state keys below
    it + chunk-only keys below it). Chunk keys already present in the
    state get their state partner's rank (so a scatter-add combines the
    weights) and ``new_chunk`` False; sentinel rows rank at ``cap + C``,
    past any capacity.
    """
    cap, c = sk.shape[0], ck.shape[0]
    valid_s = sk != SENTINEL
    valid_c = ck != SENTINEL
    # Each chunk key's insertion point in the state run, and whether the
    # state already holds it.
    ins_s = jnp.searchsorted(sk, ck, side="left").astype(jnp.int32)  # [C] ∈ [0, cap]
    partner = jnp.minimum(ins_s, cap - 1)
    dup = valid_c & (jnp.take(sk, partner) == ck)
    # Chunk-only (non-duplicate) keys below any probe, queryable by
    # insertion point: dup_cum[k] = duplicates among the first k chunk rows.
    dup_cum = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(dup).astype(jnp.int32)]
    )
    ins_c = jnp.searchsorted(ck, sk, side="left").astype(jnp.int32)  # [cap] ∈ [0, C]
    drop = jnp.int32(cap + c)
    pos_s = jnp.arange(cap, dtype=jnp.int32) + ins_c - dup_cum[ins_c]
    pos_s = jnp.where(valid_s, pos_s, drop)
    new_c = valid_c & ~dup
    arange_c = jnp.arange(c, dtype=jnp.int32)
    pos_new = ins_s + arange_c - dup_cum[arange_c]
    pos_c = jnp.where(
        new_c, pos_new, jnp.where(dup, jnp.take(pos_s, partner), drop)
    )
    return pos_s, pos_c, new_c


def merge_combine_ref(
    sa: jnp.ndarray,  # [cap] int32, sorted by (a, b), pad s_cap
    sb: jnp.ndarray,  # [cap] int32
    sw: jnp.ndarray,  # [cap] float32, pad 0
    ca: jnp.ndarray,  # [C] int32, sorted by (a, b), deduped, pad s_cap
    cb: jnp.ndarray,  # [C] int32
    cw: jnp.ndarray,  # [C] float32, pad 0
    s_cap: int,
):
    """Merge a sorted deduped chunk run into the sorted state run.

    Returns ``(oa [cap], ob [cap], ow [cap], n)`` with the union's
    lexicographically smallest ``cap`` pairs (overflow truncates the
    sorted tail, same contract as the lexsort path) and ``n`` the count
    of unique valid pairs in the union (may exceed ``cap``).
    """
    cap = sa.shape[0]
    sk = pack_keys(sa, sb, s_cap)
    ck = pack_keys(ca, cb, s_cap)
    pos_s, pos_c, new_c = merge_positions(sk, ck)
    # Overflow + sentinel rows route to a scratch slot that is sliced off.
    ps = jnp.minimum(pos_s, cap)
    pc = jnp.minimum(pos_c, cap)
    ok = (
        jnp.full((cap + 1,), SENTINEL, jnp.uint32)
        .at[ps].set(sk, mode="drop")
        .at[pc].set(ck, mode="drop")
    )
    ow = (
        jnp.zeros((cap + 1,), jnp.float32)
        .at[ps].add(sw, mode="drop")
        .at[pc].add(cw, mode="drop")
    )
    oa, ob = unpack_keys(ok[:cap], s_cap)
    n = (jnp.sum(sk != SENTINEL) + jnp.sum(new_c)).astype(jnp.int32)
    return oa, ob, ow[:cap], n
