"""Public wrapper: sorted-merge-and-combine via Pallas on TPU, XLA
searchsorted + scatter elsewhere (dispatch mirrors kernels/segment/ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.merge.ref import merge_combine_ref
from repro.kernels.merge.sorted_merge import merge_combine_pallas


def merge_combine(
    sa: jnp.ndarray,
    sb: jnp.ndarray,
    sw: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    cw: jnp.ndarray,
    s_cap: int,
    backend: str = "auto",
):
    """Merge a sorted deduped chunk run [C] into the sorted state run [cap].

    Both runs are (a, b)-sorted with unique valid pairs and (s_cap, s_cap,
    0) padding last. Returns (oa, ob, ow, n): the union's smallest ``cap``
    pairs with combined weights, and the union's unique-pair count.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return merge_combine_ref(sa, sb, sw, ca, cb, cw, s_cap)
    interpret = backend == "interpret" or jax.default_backend() != "tpu"
    return merge_combine_pallas(sa, sb, sw, ca, cb, cw, s_cap, interpret=interpret)
