"""Pallas TPU kernel: sorted-merge scatter-combine for superedge aggregation.

The merge-path ranks (where each input row's key lands in the merged
output) are cheap vectorized binary searches and stay in XLA
(``ref.merge_positions``); what XLA does poorly on TPU is the scatter
itself. This kernel is the scatter, and it exploits the one structural
fact the lexsort baseline throws away: both input runs are sorted, so
their output positions are monotone and every fixed-size input block
touches one contiguous band of output tiles. The grid enumerates
(out_tiles × in_blocks) like ``kernels/segment``, but a block's position
bounds skip every non-overlapping pair with ``pl.when``, so the work per
update is O(rows) mask-reductions instead of O(rows × tiles).

Weights accumulate by +, keys by max (each live output slot is hit by
exactly one key value — a state row, a chunk row, or both with equal
keys — so max is exact placement, and unhit slots stay at the -1 init).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams
from repro.kernels.merge.ref import SENTINEL, merge_positions, pack_keys

_INT32_MAX = jnp.iinfo(jnp.int32).max


def _kernel(pos_ref, a_ref, b_ref, w_ref, oa_ref, ob_ref, ow_ref, *, tn: int, blk: int):
    t = pl.program_id(0)
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        oa_ref[...] = jnp.full_like(oa_ref[...], -1)
        ob_ref[...] = jnp.full_like(ob_ref[...], -1)
        ow_ref[...] = jnp.zeros_like(ow_ref[...])

    pos = pos_ref[0, :]  # [blk], sorted within the block
    base = t * tn
    # Sorted block ⇒ its output span is [pos[0], pos[blk-1]]; skip tiles
    # outside it (this is where sortedness buys the linear-work scatter).
    overlap = (pos[blk - 1] >= base) & (pos[0] < base + tn)

    @pl.when(overlap)
    def _scatter():
        local = pos - base
        rows = jax.lax.broadcasted_iota(jnp.int32, (tn, blk), 0)
        hit = rows == local[None, :]
        ow_ref[0, :] += jnp.sum(
            jnp.where(hit, w_ref[0, :][None, :], 0.0), axis=1
        )
        oa_ref[0, :] = jnp.maximum(
            oa_ref[0, :], jnp.max(jnp.where(hit, a_ref[0, :][None, :], -1), axis=1)
        )
        ob_ref[0, :] = jnp.maximum(
            ob_ref[0, :], jnp.max(jnp.where(hit, b_ref[0, :][None, :], -1), axis=1)
        )


@functools.partial(
    jax.jit, static_argnames=("cap", "tn", "blk", "interpret")
)
def scatter_combine_pallas(
    pos: jnp.ndarray,  # [N] int32 output positions, sorted per blk-block
    a: jnp.ndarray,  # [N] int32
    b: jnp.ndarray,  # [N] int32
    w: jnp.ndarray,  # [N] float32
    cap: int,
    tn: int = 512,
    blk: int = 512,
    interpret: bool = False,
):
    """Place rows at their output positions: w by +, keys by max.

    ``pos`` must be sorted within every ``blk``-sized block (not globally);
    rows with ``pos ≥ cap`` land in the sliced-off pad region or miss every
    tile. Unhit slots return keys -1 and weight 0.
    """
    n = pos.shape[0]
    n_pad = ((n + blk - 1) // blk) * blk
    cap_pad = ((cap + tn - 1) // tn) * tn
    pad = (0, n_pad - n)
    # INT32_MAX pad keeps the tail block sorted and outside every tile.
    pos_p = jnp.pad(pos, pad, constant_values=_INT32_MAX)[None, :]
    a_p = jnp.pad(a, pad, constant_values=-1)[None, :]
    b_p = jnp.pad(b, pad, constant_values=-1)[None, :]
    w_p = jnp.pad(w, pad)[None, :]
    grid = (cap_pad // tn, n_pad // blk)
    spec_in = pl.BlockSpec((1, blk), lambda t, b: (0, b))
    spec_out = pl.BlockSpec((1, tn), lambda t, b: (t, 0))
    oa, ob, ow = pl.pallas_call(
        functools.partial(_kernel, tn=tn, blk=blk),
        grid=grid,
        in_specs=[spec_in] * 4,
        out_specs=[spec_out] * 3,
        out_shape=(
            jax.ShapeDtypeStruct((cap_pad // tn, tn), jnp.int32),
            jax.ShapeDtypeStruct((cap_pad // tn, tn), jnp.int32),
            jax.ShapeDtypeStruct((cap_pad // tn, tn), jnp.float32),
        ),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(pos_p, a_p, b_p, w_p)
    return oa.reshape(-1)[:cap], ob.reshape(-1)[:cap], ow.reshape(-1)[:cap]


def _pad_block(pos, a, b, w, blk: int):
    """Pad one sorted run to a block multiple so concatenated runs keep
    every block internally sorted (pad positions sort last)."""
    m = pos.shape[0]
    m_pad = ((m + blk - 1) // blk) * blk
    pad = (0, m_pad - m)
    return (
        jnp.pad(pos, pad, constant_values=_INT32_MAX),
        jnp.pad(a, pad, constant_values=-1),
        jnp.pad(b, pad, constant_values=-1),
        jnp.pad(w, pad),
    )


@functools.partial(
    jax.jit, static_argnames=("s_cap", "tn", "blk", "interpret")
)
def merge_combine_pallas(
    sa: jnp.ndarray,
    sb: jnp.ndarray,
    sw: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    cw: jnp.ndarray,
    s_cap: int,
    tn: int = 512,
    blk: int = 512,
    interpret: bool = False,
):
    """Pallas counterpart of ``ref.merge_combine_ref`` (same contract)."""
    cap = sa.shape[0]
    sk = pack_keys(sa, sb, s_cap)
    ck = pack_keys(ca, cb, s_cap)
    pos_s, pos_c, new_c = merge_positions(sk, ck)
    parts = [
        _pad_block(pos_s, sa, sb, sw, blk),
        _pad_block(pos_c, ca, cb, cw, blk),
    ]
    pos, a, b, w = (jnp.concatenate(cols) for cols in zip(*parts))
    oa, ob, ow = scatter_combine_pallas(
        pos, a, b, w, cap, tn=tn, blk=blk, interpret=interpret
    )
    oa = jnp.where(oa < 0, s_cap, oa)
    ob = jnp.where(ob < 0, s_cap, ob)
    n = (jnp.sum(sk != SENTINEL) + jnp.sum(new_c)).astype(jnp.int32)
    return oa, ob, ow, n
