"""Jit'd public wrapper for the n-body repulsion kernel.

Backend selection:
  * TPU            → Pallas kernel (nbody.py)
  * CPU, small n   → dense jnp oracle (fast enough, exact)
  * CPU, large n   → j-chunked jnp scan (same math, bounded memory) —
                     interpret-mode Pallas is too slow for production CPU
                     use; the kernel itself is validated in interpret mode
                     by tests/test_kernels_repulsion.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.repulsion.nbody import repulsion_pallas
from repro.kernels.repulsion.ref import EPS, repulsion_ref


def _pad(x, n_pad, fill=0.0):
    pad = [(0, n_pad - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("kr", "chunk", "use_radii"))
def repulsion_chunked(pos, mass, kr: float, radii=None, chunk: int = 1024,
                      use_radii: bool = True):
    """Scan over j-chunks; identical math to ref, O(n·chunk) live memory."""
    n = pos.shape[0]
    n_pad = ((n + chunk - 1) // chunk) * chunk
    pos_p = _pad(pos, n_pad)
    mass_p = _pad(mass, n_pad)
    rad_p = _pad(radii, n_pad) if (radii is not None and use_radii) else jnp.zeros(n_pad, pos.dtype)
    idx = jnp.arange(n_pad)

    pj = pos_p.reshape(-1, chunk, 2)
    mj = mass_p.reshape(-1, chunk)
    rj = rad_p.reshape(-1, chunk)
    ij = idx.reshape(-1, chunk)

    def body(acc, blk):
        pjc, mjc, rjc, ijc = blk
        dx = pos_p[:, 0:1] - pjc[None, :, 0]
        dy = pos_p[:, 1:2] - pjc[None, :, 1]
        d2 = dx * dx + dy * dy
        d = jnp.sqrt(jnp.maximum(d2, EPS * EPS))
        eff = jnp.maximum(d - rad_p[:, None] - rjc[None, :], EPS) if use_radii else jnp.maximum(d, EPS)
        mag = kr * mass_p[:, None] * mjc[None, :] / (eff * d)
        mag = jnp.where(idx[:, None] == ijc[None, :], 0.0, mag)
        fx = jnp.sum(mag * dx, axis=1)
        fy = jnp.sum(mag * dy, axis=1)
        return acc + jnp.stack([fx, fy], axis=1), None

    acc, _ = jax.lax.scan(body, jnp.zeros((n_pad, 2), pos.dtype), (pj, mj, rj, ij))
    return acc[:n]


@functools.partial(
    jax.jit, static_argnames=("nl", "kr", "chunk", "use_radii")
)
def repulsion_chunked_rows(pos, mass, i0, nl: int, kr: float, radii=None,
                           chunk: int = 1024, use_radii: bool = True):
    """Rows [i0, i0+nl) of ``repulsion_chunked`` without materializing the
    rest: same padded j-chunk partition and in-chunk reduction order, so the
    owned rows are bitwise identical (rows are independent in that scan).
    The sharded FA2 layout (core/forceatlas2.layout_sharded) calls this with
    each device's node range; ``i0`` may be traced. Keep the body in
    lockstep with ``repulsion_chunked`` above — any drift breaks the
    bit-identity the device-count CI matrix asserts.
    """
    n = pos.shape[0]
    n_pad = ((n + chunk - 1) // chunk) * chunk
    pos_p = _pad(pos, n_pad)
    mass_p = _pad(mass, n_pad)
    rad_p = _pad(radii, n_pad) if (radii is not None and use_radii) else jnp.zeros(n_pad, pos.dtype)
    idx = jnp.arange(n_pad)

    pr = jax.lax.dynamic_slice_in_dim(pos_p, i0, nl)
    mr = jax.lax.dynamic_slice_in_dim(mass_p, i0, nl)
    rr = jax.lax.dynamic_slice_in_dim(rad_p, i0, nl)
    ir = jax.lax.dynamic_slice_in_dim(idx, i0, nl)

    pj = pos_p.reshape(-1, chunk, 2)
    mj = mass_p.reshape(-1, chunk)
    rj = rad_p.reshape(-1, chunk)
    ij = idx.reshape(-1, chunk)

    def body(acc, blk):
        pjc, mjc, rjc, ijc = blk
        dx = pr[:, 0:1] - pjc[None, :, 0]
        dy = pr[:, 1:2] - pjc[None, :, 1]
        d2 = dx * dx + dy * dy
        d = jnp.sqrt(jnp.maximum(d2, EPS * EPS))
        eff = jnp.maximum(d - rr[:, None] - rjc[None, :], EPS) if use_radii else jnp.maximum(d, EPS)
        mag = kr * mr[:, None] * mjc[None, :] / (eff * d)
        mag = jnp.where(ir[:, None] == ijc[None, :], 0.0, mag)
        fx = jnp.sum(mag * dx, axis=1)
        fy = jnp.sum(mag * dy, axis=1)
        return acc + jnp.stack([fx, fy], axis=1), None

    acc, _ = jax.lax.scan(body, jnp.zeros((nl, 2), pos.dtype), (pj, mj, rj, ij))
    return acc


def repulsion(pos, mass, kr: float, radii=None, backend: str = "auto",
              tile: int = 512):
    """FA2 repulsion forces. pos [n,2], mass [n] → [n,2].

    Padded entries must carry mass 0 (they then exert/receive no force).
    """
    n = pos.shape[0]
    use_radii = radii is not None
    if backend == "auto":
        on_tpu = jax.default_backend() == "tpu"
        backend = "pallas" if on_tpu else ("ref" if n <= 2048 else "chunked")
    if backend == "ref":
        return repulsion_ref(pos, mass, kr, radii=radii)
    if backend == "chunked":
        return repulsion_chunked(pos, mass, kr, radii=radii, use_radii=use_radii)
    # pallas (or explicit interpret validation)
    interpret = backend == "interpret" or jax.default_backend() != "tpu"
    t = min(tile, max(128, n))
    n_pad = ((n + t - 1) // t) * t
    pos_p = _pad(pos, n_pad)
    mass_p = _pad(mass, n_pad)
    rad_p = _pad(radii, n_pad) if use_radii else jnp.zeros(n_pad, pos.dtype)
    out = repulsion_pallas(pos_p, mass_p, rad_p, kr, ti=t, tj=t,
                           use_radii=use_radii, interpret=interpret)
    return out[:n]
