"""Pure-jnp oracle for FA2 degree-weighted n-body repulsion.

    f_i = Σ_{j≠i} kr · m_i · m_j · (x_i − x_j) / d_ij²

with the supernode variant shifting the interaction distance by the two
radii (paper §4.1: big communities get space ∝ √size):

    d'_ij = max(d_ij − r_i − r_j, ε)
    f_i   = Σ kr · m_i · m_j · û_ij / d'_ij
"""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-4


def repulsion_ref(
    pos: jnp.ndarray,
    mass: jnp.ndarray,
    kr: float,
    radii: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """O(n²) dense reference. pos [n,2] f32, mass [n] f32 → forces [n,2]."""
    diff = pos[:, None, :] - pos[None, :, :]  # [n, n, 2]
    d2 = jnp.sum(diff * diff, axis=-1)
    d = jnp.sqrt(jnp.maximum(d2, EPS * EPS))
    if radii is not None:
        eff = jnp.maximum(d - radii[:, None] - radii[None, :], EPS)
    else:
        eff = jnp.maximum(d, EPS)
    mag = kr * mass[:, None] * mass[None, :] / (eff * d)  # /d normalizes diff
    mag = jnp.where(jnp.eye(pos.shape[0], dtype=bool), 0.0, mag)
    return jnp.sum(mag[..., None] * diff, axis=1)
