"""Pallas TPU kernel: tiled FA2 n-body repulsion.

Adaptation of the paper's Barnes–Hut GPU repulsion (DESIGN.md §2): on a
supergraph (n ≤ ~2·10⁵) exact O(n²) pairwise interaction evaluated in
VMEM tiles is faster on TPU than a pointer-chasing tree — the pair tile
is a dense [TI, TJ] elementwise block that maps onto the VPU, streamed
FlashAttention-style.

Grid = (n/TI, n/TJ): the i axis is parallel; the j axis revisits the same
output block and accumulates (``dimension_semantics=("parallel",
"arbitrary")``). Working set per step: 2·(TI+TJ) pos/mass/radii vectors +
four [TI, TJ] pair blocks ≈ 1.3 MB at TI=TJ=512 — comfortably in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels.compat import CompilerParams

EPS = 1e-4


def _kernel(pos_i_ref, mass_i_ref, rad_i_ref, pos_j_ref, mass_j_ref, rad_j_ref,
            out_ref, *, kr: float, ti: int, tj: int, use_radii: bool):
    i_step = pl.program_id(0)
    j_step = pl.program_id(1)

    xi = pos_i_ref[:, 0:1]  # [TI, 1]
    yi = pos_i_ref[:, 1:2]
    xj = pos_j_ref[:, 0:1].T  # [1, TJ]
    yj = pos_j_ref[:, 1:2].T
    dx = xi - xj  # [TI, TJ]
    dy = yi - yj
    d2 = dx * dx + dy * dy
    d = jnp.sqrt(jnp.maximum(d2, EPS * EPS))

    mi = mass_i_ref[:, 0:1]
    mj = mass_j_ref[:, 0:1].T
    if use_radii:
        eff = jnp.maximum(d - rad_i_ref[:, 0:1] - rad_j_ref[:, 0:1].T, EPS)
    else:
        eff = jnp.maximum(d, EPS)

    gi = i_step * ti + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 0)
    gj = j_step * tj + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 1)
    mag = jnp.where(gi == gj, 0.0, kr * mi * mj / (eff * d))

    fx = jnp.sum(mag * dx, axis=1, keepdims=True)  # [TI, 1]
    fy = jnp.sum(mag * dy, axis=1, keepdims=True)
    partial = jnp.concatenate([fx, fy], axis=1)  # [TI, 2]

    @pl.when(j_step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j_step != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("kr", "ti", "tj", "use_radii", "interpret"))
def repulsion_pallas(
    pos: jnp.ndarray,
    mass: jnp.ndarray,
    radii: jnp.ndarray,
    kr: float,
    ti: int = 512,
    tj: int = 512,
    use_radii: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    """pos [n,2] f32, mass/radii [n] f32 → forces [n,2]. n must divide ti/tj
    (ops.py pads; padded slots carry mass 0 so they are force-neutral)."""
    n = pos.shape[0]
    assert n % ti == 0 and n % tj == 0, (n, ti, tj)
    grid = (n // ti, n // tj)
    m2 = mass[:, None]
    r2 = radii[:, None]
    return pl.pallas_call(
        functools.partial(_kernel, kr=kr, ti=ti, tj=tj, use_radii=use_radii),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((ti, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((ti, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tj, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((tj, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((tj, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ti, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), pos.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(pos, m2, r2, pos, m2, r2)
