"""Deterministic fault injection for the streaming pipeline (ISSUE 10
tentpole, part 3).

Everything here is seeded and addressable: faults fire at exact read
offsets or exact chunk boundaries, so a test (or
``benchmarks/resilience_bench.py --check``) can kill a run at chunk 7 of
round 1, resume it, and compare sha256s against the uninterrupted run —
no flaky timing, no monkeypatching.

* ``ChaosEdgeStore`` wraps any ``EdgeStore`` and injects I/O errors,
  truncated (short) reads, and bit-flips at reads starting on configured
  row offsets. ``transient_attempts`` makes a fault clear after that many
  failed attempts (exercising the retry path); 0 means permanent
  (exercising quarantine).
* ``KillSwitch`` raises ``SimulatedPreemption`` at the k-th chunk
  boundary — plug it into ``StreamCheckpointer.on_boundary`` to simulate
  a SIGKILL'd process at a deterministic point.
* ``poison_weights`` NaN/inf-poisons layout inputs to exercise the FA2
  divergence sentinel (``FA2Config.nan_guard``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data.edge_store import EdgeStore, as_edge_store


class SimulatedPreemption(RuntimeError):
    """The chaos analog of SIGKILL: the run stops here, mid-stream."""


@dataclass
class KillSwitch:
    """Raise ``SimulatedPreemption`` at the ``at_boundary``-th chunk
    boundary (0-based, counted across phases/rounds). Use as
    ``StreamCheckpointer(on_boundary=KillSwitch(k))``."""

    at_boundary: int
    fired: bool = field(default=False, repr=False)
    _seen: int = field(default=0, repr=False)

    def __call__(self, phase: str, rnd: int, chunk: int) -> None:
        if self._seen == self.at_boundary:
            self.fired = True
            raise SimulatedPreemption(
                f"killed at boundary {self._seen} "
                f"({phase} round {rnd} chunk {chunk})"
            )
        self._seen += 1


@dataclass(frozen=True)
class ChaosConfig:
    """Faults keyed by the *row offset a read starts at* (for the streaming
    engine that is ``chunk_index * chunk_size``, so chunk k of an
    engine with chunk size C is addressed as ``k * C``).

    ``transient_attempts`` = how many attempts at an offset fail before
    reads succeed (0 = every attempt fails, forever). ``truncate_rows`` =
    rows returned by a truncated read before it stops short."""

    seed: int = 0
    io_error_offsets: tuple = ()  # reads here raise OSError
    truncate_offsets: tuple = ()  # reads here come up short
    bitflip_offsets: tuple = ()  # reads here corrupt one node id
    transient_attempts: int = 0  # 0 = permanent faults
    truncate_rows: int = 0


class ChaosEdgeStore(EdgeStore):
    """An ``EdgeStore`` wrapper injecting the configured faults.

    Construction-time metadata (``n_edges``) is passed through unchanged —
    chaos models *read-time* corruption, the kind store-open validation
    cannot catch. ``injected`` records what actually fired, keyed by
    ``(kind, offset)``, so tests can assert the fault was exercised."""

    def __init__(self, inner, cfg: ChaosConfig):
        self.inner = as_edge_store(inner)
        self.cfg = cfg
        self.n_edges = self.inner.n_edges
        self._attempts: dict = {}
        self.injected: dict = {}

    def _fails(self, kind: str, start: int) -> bool:
        key = (kind, start)
        n = self._attempts.get(key, 0)
        self._attempts[key] = n + 1
        if self.cfg.transient_attempts and n >= self.cfg.transient_attempts:
            return False  # transient fault: cleared after N failed attempts
        self.injected[key] = self.injected.get(key, 0) + 1
        return True

    def read_into(self, start: int, out: np.ndarray) -> int:
        if start in self.cfg.io_error_offsets and self._fails("io", start):
            raise OSError(f"chaos: injected I/O error at row {start}")
        if start in self.cfg.truncate_offsets and self._fails("trunc", start):
            k = min(self.cfg.truncate_rows, len(out))
            self.inner.read_into(start, out[:k])
            return k
        k = self.inner.read_into(start, out)
        if start in self.cfg.bitflip_offsets and k and self._fails("flip", start):
            rng = np.random.default_rng(self.cfg.seed + start)
            row = int(rng.integers(0, k))
            col = int(rng.integers(0, 2))
            out[row, col] |= np.int32(1 << 30)  # id blown out of range
        return k

    @property
    def resident_bytes(self) -> int:
        return self.inner.resident_bytes


def poison_weights(weights, k: int = 1, seed: int = 0):
    """Return a copy of ``weights`` with ``k`` entries NaN-poisoned at
    seeded positions — feeds the FA2 attraction pass non-finite forces to
    exercise the ``nan_guard`` sentinel."""
    w = np.array(weights, dtype=np.float32, copy=True)
    if w.size == 0 or k <= 0:
        return w
    rng = np.random.default_rng(seed)
    idx = rng.choice(w.size, size=min(k, w.size), replace=False)
    w.flat[idx] = np.nan
    return w
