"""Fault tolerance for the streaming pipeline (ISSUE 10).

The production-scale north star needs runs that survive what production
throws at them: killed processes (checkpoint/resume — ``checkpoint``),
corrupt or flaky input (validation/quarantine — ``validate``), and a way
to prove both deterministically (fault injection — ``chaos``). Graceful
degradation in the hot paths lives with the code it guards
(``core/forceatlas2.FA2Config.nan_guard``, ``serve/tiles.TileEngine``).

``repro.train.checkpoint`` is now a deprecated re-export shim over
``repro.resilience.checkpoint`` (same format, same functions); the
training-substrate ``CheckpointManager``/``ElasticPlan`` are re-exported
here as the step-counted (rather than chunk-boundary) flavor.
"""
from repro.resilience.checkpoint import (  # noqa: F401
    CheckpointMismatchError,
    Preempted,
    StreamCheckpointer,
    config_fingerprint,
    latest_step,
    load_arrays,
    restore,
    restore_latest_valid,
    save,
)
from repro.resilience.chaos import (  # noqa: F401
    ChaosConfig,
    ChaosEdgeStore,
    KillSwitch,
    SimulatedPreemption,
    poison_weights,
)
from repro.resilience.validate import (  # noqa: F401
    ValidationAccounting,
    ValidationError,
    ValidationPolicy,
    validated_read,
)
from repro.train.fault_tolerance import (  # noqa: F401
    CheckpointManager,
    ElasticPlan,
)

__all__ = [
    "CheckpointManager",
    "CheckpointMismatchError",
    "ChaosConfig",
    "ChaosEdgeStore",
    "ElasticPlan",
    "KillSwitch",
    "Preempted",
    "SimulatedPreemption",
    "StreamCheckpointer",
    "ValidationAccounting",
    "ValidationError",
    "ValidationPolicy",
    "config_fingerprint",
    "latest_step",
    "load_arrays",
    "poison_weights",
    "restore",
    "restore_latest_valid",
    "save",
    "validated_read",
]
