"""Checkpoint save/restore with elastic resharding + the streaming
checkpointer (ISSUE 10 tentpole).

Promoted from the seed's dormant ``repro/train/checkpoint.py`` (which now
re-exports this module and is deprecated at its old path): the format and
atomicity guarantees are unchanged, and the training substrate keeps
importing through the shim.

Format: one .npz per checkpoint (flattened pytree with '/'-joined path
keys) + a meta.json (step, phase/round/chunk cursor, config fingerprint).
Writes are atomic (tmp + rename) and a keep-last-k window is enforced —
the two properties that make checkpoint/restart safe under preemption.

Elasticity: arrays are stored unsharded; ``restore`` device_puts every
leaf onto the *target* shardings, so a checkpoint taken on one mesh
restores onto any other (scale up/down) as long as shapes match. The
streaming engine exploits exactly this: its sharded update steps keep
node/sketch/agg state replicated (core/stream.py), so a stream checkpoint
written on one device count resumes bit-identically on any other — the
restored arrays are plain host numpy, re-``device_put`` by the first
jitted update that consumes them.

``StreamCheckpointer`` is the engine-facing half: ``core/stream.py`` calls
``boundary()`` after every completed chunk update, and the checkpointer
decides whether to persist (every ``every_chunks`` boundaries, at round
boundaries when ``every_chunks`` is 0, or immediately after a SIGTERM —
``install_preemption_handler``). ``on_boundary`` is the chaos hook
(``resilience/chaos.KillSwitch``) used to kill runs at deterministic
points in tests and ``benchmarks/resilience_bench.py``.
"""
from __future__ import annotations

import hashlib
import json
import os
import signal
import tempfile
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

SEP = "/"


class Preempted(RuntimeError):
    """Raised at a chunk boundary after the preemption-triggered checkpoint
    was written (``StreamCheckpointer.exit_on_preempt``) — the launcher
    catches it and exits cleanly; ``--resume`` picks the run back up."""


class CheckpointMismatchError(RuntimeError):
    """A checkpoint's config fingerprint does not match the resuming run —
    resuming would silently produce garbage, so it is an error instead."""


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = SEP.join(_key_str(k) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 etc.): npz-opaque
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        flat[key] = arr
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None,
         keep: int = 3) -> str:
    """Atomically write checkpoint ``step``; prune to the newest ``keep``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    fd_m, tmp_meta = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd_m)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        # Meta lands (atomically) BEFORE the npz rename: a kill between the
        # two leaves an orphaned meta (invisible — discovery keys off .npz)
        # rather than a meta-less npz that readers would mis-trust.
        meta = {"step": step, **(extra or {})}
        with open(tmp_meta, "w") as f:
            json.dump(meta, f)
        os.replace(tmp_meta, final + ".meta.json")
        os.replace(tmp, final)  # atomic on POSIX
    finally:
        for t in (tmp, tmp_meta):
            if os.path.exists(t):
                os.unlink(t)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    names = os.listdir(ckpt_dir)
    ckpts = sorted(
        f for f in names if f.startswith("step_") and f.endswith(".npz")
    )
    for old in ckpts[:-keep]:
        os.unlink(os.path.join(ckpt_dir, old))
        meta = os.path.join(ckpt_dir, old + ".meta.json")
        if os.path.exists(meta):
            os.unlink(meta)
    # Orphaned metas (kill before the npz rename, pruned/corrupt npz).
    for f in names:
        if (f.startswith("step_") and f.endswith(".npz.meta.json")
                and not os.path.exists(
                    os.path.join(ckpt_dir, f[: -len(".meta.json")]))):
            try:
                os.unlink(os.path.join(ckpt_dir, f))
            except FileNotFoundError:
                pass


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(f[len("step_") : -len(".npz")])
        for f in os.listdir(ckpt_dir)
        if f.startswith("step_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any | None = None):
    """Rebuild the pytree of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (matching pytree of NamedSharding)
    re-shards onto the CURRENT mesh — elastic restore."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    leaves_with_path = jax.tree_util.tree_leaves_with_path(like)
    vals = []
    for kpath, leaf in leaves_with_path:
        key = SEP.join(_key_str(k) for k in kpath)
        arr = data[key]
        want = np.dtype(leaf.dtype) if not hasattr(leaf.dtype, "itemsize") else leaf.dtype
        if arr.dtype.kind == "u" and np.dtype(want).kind == "V":
            arr = arr.view(want)  # round-trip ml_dtypes (bfloat16) storage
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        vals.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, vals)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    else:
        tree = jax.tree_util.tree_map(jax.device_put, tree)
    meta_path = path + ".meta.json"
    meta = {}
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    return tree, meta


def load_arrays(ckpt_dir: str, step: int) -> tuple[dict, dict]:
    """Load checkpoint ``step`` as a flat ``{key: host ndarray}`` dict plus
    its meta — the shape-agnostic reader the streaming resume path uses
    (it knows its own state shapes; no ``like`` pytree needed)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    meta = {}
    meta_path = path + ".meta.json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    return arrays, meta


def restore_latest_valid(
    ckpt_dir: str,
    valid: Callable[[dict, dict], bool] | None = None,
) -> tuple[dict, dict] | None:
    """Newest loadable checkpoint as ``(arrays, meta)``, or None if the
    directory holds none. A corrupt newest file (impossible via the atomic
    rename, but disks bit-rot) is deleted — npz and its meta together —
    and the walk continues back through the keep-last-k window. ``valid``
    (arrays, meta) lets callers demand semantic completeness (e.g. the
    stream resume cursor keys) with the same walk-back-on-failure."""
    step = latest_step(ckpt_dir)
    while step is not None:
        bad = False
        try:
            arrays, meta = load_arrays(ckpt_dir, step)
            if valid is None or valid(arrays, meta):
                return arrays, meta
            bad = True  # loadable but incomplete → walk back
        except Exception:  # partial/corrupt → try the previous one
            bad = True
        if bad:
            path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
            for p in (path, path + ".meta.json"):
                try:
                    os.unlink(p)
                except FileNotFoundError:
                    pass
        step = latest_step(ckpt_dir)
    return None


def config_fingerprint(**kwargs) -> str:
    """Short sha256 over the repr of the run parameters that must match for
    a resume to be bit-identical (graph extents, chunk size, stage configs).
    Dataclass reprs are deterministic, so equal configs hash equal."""
    blob = json.dumps(
        {k: repr(v) for k, v in sorted(kwargs.items())}, sort_keys=True
    )
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


@dataclass
class StreamCheckpointer:
    """Chunk-boundary checkpoint cadence + SIGTERM handling for the
    streaming engine (``core/stream.py`` calls ``boundary`` after every
    completed chunk update; ``stream_pipeline(..., resume=)`` restores).

    ``every_chunks`` > 0 saves every that-many boundaries; 0 saves at
    round/pass boundaries only. A SIGTERM (``install_preemption_handler``)
    forces a save at the next boundary regardless of cadence, and with
    ``exit_on_preempt`` raises ``Preempted`` right after it so launchers
    under systemd/SLURM exit cleanly with a final checkpoint on disk.

    ``fingerprint`` is stamped into every meta.json; ``stream_pipeline``
    fills it from its own config and refuses to resume from a checkpoint
    whose fingerprint differs (``CheckpointMismatchError``).

    ``on_boundary(phase, round, chunk)`` fires at *every* boundary, after
    any save — the deterministic fault-injection hook
    (``resilience.chaos.KillSwitch``).
    """

    ckpt_dir: str
    every_chunks: int = 0
    keep: int = 3
    fingerprint: str = ""
    exit_on_preempt: bool = False
    on_boundary: Callable | None = None
    _seq: int = field(default=0, repr=False)
    _preempted: bool = field(default=False, repr=False)
    saves: int = field(default=0, repr=False)

    def install_preemption_handler(self) -> None:
        """SIGTERM (the cloud/cluster preemption signal) ⇒ checkpoint at
        the next chunk boundary. Returns via ``signal.signal``'s contract;
        call from the main thread."""
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    def want_save(self, at_round_boundary: bool) -> bool:
        if self._preempted:
            return True
        if self.every_chunks > 0:
            return self._seq % self.every_chunks == 0
        return at_round_boundary

    def boundary(self, phase: str, rnd: int, chunk: int,
                 at_round_boundary: bool, payload: Callable[[], dict]) -> None:
        """One completed chunk update. ``(rnd, chunk)`` is the *resume
        cursor* (the next unprocessed chunk, round-boundary normalized);
        ``payload`` lazily materializes the host-side state dict so
        non-saving boundaries cost nothing."""
        self._seq += 1
        preempted = self._preempted
        if self.want_save(at_round_boundary):
            self.save(phase, rnd, chunk, payload())
        if preempted and self.exit_on_preempt:
            raise Preempted(
                f"preempted: checkpoint written at {phase} round {rnd} "
                f"chunk {chunk} under {self.ckpt_dir}"
            )
        if self.on_boundary is not None:
            self.on_boundary(phase, rnd, chunk)

    def save(self, phase: str, rnd: int, chunk: int, arrays: dict) -> str:
        path = save(
            self.ckpt_dir, self._seq, arrays,
            extra={"phase": phase, "round": rnd, "chunk": chunk,
                   "fingerprint": self.fingerprint},
            keep=self.keep,
        )
        self.saves += 1
        self._preempted = False
        return path

    def seed(self, meta: dict) -> None:
        """Continue the save sequence past a restored checkpoint's step.
        Without this a resumed process restarts ``_seq`` at 0 while the
        pre-kill ``step_`` files are still on disk: ``_prune`` keeps the
        lexically newest names, so every post-resume save would be deleted
        on arrival (and ``latest_step`` would keep answering with the
        stale pre-kill checkpoint) until the counter caught up."""
        self._seq = max(self._seq, int(meta.get("step", 0)))

    def restore_latest(self) -> tuple[dict, dict] | None:
        found = restore_latest_valid(self.ckpt_dir)
        if found is not None:
            self.seed(found[1])
        return found
