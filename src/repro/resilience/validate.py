"""Input validation & quarantine at the EdgeStore/EdgeChunkStream boundary
(ISSUE 10 tentpole, part 2).

The streaming engine trusts its stores after construction-time dtype/shape
checks — correct for clean data, fatal for a production ingest path where a
crawler shard can be truncated mid-write, an NFS read can fail transiently,
or a bit-flip can push a node id out of range. ``ValidationPolicy`` +
``validated_read`` make the per-chunk read defensive without touching the
jitted update bodies:

* transient I/O errors (``OSError``) and short reads are retried with
  doubling backoff up to ``max_retries`` times;
* a chunk that still cannot be read is **quarantined**: its buffer is
  filled with the trash node (a no-op for every chunk-update kernel), its
  id is recorded, and the run continues — surfaced in ``StreamStats`` and
  the ``errors.*`` counters instead of crashing a multi-pass run;
* rows that read fine but are *invalid* (node ids outside ``[0, n_nodes]``,
  optionally self-loops) are dropped to the trash node (or raised on,
  per policy) and counted.

Every counter increments at the point of occurrence — publish paths only
mirror totals, so nothing double-counts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.obs.metrics import REGISTRY, ensure_error_counters


class ValidationError(ValueError):
    """A chunk contained invalid rows and the policy said error, not drop."""


@dataclass(frozen=True)
class ValidationPolicy:
    """Defensive-read policy for ``EdgeChunkStream``.

    ``self_loops``: "keep" (paper-default — SCoDA ignores them anyway),
    "drop" (to trash), or "error". ``on_invalid`` governs out-of-range node
    ids: "drop" or "error". ``quarantine`` False turns exhausted-retry
    chunks into raised ``OSError`` instead of trash-filled buffers."""

    check_range: bool = True
    self_loops: str = "keep"  # keep | drop | error
    on_invalid: str = "drop"  # drop | error
    max_retries: int = 2
    retry_backoff_s: float = 0.01
    quarantine: bool = True

    def __post_init__(self):
        if self.self_loops not in ("keep", "drop", "error"):
            raise ValueError(f"self_loops: bad value {self.self_loops!r}")
        if self.on_invalid not in ("drop", "error"):
            raise ValueError(f"on_invalid: bad value {self.on_invalid!r}")


@dataclass
class ValidationAccounting:
    """Mutable per-run tally, mirrored into ``StreamStats`` by the engine."""

    retries: int = 0
    quarantined: list = field(default_factory=list)  # chunk indices
    dropped_edges: int = 0


def _read_full(store, start: int, want: int, buf: np.ndarray) -> None:
    """One read attempt; a short read (truncation landed mid-chunk) is
    promoted to OSError with the byte offset so retry/quarantine applies."""
    k = store.read_into(start, buf[:want])
    if k < want:
        raise OSError(
            f"short read: got {k} of {want} rows at row {start} "
            f"(byte offset {(start + k) * 8})"
        )


def validated_read(
    store,
    chunk_index: int,
    chunk_size: int,
    buf: np.ndarray,
    n_nodes: int,
    policy: ValidationPolicy,
    acct: ValidationAccounting,
    registry=None,
) -> np.ndarray:
    """Fill ``buf`` with chunk ``chunk_index`` defensively (see module doc).

    Always returns a fully-populated [chunk_size, 2] buffer whose every row
    is either a valid edge or the trash pair ``(n_nodes, n_nodes)`` — the
    same contract as the trusting read, so downstream kernels are unchanged.
    """
    reg = registry if registry is not None else REGISTRY
    ensure_error_counters(reg)
    start = chunk_index * chunk_size
    want = min(chunk_size, store.n_edges - start)

    err = None
    for attempt in range(policy.max_retries + 1):
        try:
            _read_full(store, start, want, buf)
            err = None
            break
        except OSError as e:
            err = e
            if attempt < policy.max_retries:
                acct.retries += 1
                reg.counter("errors.io_retries").inc()
                time.sleep(policy.retry_backoff_s * (2 ** attempt))
    if err is not None:
        if not policy.quarantine:
            raise err
        buf[:] = n_nodes  # all-trash chunk: a no-op for every update body
        acct.quarantined.append(chunk_index)
        reg.counter("errors.quarantined_chunks").inc()
        return buf

    if want < chunk_size:
        buf[want:] = n_nodes  # normal tail padding

    live = buf[:want]
    bad = np.zeros(want, dtype=bool)
    if policy.check_range:
        bad |= ((live < 0) | (live > n_nodes)).any(axis=1)
    if policy.self_loops != "keep":
        loops = (live[:, 0] == live[:, 1]) & (live[:, 0] != n_nodes)
        if policy.self_loops == "error" and loops.any():
            raise ValidationError(
                f"chunk {chunk_index}: {int(loops.sum())} self-loop rows "
                f"(first at row {start + int(np.argmax(loops))})"
            )
        bad |= loops
    n_bad = int(bad.sum())
    if n_bad:
        if policy.on_invalid == "error":
            first = start + int(np.argmax(bad))
            raise ValidationError(
                f"chunk {chunk_index}: {n_bad} invalid rows "
                f"(node id outside [0, {n_nodes}]; first at row {first})"
            )
        live[bad] = n_nodes  # drop to trash
        acct.dropped_edges += n_bad
        reg.counter("errors.invalid_edges").inc(n_bad)
    return buf
